# Build / test / bench entry points. CI (.github/workflows/ci.yml) calls
# exactly these targets, so a local `make <target>` reproduces the CI run
# bit for bit — no inline-shell drift between the two.

CARGO  ?= cargo
PYTHON ?= python3
ARTIFACTS ?= artifacts

# CI-scale ablation knobs (tiny on purpose: these runs exist so the bench
# recorder and its JSON schema can't silently rot, not to produce
# publishable numbers). Override: make bench-smoke SMOKE_FLAGS='--secs 1'.
SMOKE_FLAGS ?= --secs 0.1 --runs 1 --warmup 0 --initial 2000 \
  --workload-threads 2 --size-heavy-threads 2 --refresh-us 300,1000

# Pinned fault seed (decimal 0xC1A05) for the fuzz smoke: CI failures
# must replay locally with the exact same schedule. Override:
# make fuzz-smoke FUZZ_SEED=7.
FUZZ_SEED ?= 793093
FUZZ_FLAGS ?= --fault-seed $(FUZZ_SEED) --seeds 2 --ops 800 --structure hashtable

.PHONY: build test pytest bench-smoke schema-check regress-check \
  server-smoke artifacts fuzz-smoke resize-stress fmt fmt-check lint clean

## Release build of the library, the csize binary, and every example
## (kv_server is an example, so --examples is not optional).
build:
	$(CARGO) build --release --bins --examples

## Tier-1 verify: the whole Rust test suite.
test:
	$(CARGO) test -q

## Kernel tests (needs jax[cpu] + pytest; CI installs them).
pytest:
	$(PYTHON) -m pytest python/tests -q

## Format and lint gates, same invocations CI runs. `make fmt` rewrites
## in place — run it wherever a toolchain exists before pushing.
fmt:
	$(CARGO) fmt

fmt-check:
	$(CARGO) fmt --check

lint:
	$(CARGO) clippy --all-targets -- -D warnings

## Six-policy ablation smoke run; writes BENCH_ablation.json.
bench-smoke:
	$(CARGO) bench --bench ablation_policies -- $(SMOKE_FLAGS)

## Schema sanity for the bench recorder's report: required keys (incl.
## shards / refresh_us / daemon_rounds), no NaN, no negative throughput.
schema-check:
	$(PYTHON) scripts/check_ablation_schema.py BENCH_ablation.json

## Throughput regression gate: fresh BENCH_ablation.json vs the previous
## CI run's artifact. Fails on a >25% drop in any matched record;
## soft-passes (warn, exit 0) when the baseline is missing — first run,
## or the artifact download fell over.
REGRESS_BASELINE ?= baseline/BENCH_ablation.json
regress-check:
	$(PYTHON) scripts/check_ablation_regress.py $(REGRESS_BASELINE) \
	  BENCH_ablation.json

## Boot the reactor server and drive the full protocol — including an
## overload burst that must observe ERR OVERLOAD — failing loud on hangs.
server-smoke: build
	timeout 120 bash scripts/server_smoke.sh

## Chaos gate: the fault-injection test suite (feature `faults` arms the
## injection sites the default build compiles out) plus a pinned-seed
## `csize fuzz` sweep — six policies under the chaos plane, minimized
## repro histories dumped to artifacts/ on any violation. timeout-wrapped
## so a wedged schedule fails loud instead of hanging CI.
fuzz-smoke:
	timeout 300 $(CARGO) test -q --features faults
	timeout 300 $(CARGO) run --release --features faults --bin csize -- \
	  fuzz $(FUZZ_FLAGS)

## Growth gate: `csize resize-stress` under the chaos plane — phase 1 is
## the in-process growth workload (10x trigger capacity of inserts, the
## 50%-of-median window-collapse gate, migration drained to zero); phase
## 2 mounts a resizing hashtable on a monitored server and swarms it,
## asserting zero monitor violations, resizes >= 1, and
## migration_pending == 0 out of STATS. Seeded like fuzz-smoke so CI
## failures replay locally; repro histories land in artifacts/.
RESIZE_STRESS_FLAGS ?= --fault-seed $(FUZZ_SEED) --monitor-sample 16
resize-stress:
	timeout 300 $(CARGO) run --release --features faults --bin csize -- \
	  resize-stress $(RESIZE_STRESS_FLAGS)

## The AOT artifact flow: release binaries + ablation smoke + schema
## check, collected with rendered figures into $(ARTIFACTS)/. The steps
## run as sequential sub-makes (not prerequisites) because their order is
## data flow, not a dependency DAG: schema-check validates the report
## bench-smoke just wrote, so `make -j artifacts` must not reorder them
## (or bless a stale report).
artifacts:
	$(MAKE) build
	$(MAKE) bench-smoke
	$(MAKE) schema-check
	mkdir -p $(ARTIFACTS)
	cp BENCH_ablation.json $(ARTIFACTS)/
	cp target/release/csize $(ARTIFACTS)/
	cp target/release/examples/kv_server $(ARTIFACTS)/
	$(PYTHON) scripts/make_figures.py BENCH_ablation.json $(ARTIFACTS)
	@echo "--- artifacts ---" && ls -l $(ARTIFACTS)

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS) BENCH_ablation.json
