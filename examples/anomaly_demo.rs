//! Reproduces the paper's Figure 1 and Figure 2 anomalies (Section 1) on a
//! Java-style "naive" size implementation, and shows the methodology fixing
//! both.
//!
//! * Figure 1: a thread sees `contains(k) == true` and then `size() == 0` —
//!   impossible in any sequential execution over the same history.
//! * Figure 2: `size()` returns a **negative** number, because the racing
//!   delete's decrement lands before the insert's (delayed) increment.
//!
//! ```bash
//! cargo run --release --example anomaly_demo [--trials N] [--rounds N]
//! ```

use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies};
use concurrent_size::cli::Args;
use concurrent_size::size::{LinearizableSize, NaiveSize, SizeOpts, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let trials = args.get_usize("trials", 2_000);
    let rounds = args.get_usize("rounds", 500);

    // The naive policy updates its counter *after* the structure update —
    // exactly Java's ConcurrentSkipListMap scheme the paper dissects. The
    // insert-side window stands in for the preemption the paper's
    // 64-thread scheduler provides for free.
    let mut naive_policy = NaiveSize::new(MAX_THREADS, SizeOpts::default());
    naive_policy.set_insert_window(Duration::from_micros(80));
    let naive: Arc<SkipListSet<NaiveSize>> = Arc::new(SkipListSet::with_policy(naive_policy));
    let lin: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));

    println!("== Figure 1: contains(k)=true followed by size()=0 ==");
    let naive1 = fig1_anomalies(naive.as_ref(), trials);
    let lin1 = fig1_anomalies(lin.as_ref(), trials);
    println!("  naive size        : {naive1}/{trials} anomalous trials");
    println!("  linearizable size : {lin1}/{trials} anomalous trials");

    println!("== Figure 2: negative size ==");
    let naive2 = fig2_anomalies(naive.as_ref(), rounds);
    let lin2 = fig2_anomalies(lin.as_ref(), rounds);
    println!("  naive size        : {naive2}/{rounds} rounds hit a negative size");
    println!("  linearizable size : {lin2}/{rounds} rounds (must be 0)");

    assert_eq!(lin1, 0, "methodology violated Figure 1 linearizability!");
    assert_eq!(lin2, 0, "methodology returned a negative size!");
    println!(
        "\nanomaly_demo OK: methodology clean; naive anomalies observed: {}",
        naive1 + naive2
    );
}
