//! Thin CLI shim over [`concurrent_size::server`] — the reactor-based TCP
//! set server with exact, bounded-staleness, and estimated SIZE endpoints
//! plus size-driven admission control (the "reliable size in a real
//! system" scenario the paper's introduction motivates).
//!
//! All the machinery lives in the library (`rust/src/server/`): the
//! acceptor handing sockets to `--reactors` nonblocking reactor shards
//! (each multiplexing its own connection table on one thread, batching
//! pipelined commands per dispatch), the bounded handler pool executing
//! store ops, the watermark admission gate shedding `PUT`s with
//! `ERR OVERLOAD`, and the `STATS` telemetry line.
//! This file only parses flags, builds the store, and — without
//! `--listen` — runs a self-test that drives the server over real
//! sockets: protocol checks (including the dictionary GET/PUT-value and
//! SCAN/COUNT range endpoints), a client swarm with a scan-mixed
//! pipelined phase, a concurrent-connection
//! burst far past the old thread-slot panic threshold, and STATS/daemon
//! assertions derived from the *configured* `--refresh-ms` (a slow CI
//! machine changes the timing, not the contract).
//!
//! ```bash
//! cargo run --release --example kv_server               # self-test mode
//! cargo run --release --example kv_server -- --listen 127.0.0.1:7171 \
//!     [--policy linearizable|handshake|optimistic|...] [--workers N] \
//!     [--reactors auto|N] [--pipeline-depth N] \
//!     [--store-shards auto|N] [--key-dist uniform|zipf:0.99] \
//!     [--refresh-ms 5] [--size-shards auto] [--reactor sleep|spin] \
//!     [--admission-high N [--admission-low N]] \
//!     [--shard-admission-high N [--shard-admission-low N]] [--max-conns N] \
//!     [--request-timeout-ms MS] [--conn-idle-ms MS] [--monitor-sample N] \
//!     [--fault-seed SEED]   # needs --features faults
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use concurrent_size::bench_util;
use concurrent_size::cli::{Args, PolicyKind};
use concurrent_size::harness;
use concurrent_size::server::{BlockingClient, DEFAULT_RECENT_MS, parse_stats, Server, ServerConfig};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::shardstore::make_shard_store;
use concurrent_size::size::{detect_shards, SizeOpts};
use concurrent_size::thread_id;
use concurrent_size::workload::{KeyDist, UPDATE_HEAVY};

type Store = Arc<dyn ConcurrentSet>;

fn usage() {
    println!(
        "kv_server — concurrent-size TCP set server (reactor + admission control)

USAGE:
  kv_server [--listen ADDR] [--policy P] [--workers N] [--max-conns N]
            [--reactors auto|N] [--pipeline-depth N]
            [--store-shards auto|N] [--key-dist uniform|zipf:THETA]
            [--refresh-ms MS] [--size-shards auto|N] [--reactor sleep|spin]
            [--admission-high N [--admission-low N]]
            [--shard-admission-high N [--shard-admission-low N]]
            [--request-timeout-ms MS] [--conn-idle-ms MS]
            [--monitor-sample N] [--fault-seed SEED]

FLAGS:
  --listen ADDR       serve on ADDR (port 0 = ephemeral; the real address is
                      printed); without it the binary runs its self-test
  --policy P          size policy: baseline|linearizable|naive|lock|handshake|
                      optimistic (default linearizable)
  --workers N         handler pool size (default 16, clamped to half the
                      thread-slot capacity; reactor threads stay fixed no
                      matter how many connections are live)
  --reactors R        reactor shards: an acceptor thread hands each socket
                      to the least-loaded shard, and every shard runs its
                      own connection table and sweep loop ('auto' =
                      machine-detected; default 1 = the single-reactor
                      server, bit-identical to before)
  --pipeline-depth N  commands batched into one handler dispatch per
                      connection when clients pipeline (default 32, min 1;
                      replies come back coalesced into one write)
  --max-conns N       live-connection ceiling (default 4096); excess clients
                      get 'ERR server full'
  --refresh-ms MS     background SizeRefresher period in milliseconds: keeps
                      the published size warm so SIZE~ reads are passive
                      (default: off when serving, 5 in self-test mode)
  --size-shards S     stripe count of the sharded counter mirror behind SIZE?
                      and admission control ('auto' = machine-detected,
                      0 = disabled; default auto)
  --reactor M         reactor idle mode: sleep (default, ~0 idle CPU) | spin
                      (busy-poll, lowest latency); builds with
                      --features net-epoll prefer an epoll readiness
                      backend and fall back to polled mode when absent
  --store-shards S    partition the key space over S independent store
                      shards behind a cluster-wide size aggregator
                      ('auto' = machine-detected; default 1 = monolithic)
  --key-dist D        key distribution of the self-test swarm: uniform
                      (default) or zipf:THETA with THETA in (0,1)
                      (0.99 = YCSB's hot-keys skew)
  --admission-high N  shed PUTs with ERR OVERLOAD once the size estimate
                      reaches N (admission control off unless given)
  --admission-low N   readmit once the estimate drains to N (default: high/2;
                      the gap is the hysteresis band)
  --shard-admission-high N
                      second admission tier: shed a PUT with
                      'ERR OVERLOAD shard=<i>' once its target shard's
                      estimate reaches N — only the hot shard sheds
  --shard-admission-low N
                      per-shard readmission watermark (default: high/2)
  --request-timeout-ms MS
                      per-request handler deadline (default 30000, 0 = off):
                      past it the client gets ERR TIMEOUT, the connection's
                      pool slot is reclaimed, and the stale reply is dropped
  --conn-idle-ms MS   reap connections with no protocol progress for MS
                      (default off; drip-fed bytes that never complete a
                      line do not count, so slowloris clients are reaped)
  --monitor-sample N  sampled in-server linearizability monitor: every N
                      pool requests, record one event window against a
                      size_exact anchor and check every SIZE in it
                      (default 0 = off; violations show in STATS and dump
                      minimized repros under artifacts/)
  --scan-frac F       fraction of self-test swarm ops issued as SCAN/COUNT
                      range reads (default 0.1; 0 skips the scan phase's
                      range traffic)
  --scan-span W       width of each self-test swarm scan range (default 64)
  --fault-seed SEED   install the seeded chaos fault plane (delays, yields,
                      short writes, handler panics, forced optimistic
                      fallbacks) for the server's lifetime; requires a
                      build with --features faults (warns otherwise)
  --help              this text (exits 0 without binding a socket)

PROTOCOL (one command per line):
  PUT k [v]               -> 1 fresh insert / 0 value overwrite (v defaults
                             to 0); answers ERR OVERLOAD while shedding
                             (ERR OVERLOAD shard=<i> when a shard tier sheds)
  DEL k | HAS k           -> 1 / 0
  GET k                   -> the stored value, or NIL when k is absent
  SCAN lo hi              -> one 'k v' line per live key in [lo, hi] in key
                             order, then 'END n'; a validated double-collect
                             snapshot under linearizable/optimistic policies,
                             per-key justified otherwise; never shed
  COUNT lo hi             -> number of live keys in [lo, hi] (same snapshot
                             contract as SCAN); never shed
  SIZE                    -> exact linearizable count (combining arbiter;
                             two-phase aggregated across store shards)
  SIZE~ [ms]              -> count at most ms (default {DEFAULT_RECENT_MS}) milliseconds stale
  SIZE?                   -> O(shards) bounded-lag estimate (never negative)
  STATS                   -> key=value server + size telemetry, one line
  QUIT                    -> close (no reply)"
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    // --help must exit 0 without binding a socket (CI help-gates on this).
    if args.has_flag("help") {
        usage();
        return;
    }
    let policy = args.get("policy").unwrap_or("linearizable");
    let Some(kind) = PolicyKind::parse(policy) else {
        eprintln!("unknown --policy {policy:?} (--help for the list)");
        std::process::exit(2);
    };
    let config = match ServerConfig::from_args(&args) {
        Ok(config) => config,
        Err(msg) => {
            eprintln!("kv_server: {msg} (--help for usage)");
            std::process::exit(2);
        }
    };
    // Chaos plane: armed for the whole process lifetime (the guard drops
    // at exit). Without the `faults` feature the install is a no-op, so
    // warn instead of silently running a healthy server.
    let _fault_guard = args.get_opt_u64("fault-seed").map(|seed| {
        if concurrent_size::faults::COMPILED {
            println!("fault plane armed: chaos profile, seed {seed:#x}");
        } else {
            eprintln!(
                "warning: --fault-seed ignored — rebuild with --features faults to arm the plane"
            );
        }
        concurrent_size::faults::install(concurrent_size::faults::FaultPlane::chaos(seed))
    });
    let dist_spelling = args.get("key-dist").unwrap_or("uniform");
    let Some(key_dist) = KeyDist::parse(dist_spelling) else {
        eprintln!(
            "unknown --key-dist {dist_spelling:?} (use uniform|zipf:<theta>, theta in (0,1))"
        );
        std::process::exit(2);
    };
    let opts = SizeOpts::default().with_shards(args.size_shards(detect_shards()));
    let store_shards = args.store_shards(1);
    let store: Store = if store_shards > 1 {
        println!("sharded store: {store_shards} shards behind one size aggregator");
        Arc::from(
            make_shard_store(kind, store_shards, 1 << 16, opts).expect("shard store factory"),
        )
    } else {
        Arc::from(
            bench_util::make_set_opts("hashtable", kind, 1 << 16, opts)
                .expect("hashtable factory"),
        )
    };
    let serving = args.get("listen").is_some();
    // Self-test mode exercises the daemon path by default; a served store
    // only runs one when asked.
    let refresh_ms = args.get_f64("refresh-ms", if serving { 0.0 } else { 5.0 });
    if refresh_ms > 0.0 {
        let period = Duration::from_secs_f64(refresh_ms / 1e3);
        if store.set_refresh_period(Some(period)) {
            println!("size refresher running every {period:?}");
        }
    }
    let scan_frac = args.get_f64("scan-frac", 0.1);
    let scan_span = args.get_u64("scan-span", 64);
    match args.get("listen") {
        Some(addr) => {
            let server = Server::bind(addr, store, config).expect("bind");
            println!(
                "kv_server listening on {} ({} reactor shards, {} handler threads; \
                 PUT/DEL/HAS/GET/SCAN/COUNT/SIZE/SIZE~/SIZE?/STATS/QUIT)",
                server.local_addr(),
                server.reactor_count(),
                server.handler_threads(),
            );
            server.wait();
        }
        None => self_test(store, config, refresh_ms, key_dist, scan_frac, scan_span),
    }
}

/// Self-test: boot the real server on an ephemeral port and drive it over
/// sockets — protocol checks from concurrent clients, a swarm, a burst of
/// connections far past the old per-connection thread-slot limit, and
/// STATS under the running refresher. Staleness bounds are derived from
/// the configured `--refresh-ms` (not hard-coded) so slow CI machines
/// shift timing without breaking the assertions.
fn self_test(
    store: Store,
    config: ServerConfig,
    refresh_ms: f64,
    key_dist: KeyDist,
    scan_frac: f64,
    scan_span: u64,
) {
    let server = Server::bind("127.0.0.1:0", store.clone(), config).expect("bind");
    let addr = server.local_addr();
    // A bound the daemon can beat comfortably: two periods (one period
    // would race the publication instant itself), floored at the protocol
    // default when no daemon runs.
    let recent_ms = if refresh_ms > 0.0 {
        ((2.0 * refresh_ms).ceil() as u64).max(1)
    } else {
        DEFAULT_RECENT_MS
    };

    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = BlockingClient::connect(addr);
                for k in (c * 1000)..(c * 1000 + 250) {
                    assert_eq!(client.cmd(&format!("PUT {k}")), "1");
                }
                for k in (c * 1000)..(c * 1000 + 50) {
                    assert_eq!(client.cmd(&format!("DEL {k}")), "1");
                }
                // Dictionary endpoints: values round-trip, a second PUT
                // is an overwrite (reply 0), and absence answers NIL.
                let vk = c * 1000 + 300;
                assert_eq!(client.cmd(&format!("PUT {vk} 77")), "1");
                assert_eq!(client.cmd(&format!("GET {vk}")), "77");
                assert_eq!(client.cmd(&format!("PUT {vk} 78")), "0", "overwrite");
                assert_eq!(client.cmd(&format!("GET {vk}")), "78");
                assert_eq!(client.cmd(&format!("GET {}", c * 1000 + 999)), "NIL");
                // Range endpoints over this client's private key block:
                // the 200 surviving PUTs, in key order, all value 0.
                let (lo, hi) = (c * 1000 + 50, c * 1000 + 249);
                let pairs = client.scan(lo, hi).expect("SCAN reply");
                assert_eq!(pairs.len(), 200, "scan [{lo}, {hi}]");
                assert!(
                    pairs.windows(2).all(|w| w[0].0 < w[1].0),
                    "scan is key-ordered"
                );
                assert!(pairs.iter().all(|&(k, v)| (lo..=hi).contains(&k) && v == 0));
                assert_eq!(client.cmd(&format!("COUNT {lo} {hi}")), "200");
                assert_eq!(client.cmd(&format!("SCAN {hi} {lo}")), "END 0");
                assert!(
                    client.cmd("SCAN 1").starts_with("ERR"),
                    "SCAN without a range must be rejected"
                );
                // A size-less policy (--policy baseline) answers ERR here.
                let reply = client.cmd("SIZE");
                if !reply.starts_with("ERR") {
                    let size: i64 = reply.parse().expect("numeric SIZE reply");
                    assert!((0..=1000).contains(&size), "impossible size {size}");
                }
                // Bounded-staleness reads must stay in range under the
                // bound derived from the configured refresh period — and
                // so must the sharded estimate, when a mirror exists.
                for cmd in ["SIZE~".to_string(), format!("SIZE~ {recent_ms}"), "SIZE?".into()] {
                    let reply = client.cmd(&cmd);
                    if !reply.starts_with("ERR") {
                        let size: i64 = reply.parse().expect("numeric size reply");
                        assert!((0..=1000).contains(&size), "impossible {cmd} -> {size}");
                    }
                }
                assert!(
                    client.cmd("SIZE~ bogus").starts_with("ERR"),
                    "malformed staleness must be rejected"
                );
                assert!(
                    client.cmd("GARBAGE").starts_with("ERR"),
                    "junk must get ERR"
                );
                // Key 999 is in nobody's range: proves the connection
                // survives bad commands without racing other clients.
                assert_eq!(
                    client.cmd("HAS 999"),
                    "0",
                    "conn must survive a bad command"
                );
            })
        })
        .collect();
    for c in clients {
        c.join().expect("self-test client failed");
    }

    // Burst: hold far more connections open AT THE SAME TIME than there
    // are thread-id slots (the old thread-per-connection server panicked
    // past `capacity()`; the old pool held excess clients hostage behind
    // `workers` live ones). The reactor must hold them all concurrently
    // while the pool stays at `handler_threads() <= capacity()/2`.
    let burst = (thread_id::capacity() * 4).max(256);
    let mut streams: Vec<BlockingClient> =
        (0..burst).map(|_| BlockingClient::connect(addr)).collect();
    for (i, client) in streams.iter_mut().enumerate() {
        client.send(format!("HAS {}", i % 7));
    }
    for client in &mut streams {
        let reply = client.recv().expect("burst reply");
        assert!(reply == "0" || reply == "1", "burst reply {reply:?}");
    }
    // Every burst reply arrived and nothing QUIT yet, so all burst
    // connections are provably open — and accepted — right now.
    let live = server.stats().live_conns;
    assert!(
        live >= burst,
        "reactor holds {live} connections, wanted >= {burst}"
    );
    assert!(server.handler_threads() <= thread_id::capacity() / 2);
    drop(streams);

    // Swarm load over the server path (clients >> thread slots is fine:
    // swarm clients hold sockets, not slots), first lock-step, then
    // pipelined — 16 commands per write exercises batch dispatch and
    // reply coalescing end to end.
    let base = harness::SwarmConfig {
        key_dist,
        ..harness::SwarmConfig::new(8, 500, UPDATE_HEAVY, 4096, 0xBEEF)
    };
    let (mut swarm_ops, mut swarm_rate) = (0u64, 0.0f64);
    for (label, swarm_config) in [
        ("lock-step", base),
        ("pipelined", base.pipelined(16)),
        // Multi-line SCAN replies interleaved with single-line ones
        // through the same pipelined batches and coalesced writes.
        (
            "pipelined+scans",
            base.pipelined(16).with_scans(scan_frac, scan_span),
        ),
    ] {
        let swarm =
            harness::client_swarm(addr, swarm_config).expect("swarm against self-test server");
        swarm_ops += swarm.ops;
        swarm_rate = swarm.throughput();
        assert_eq!(
            swarm.ops,
            8 * 500,
            "every {label} swarm command must get a reply"
        );
        if config.admission.is_none() && config.shard_admission.is_none() {
            assert_eq!(swarm.overloads, 0, "no admission gate configured");
        }
        // Size probes answer ERR under a size-less policy or a disabled
        // mirror; only a fully capable store must be error-free.
        if store.size().is_some() && store.size_estimate().is_some() {
            assert_eq!(
                swarm.errors,
                0,
                "{label} swarm must not see protocol errors"
            );
        }
    }

    // STATS must parse as key=value integers while the refresher daemon
    // runs; with a daemon configured, wait (bounded by periods derived
    // from --refresh-ms, not wall-clock guesses) until it has driven
    // rounds.
    let mut probe = BlockingClient::connect(addr);
    let stats = parse_stats(&probe.cmd("STATS")).expect("STATS must parse");
    assert!(stats.contains_key("conns") && stats.contains_key("daemon_rounds"));
    if refresh_ms > 0.0 && store.size().is_some() {
        let period = Duration::from_secs_f64(refresh_ms / 1e3);
        let deadline = Instant::now() + (period * 400).max(Duration::from_secs(2));
        loop {
            let stats = parse_stats(&probe.cmd("STATS")).expect("STATS must parse");
            if stats["daemon_rounds"] > 0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "refresher drove no rounds within the derived deadline"
            );
            std::thread::sleep(period);
        }
    }

    // Ground truth at quiescence, as before.
    // Census the whole touched key space: protocol clients use 0..3250,
    // the swarm 0..4096.
    match store.size() {
        Some(s) => {
            let live = (0..4096u64).filter(|&k| store.contains(k)).count() as i64;
            assert_eq!(s, live, "exact size disagrees with a census");
        }
        None => {
            // The swarm perturbed the key space, so only sanity holds
            // for a size-less store: the census must run and be nonempty.
            let live = (0..4096u64).filter(|&k| store.contains(k)).count();
            assert!(live > 0, "census found an empty store after the run");
        }
    }
    // The sharded mirror must agree exactly at quiescence.
    if let Some(estimate) = store.size_estimate() {
        assert_eq!(estimate, store.size().unwrap_or(estimate), "SIZE? drifted");
    }
    println!(
        "kv_server self-test OK: {burst} concurrently-open connections on \
         {} reactor shards / {} handler threads, swarm {swarm_ops} ops \
         (pipelined phase {swarm_rate:.0} ops/s), final SIZE = {:?}, \
         SIZE? = {:?}, stats = {:?}",
        server.reactor_count(),
        server.handler_threads(),
        store.size(),
        store.size_estimate(),
        server.stats(),
    );
}
