//! A small TCP set server with exact and bounded-staleness SIZE
//! endpoints — the "reliable size in a real system" scenario the paper's
//! introduction motivates (monitoring, admission control,
//! dynamic-language runtimes).
//!
//! Protocol (one command per line): `PUT k` | `DEL k` | `HAS k` | `SIZE`
//! | `SIZE~ [ms]` | `SIZE?` | `QUIT`. Responses: `1`/`0` for ops, the
//! exact count for `SIZE` (served through the store's combining arbiter,
//! so concurrent SIZE clients share one collect), a possibly-stale count
//! for `SIZE~` (wait-free published read, at most `ms` — default 50 —
//! milliseconds old; with `--refresh-ms` a background `SizeRefresher`
//! keeps the publication warm so these reads are passive), a bounded-lag
//! O(shards) estimate for `SIZE?` (the sharded counter mirror,
//! `--size-shards`), and `ERR ...` for malformed input or a store whose
//! policy cannot serve the request. Run with `--help` for the full flag
//! list.
//!
//! Connections are served by a **bounded worker pool** (never more than
//! `thread_id::capacity()` handler threads): the per-thread size metadata
//! has a fixed number of slots, so the old thread-per-connection design
//! panicked in `acquire_slot` on the 65th live connection. Workers pull
//! accepted sockets from a backlog channel and serve one connection at a
//! time; excess clients queue instead of crashing the server.
//!
//! ```bash
//! cargo run --release --example kv_server               # self-test mode
//! cargo run --release --example kv_server -- --listen 127.0.0.1:7171 \
//!     [--policy linearizable|handshake|optimistic|...] [--workers N] \
//!     [--refresh-ms 5] [--size-shards auto]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{Receiver, sync_channel};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use concurrent_size::bench_util;
use concurrent_size::cli::{Args, PolicyKind};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{detect_shards, SizeOpts};
use concurrent_size::thread_id;

type Store = Arc<dyn ConcurrentSet>;

/// Accepted connections waiting for a worker (beyond this, accept blocks).
const BACKLOG: usize = 1024;

/// Default staleness bound for `SIZE~` when the client names none.
const DEFAULT_RECENT_MS: u64 = 50;

fn handle(store: &dyn ConcurrentSet, stream: TcpStream) {
    let mut out = match stream.try_clone() {
        Ok(out) => out,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => return,
        };
        let mut parts = line.split_whitespace();
        let reply = match (parts.next(), parts.next()) {
            (Some("PUT"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.insert(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            (Some("DEL"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.delete(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            (Some("HAS"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.contains(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            // A store under a size-less policy answers gracefully instead
            // of panicking the handler. Exact SIZEs go through the
            // combining arbiter: concurrent SIZE clients share one
            // underlying collect instead of serializing N of them.
            (Some("SIZE"), _) => match store.size_exact() {
                Some(v) => v.value.to_string(),
                None => "ERR size unsupported by this policy".into(),
            },
            // Bounded-staleness size: wait-free while a recent-enough
            // published result exists.
            (Some("SIZE~"), ms) => {
                match ms.map_or(Ok(DEFAULT_RECENT_MS), str::parse::<u64>) {
                    Ok(ms) => match store.size_recent(Duration::from_millis(ms)) {
                        Some(v) => v.value.to_string(),
                        None => "ERR size unsupported by this policy".into(),
                    },
                    Err(_) => "ERR bad staleness".into(),
                }
            }
            // Bounded-lag estimate from the sharded counter mirror: the
            // cheapest probe the store offers (O(shards), no arbiter).
            (Some("SIZE?"), _) => match store.size_estimate() {
                Some(v) => v.to_string(),
                None => "ERR estimate unavailable (no sharded mirror)".into(),
            },
            (Some("QUIT"), _) => return,
            _ => "ERR unknown command".into(),
        };
        if writeln!(out, "{reply}").is_err() {
            return;
        }
    }
}

/// Cap the pool so handler threads (plus the accept thread, the main
/// thread, and a little slack for test clients) always fit in the
/// per-thread metadata slots.
fn clamp_workers(requested: usize) -> usize {
    requested.clamp(1, thread_id::capacity() / 2)
}

/// Spawn `workers` handler threads draining `rx`; returns their handles.
fn spawn_pool(
    store: &Store,
    rx: Receiver<TcpStream>,
    workers: usize,
) -> Vec<std::thread::JoinHandle<()>> {
    let rx = Arc::new(Mutex::new(rx));
    (0..workers)
        .map(|_| {
            let store = store.clone();
            let rx = rx.clone();
            std::thread::spawn(move || loop {
                // Hold the lock only to dequeue, not while serving.
                let stream = match rx.lock().unwrap().recv() {
                    Ok(s) => s,
                    Err(_) => return, // acceptor gone: drain and exit
                };
                handle(store.as_ref(), stream);
            })
        })
        .collect()
}

/// Accept loop feeding the pool. Exits when the listener errors out.
fn accept_into_pool(listener: TcpListener, store: Store, workers: usize) {
    let (tx, rx) = sync_channel::<TcpStream>(BACKLOG);
    let pool = spawn_pool(&store, rx, workers);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => {
                // Transient accept failures (ECONNABORTED, EMFILE, ...)
                // must not take the whole server down.
                eprintln!("kv_server: accept failed: {e}");
                continue;
            }
        }
    }
    drop(tx);
    for w in pool {
        let _ = w.join();
    }
}

fn serve(addr: &str, store: Store, workers: usize) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!(
        "kv_server listening on {addr} (PUT/DEL/HAS/SIZE/QUIT; {workers} workers)"
    );
    accept_into_pool(listener, store, workers);
    Ok(())
}

/// Self-test: spin up the server on an ephemeral port, drive it with
/// concurrent clients plus a connection burst beyond the thread-slot
/// capacity, and check the SIZE endpoint against ground truth.
fn self_test(store: Store, workers: usize) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    {
        let store = store.clone();
        std::thread::spawn(move || accept_into_pool(listener, store, workers));
    }

    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let mut out = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                let mut send = |cmd: String, line: &mut String| {
                    writeln!(out, "{cmd}").unwrap();
                    line.clear();
                    reader.read_line(line).unwrap();
                    line.trim().to_string()
                };
                for k in (c * 1000)..(c * 1000 + 250) {
                    assert_eq!(send(format!("PUT {k}"), &mut line), "1");
                }
                for k in (c * 1000)..(c * 1000 + 50) {
                    assert_eq!(send(format!("DEL {k}"), &mut line), "1");
                }
                // A size-less policy (--policy baseline) answers ERR here.
                let reply = send("SIZE".into(), &mut line);
                if !reply.starts_with("ERR") {
                    let size: i64 = reply.parse().expect("numeric SIZE reply");
                    assert!((0..=1000).contains(&size), "impossible size {size}");
                }
                // Bounded-staleness reads must stay in the same range,
                // with or without an explicit bound — and so must the
                // sharded estimate, when the store carries a mirror.
                for cmd in ["SIZE~", "SIZE~ 5", "SIZE?"] {
                    let reply = send(cmd.into(), &mut line);
                    if !reply.starts_with("ERR") {
                        let size: i64 = reply.parse().expect("numeric size reply");
                        assert!((0..=1000).contains(&size), "impossible {cmd} -> {size}");
                    }
                }
                assert!(
                    send("SIZE~ bogus".into(), &mut line).starts_with("ERR"),
                    "malformed staleness must be rejected"
                );
                send("QUIT".into(), &mut line)
            })
        })
        .collect();
    for c in clients {
        c.join().expect("self-test client failed");
    }

    // Burst: more connections than thread_id::capacity(), all open AT
    // THE SAME TIME. The old thread-per-connection server panicked in
    // `acquire_slot` as soon as the live-connection count crossed the
    // slot capacity; the pool serves `workers` of them and queues the
    // rest. (Opening them one at a time, as this test once did, never
    // exercised that claim.)
    let burst = thread_id::capacity() + 16;
    let streams: Vec<TcpStream> = (0..burst)
        .map(|_| TcpStream::connect(addr).expect("burst connect"))
        .collect();
    // Every connection is now open concurrently; drain them in accept
    // order (a queued connection is only served once an earlier QUIT
    // frees its worker).
    for (i, stream) in streams.into_iter().enumerate() {
        let mut out = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        writeln!(out, "HAS {}", i % 7).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.trim() == "0" || line.trim() == "1", "burst reply {line:?}");
        writeln!(out, "QUIT").unwrap();
    }

    // With a size-less policy (--policy baseline) fall back to a census.
    match store.size() {
        Some(s) => assert_eq!(s, 4 * 200),
        None => {
            let live = (0..4000u64).filter(|&k| store.contains(k)).count();
            assert_eq!(live, 4 * 200);
        }
    }
    // The sharded mirror must agree exactly at quiescence.
    if let Some(estimate) = store.size_estimate() {
        assert_eq!(estimate, 4 * 200, "quiescent SIZE? estimate drifted");
    }
    println!(
        "kv_server self-test OK: survived {burst} concurrently-open connections, \
         final SIZE = {:?}, SIZE? = {:?}, arbiter stats = {:?}",
        store.size(),
        store.size_estimate(),
        store.size_stats(),
    );
}

fn usage() {
    println!(
        "kv_server — concurrent-size TCP set server

USAGE:
  kv_server [--listen ADDR] [--policy P] [--workers N]
            [--refresh-ms MS] [--size-shards auto|N]

FLAGS:
  --listen ADDR     serve on ADDR; without it the binary runs its self-test
  --policy P        size policy: baseline|linearizable|naive|lock|handshake|
                    optimistic (default linearizable)
  --workers N       handler pool size (default 16, clamped to half the
                    thread-slot capacity)
  --refresh-ms MS   background SizeRefresher period in milliseconds: keeps
                    the published size warm so SIZE~ reads are passive
                    (default: off when serving, 5 in self-test mode)
  --size-shards S   stripe count of the sharded counter mirror behind SIZE?
                    ('auto' = machine-detected, 0 = disabled; default auto)
  --help            this text

PROTOCOL (one command per line):
  PUT k | DEL k | HAS k   -> 1 / 0
  SIZE                    -> exact linearizable count (combining arbiter)
  SIZE~ [ms]              -> count at most ms (default {DEFAULT_RECENT_MS}) milliseconds stale
  SIZE?                   -> O(shards) bounded-lag estimate
  QUIT"
    );
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if args.has_flag("help") {
        usage();
        return;
    }
    let policy = args.get("policy").unwrap_or("linearizable");
    let Some(kind) = PolicyKind::parse(policy) else {
        eprintln!("unknown --policy {policy:?} (--help for the list)");
        std::process::exit(2);
    };
    let opts = SizeOpts::default().with_shards(args.size_shards(detect_shards()));
    let store: Store = Arc::from(
        bench_util::make_set_opts("hashtable", kind, 1 << 16, opts).expect("hashtable factory"),
    );
    let workers = clamp_workers(args.get_usize("workers", 16));
    let serving = args.get("listen").is_some();
    // Self-test mode exercises the daemon path by default; a served store
    // only runs one when asked.
    let refresh_ms = args.get_f64("refresh-ms", if serving { 0.0 } else { 5.0 });
    if refresh_ms > 0.0 {
        let period = Duration::from_secs_f64(refresh_ms / 1e3);
        if store.set_refresh_period(Some(period)) {
            println!("size refresher running every {period:?}");
        }
    }
    match args.get("listen") {
        Some(addr) => serve(&addr.to_string(), store, workers).expect("serve"),
        None => self_test(store, workers),
    }
}
