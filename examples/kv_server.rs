//! A small TCP set server with an exact SIZE endpoint — the "reliable
//! size in a real system" scenario the paper's introduction motivates
//! (monitoring, admission control, dynamic-language runtimes).
//!
//! Protocol (one command per line): `PUT k` | `DEL k` | `HAS k` | `SIZE` |
//! `QUIT`. Responses: `1`/`0` for ops, the exact count for `SIZE`.
//!
//! ```bash
//! cargo run --release --example kv_server               # self-test mode
//! cargo run --release --example kv_server -- --listen 127.0.0.1:7171
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use concurrent_size::cli::Args;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::MAX_THREADS;

type Store = Arc<HashTableSet<LinearizableSize>>;

fn handle(store: Store, stream: TcpStream) {
    let mut out = stream.try_clone().expect("clone stream");
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => return,
        };
        let mut parts = line.split_whitespace();
        let reply = match (parts.next(), parts.next()) {
            (Some("PUT"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.insert(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            (Some("DEL"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.delete(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            (Some("HAS"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => (store.contains(k) as i64).to_string(),
                Err(_) => "ERR bad key".into(),
            },
            (Some("SIZE"), _) => store.size().unwrap().to_string(),
            (Some("QUIT"), _) => return,
            _ => "ERR unknown command".into(),
        };
        if writeln!(out, "{reply}").is_err() {
            return;
        }
    }
}

fn serve(addr: &str, store: Store) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("kv_server listening on {addr} (PUT/DEL/HAS/SIZE/QUIT)");
    for stream in listener.incoming() {
        let store = store.clone();
        std::thread::spawn(move || handle(store, stream.expect("accept")));
    }
    Ok(())
}

/// Self-test: spin up the server on an ephemeral port, drive it with
/// concurrent clients, and check the SIZE endpoint against ground truth.
fn self_test(store: Store) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    {
        let store = store.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let store = store.clone();
                std::thread::spawn(move || handle(store, stream.expect("accept")));
            }
        });
    }

    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).expect("connect");
                let mut out = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                let mut send = |cmd: String, line: &mut String| {
                    writeln!(out, "{cmd}").unwrap();
                    line.clear();
                    reader.read_line(line).unwrap();
                    line.trim().to_string()
                };
                for k in (c * 1000)..(c * 1000 + 250) {
                    assert_eq!(send(format!("PUT {k}"), &mut line), "1");
                }
                for k in (c * 1000)..(c * 1000 + 50) {
                    assert_eq!(send(format!("DEL {k}"), &mut line), "1");
                }
                let size: i64 = send("SIZE".into(), &mut line).parse().unwrap();
                assert!((0..=1000).contains(&size), "impossible size {size}");
                send("QUIT".into(), &mut line)
            })
        })
        .collect();
    for c in clients {
        let _ = c.join();
    }

    assert_eq!(store.size(), Some(4 * 200));
    println!("kv_server self-test OK: final SIZE = {:?}", store.size());
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let store: Store = Arc::new(HashTableSet::new(MAX_THREADS, 1 << 16));
    match args.get("listen") {
        Some(addr) => serve(&addr.to_string(), store).expect("serve"),
        None => self_test(store),
    }
}
