//! Quickstart: add a linearizable, wait-free `size()` to a concurrent set.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::MAX_THREADS;

fn main() {
    // A lock-free skip list transformed with the paper's methodology:
    // insert/delete/contains as usual, plus an O(#threads) exact size().
    let set: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));

    // Concurrent writers...
    let writers: Vec<_> = (0..4u64)
        .map(|t| {
            let set = set.clone();
            std::thread::spawn(move || {
                for k in (t * 1000)..(t * 1000 + 500) {
                    set.insert(k);
                }
                for k in (t * 1000)..(t * 1000 + 100) {
                    set.delete(k);
                }
            })
        })
        .collect();

    // ...while a reader keeps asking for the exact size. Every value it
    // sees is a size the set really had at some moment (linearizability) —
    // never negative, never phantom.
    let sizes = {
        let set = set.clone();
        std::thread::spawn(move || {
            let mut observed = Vec::new();
            for _ in 0..1000 {
                let s = set.size().unwrap();
                assert!((0..=2000).contains(&s), "impossible size {s}");
                observed.push(s);
            }
            observed
        })
    };

    for w in writers {
        w.join().unwrap();
    }
    let observed = sizes.join().unwrap();

    println!("final size           : {:?}", set.size());
    println!(
        "concurrent size calls: {} (all linearizable)",
        observed.len()
    );
    println!(
        "observed size range  : {:?}..={:?}",
        observed.iter().min().unwrap(),
        observed.iter().max().unwrap()
    );
    assert_eq!(set.size(), Some(4 * 400));
    println!("quickstart OK");
}
