//! End-to-end driver: all three layers composed on a real workload.
//!
//! 1. **L3 (Rust)** runs a YCSB-style workload over the size-transformed
//!    skip list while an epoch sampler records the size metadata and the
//!    linearizable `size()`.
//! 2. **L2/L1 (AOT JAX + Pallas via PJRT)** reduce the counter samples to
//!    per-epoch sizes (`size_reduce`), scan the update history
//!    (`prefix_scan`) and validate legality (`history_stats`).
//! 3. The linearizable sizes and the Pallas pipeline must agree — exactly
//!    at quiescent epochs and on the final state.
//!
//! ```bash
//! make artifacts && cargo run --release --example size_analytics
//! ```

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;
use std::time::{Duration, Instant};

use concurrent_size::analytics::{analyze, EpochRecorder};
use concurrent_size::cli::Args;
use concurrent_size::history::{self, DeltaLog};
use concurrent_size::metrics::fmt_rate;
use concurrent_size::runtime::Artifacts;
use concurrent_size::size::{LinearizableSize, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::workload::{self, key_range, OpType, UPDATE_HEAVY};
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let initial = args.get_u64("initial", 20_000);
    let secs = args.get_f64("secs", 3.0);
    let epochs = args.get_usize("epochs", 128);
    let workers = args.get_usize("threads", 3);

    println!("[1/4] loading AOT artifacts (PJRT CPU)...");
    let artifacts = match Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            // Stub runtime (no `pjrt` feature) or missing artifacts: skip
            // gracefully rather than panicking at the user.
            eprintln!("size_analytics unavailable: {e}");
            std::process::exit(1);
        }
    };

    println!("[2/4] prefilling SizeSkipList with {initial} keys...");
    let set: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));
    let mix = UPDATE_HEAVY;
    let range = key_range(initial, mix);
    workload::prefill(set.as_ref(), initial, range, 42);

    println!("[3/4] running {workers} workload threads for {secs}s with {epochs} epochs...");
    let stop = Arc::new(AtomicBool::new(false));
    let log = Arc::new(DeltaLog::new());
    // The prefill enters the history as one bulk delta, so the running size
    // is absolute and the never-negative legality check applies end to end.
    log.record_delta(initial as i64);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..workers as u64)
        .map(|t| {
            let set = set.clone();
            let stop = stop.clone();
            let log = log.clone();
            std::thread::spawn(move || {
                let mut stream = workload::OpStream::new(t, mix, range);
                let mut ops = 0u64;
                while !stop.load(SeqCst) {
                    let (op, k) = stream.next();
                    let ok = workload::apply(set.as_ref(), op, k);
                    if ok && log.len() < concurrent_size::runtime::AOT_L {
                        match op {
                            OpType::Insert => log.record_insert(),
                            OpType::Delete => log.record_delete(),
                            OpType::Contains => {}
                        }
                    }
                    ops += 1;
                }
                ops
            })
        })
        .collect();

    let mut rec = EpochRecorder::new();
    let calc = set.policy().calculator().unwrap();
    let dt = Duration::from_secs_f64(secs / epochs as f64);
    for _ in 0..epochs - 1 {
        std::thread::sleep(dt);
        rec.record(calc);
    }
    stop.store(true, SeqCst);
    let total_ops: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    rec.record(calc); // quiescent final epoch
    let elapsed = t0.elapsed();

    println!("[4/4] running the Pallas analytics pipeline...");
    let report = analyze(&artifacts, &rec).expect("epoch analytics failed");

    // History validation: the recorded update deltas must form a legal
    // history, and the Pallas scan must agree with the Rust oracle.
    let mut deltas = log.snapshot();
    deltas.truncate(concurrent_size::runtime::AOT_L); // racing pushes may overshoot
    let (p_running, p_stats) = artifacts.validate_history(&deltas).expect("history pipeline");
    let (r_running, r_stats) = history::validate(&deltas);
    assert_eq!(p_running, r_running, "Pallas scan != Rust oracle");
    assert_eq!(p_stats, r_stats, "Pallas stats != Rust oracle");

    let final_pallas = *report.pallas_sizes.last().unwrap();
    let final_lin = *report.linearizable_sizes.last().unwrap();

    println!("\n================ size_analytics report ================");
    println!("workload ops            : {total_ops} ({} ops/s)",
             fmt_rate(total_ops as f64 / elapsed.as_secs_f64()));
    println!("epochs sampled          : {}", rec.len());
    println!("final size  (pallas)    : {final_pallas}");
    println!("final size  (linearizable size()): {final_lin}");
    println!("epoch skew max |pallas - size()| : {}", report.max_skew());
    println!("history deltas recorded : {}", deltas.len());
    println!(
        "history stats [min,max,final,neg]: {:?}",
        p_stats.as_array()
    );
    println!("history legal (never negative)   : {}", p_stats.is_legal());
    println!("=======================================================");

    assert!(report.final_exact(), "quiescent epoch must match exactly");
    assert!(p_stats.is_legal(), "update history must never go negative");
    // The absolute history telescopes to the final linearizable size —
    // checkable only when the log did not hit the AOT capacity.
    let truncated = deltas.len() >= concurrent_size::runtime::AOT_L;
    if truncated {
        println!("note: history hit AOT_L capacity; prefix checked for legality only");
    } else {
        assert_eq!(
            p_stats.final_size, final_lin,
            "history final size must equal the linearizable size"
        );
    }
    println!("size_analytics OK: all three layers agree.");
}
