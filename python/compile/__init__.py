"""Build-time-only Python package: JAX/Pallas authoring + AOT export.

Never imported at runtime — the Rust coordinator only consumes the HLO
text artifacts produced by ``python -m compile.aot``.
"""

import jax

# The size metadata counters are u64 in the Rust coordinator; analytics run
# on i64, which requires the x64 mode (default jax dtype is 32-bit).
jax.config.update("jax_enable_x64", True)
