"""AOT exporter: lower the Layer-2 graphs to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowered with ``return_tuple=True`` and
unwrapped with ``to_tuple*`` on the Rust side.

Exported artifacts (shapes are the contract with ``rust/src/runtime``):

* ``size_reduce.hlo.txt``   — ``epoch_sizes``:      s64[AOT_E, AOT_T, 2] -> (s64[AOT_E],)
* ``prefix_scan.hlo.txt``   — ``running_sizes``:    s64[AOT_L] -> (s64[AOT_L],)
* ``history_stats.hlo.txt`` — ``validate_history``: s64[AOT_L], s64[] -> (s64[AOT_L], s64[4])

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The AOT shape contract; rust/src/runtime/artifacts.rs mirrors these values.
AOT_E = 256  # epochs per analytics batch
AOT_T = 64  # thread slots (max_threads supported by the coordinator)
AOT_L = 65536  # history log capacity


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to xla_extension-0.5.1-compatible HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all() -> dict:
    """Lower every exported graph; returns {artifact name: hlo text}."""
    s64 = jnp.int64
    counters = jax.ShapeDtypeStruct((AOT_E, AOT_T, 2), s64)
    deltas = jax.ShapeDtypeStruct((AOT_L,), s64)
    vlen = jax.ShapeDtypeStruct((), s64)

    return {
        "size_reduce.hlo.txt": to_hlo_text(
            jax.jit(model.epoch_sizes).lower(counters)
        ),
        "prefix_scan.hlo.txt": to_hlo_text(
            jax.jit(model.running_sizes).lower(deltas)
        ),
        "history_stats.hlo.txt": to_hlo_text(
            jax.jit(model.validate_history).lower(deltas, vlen)
        ),
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, text in lower_all().items():
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")


if __name__ == "__main__":
    main()
