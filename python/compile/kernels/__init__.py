"""Layer-1 Pallas kernels for the Concurrent Size analytics pipeline.

All kernels are authored with TPU-style tiling (BlockSpec expresses the
HBM<->VMEM schedule) but lowered with ``interpret=True`` so the AOT HLO runs
on the PJRT CPU client embedded in the Rust coordinator.
"""

from .history_stats import history_stats  # noqa: F401
from .prefix_scan import prefix_scan  # noqa: F401
from .size_reduce import size_reduce  # noqa: F401
