"""Pallas kernel: streaming statistics over a running-size series.

Consumes the output of :mod:`prefix_scan` and reduces it to the four
quantities the linearizability validator checks (paper Sections 1, 8):

* ``stats[0]`` — minimum running size (must be >= 0 for a legal history;
  the naive counter-after-op scheme of paper Figure 2 drives this negative),
* ``stats[1]`` — maximum running size,
* ``stats[2]`` — final size (cross-checked against a linearizable ``size()``
  taken at quiescence),
* ``stats[3]`` — number of prefix points with a negative size.

Tiling: grid over ``[BLOCK_L]`` tiles with four SMEM accumulator cells;
the accumulators are folded across the sequential grid and emitted once.
VMEM per step is one tile (32 KiB at BLOCK_L = 4096); the kernel is a
single-pass, memory-bound streaming reduction.

``valid_len`` masks out padding, so callers may pad ``running`` to the AOT
shape without corrupting the min/negativity statistics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_L = 4096


def _history_stats_kernel(running_ref, valid_len_ref, stats_ref, acc_ref):
    i = pl.program_id(0)
    blk = running_ref.shape[0]
    dtype = running_ref.dtype
    big = jnp.asarray(jnp.iinfo(dtype).max, dtype)

    @pl.when(i == 0)
    def _init():
        acc_ref[0] = big  # running min
        acc_ref[1] = -big  # running max
        acc_ref[2] = jnp.zeros((), dtype)  # final value
        acc_ref[3] = jnp.zeros((), dtype)  # negative count

    tile = running_ref[...]
    base = i * blk
    idx = base + jax.lax.iota(dtype, blk)
    valid = idx < valid_len_ref[0]
    masked_min = jnp.where(valid, tile, big)
    masked_max = jnp.where(valid, tile, -big)

    acc_ref[0] = jnp.minimum(acc_ref[0], jnp.min(masked_min))
    acc_ref[1] = jnp.maximum(acc_ref[1], jnp.max(masked_max))
    # Final value: last valid element seen so far (padding tiles keep it).
    in_tile = jnp.logical_and(valid_len_ref[0] > base,
                              valid_len_ref[0] <= base + blk)
    last_idx = jnp.clip(valid_len_ref[0] - 1 - base, 0, blk - 1)
    acc_ref[2] = jnp.where(in_tile, tile[last_idx], acc_ref[2])
    # dtype= keeps the count in the input dtype; jnp.sum would otherwise
    # promote int32 to int64 (under x64) and the SMEM store would fail.
    acc_ref[3] = acc_ref[3] + jnp.sum(
        jnp.where(jnp.logical_and(valid, tile < 0), 1, 0).astype(dtype),
        dtype=dtype)

    stats_ref[0] = acc_ref[0]
    stats_ref[1] = acc_ref[1]
    stats_ref[2] = acc_ref[2]
    stats_ref[3] = acc_ref[3]


@functools.partial(jax.jit, static_argnames=("block_l",))
def history_stats(running: jax.Array, valid_len: jax.Array,
                  *, block_l: int = DEFAULT_BLOCK_L) -> jax.Array:
    """[min, max, final, negative-count] over ``running[:valid_len]``.

    Args:
      running: integer array ``[L]`` of running sizes (possibly padded).
      valid_len: scalar count of meaningful prefix elements.

    Returns:
      ``[4]`` stats array, same dtype as ``running``. For ``valid_len == 0``
      min is ``iinfo.max`` and max is ``-iinfo.max`` (empty-fold identities).
    """
    if running.ndim != 1:
        raise ValueError(f"expected [L] running sizes, got {running.shape}")
    l = running.shape[0]
    blk = min(block_l, max(l, 1))
    l_pad = pl.cdiv(l, blk) * blk if l > 0 else blk
    padded = jnp.zeros((l_pad,), running.dtype).at[:l].set(running)
    vlen = jnp.asarray(valid_len, running.dtype).reshape((1,))

    return pl.pallas_call(
        _history_stats_kernel,
        grid=(l_pad // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((4,), running.dtype),
        scratch_shapes=[pltpu.SMEM((4,), running.dtype)],
        interpret=True,
    )(padded, vlen)
