"""Pallas kernel: running data-structure size via block-tiled prefix scan.

The offline linearizability validator (Rust ``history`` module) serializes an
execution's successful updates by their linearization order into a delta log
``deltas[L]`` (+1 per insert, -1 per delete, 0 for no-ops/padding).  The
running size after the i-th linearized update is the inclusive prefix sum
``running[i] = sum_{j<=i} deltas[j]`` — the size a linearizable ``size()``
would observe at that point (paper Section 8.1).  A legal history never goes
negative (paper Figure 2 shows the naive scheme violating exactly this).

Parallel-scan structure:
* Within a block: ``jnp.cumsum`` over the VMEM-resident ``[BLOCK_L]`` tile
  (lowers to a log-depth associative scan on the VPU).
* Across blocks: the TPU grid executes sequentially, so a single SMEM carry
  cell threads the running total from block to block — the classic
  scan-then-propagate decomposition with the propagate phase fused into the
  sequential grid walk.
* VMEM per step: 2 tiles * BLOCK_L * 8 B (= 64 KiB at BLOCK_L = 4096); HBM
  traffic is the roofline minimum 2 * L * 8 B (read log + write scan).

Lowered with ``interpret=True`` for the CPU PJRT runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_L = 4096


def _prefix_scan_kernel(deltas_ref, running_ref, carry_ref):
    """One grid step: scan a [BLOCK_L] tile, threading the carry through SMEM."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[0] = jnp.zeros((), deltas_ref.dtype)

    scanned = jnp.cumsum(deltas_ref[...]) + carry_ref[0]
    running_ref[...] = scanned
    carry_ref[0] = scanned[-1]


@functools.partial(jax.jit, static_argnames=("block_l",))
def prefix_scan(deltas: jax.Array, *, block_l: int = DEFAULT_BLOCK_L) -> jax.Array:
    """Inclusive prefix sum of an operation delta log.

    Args:
      deltas: integer array ``[L]`` of per-operation size deltas.
      block_l: elements per grid step; ``L`` is padded up to a multiple.

    Returns:
      ``[L]`` inclusive running sums, same dtype as ``deltas``.
    """
    if deltas.ndim != 1:
        raise ValueError(f"expected [L] delta log, got {deltas.shape}")
    l = deltas.shape[0]
    blk = min(block_l, max(l, 1))
    l_pad = pl.cdiv(l, blk) * blk if l > 0 else blk
    padded = jnp.zeros((l_pad,), deltas.dtype).at[:l].set(deltas)

    out = pl.pallas_call(
        _prefix_scan_kernel,
        grid=(l_pad // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l_pad,), deltas.dtype),
        scratch_shapes=[pltpu.SMEM((1,), deltas.dtype)],
        interpret=True,
    )(padded)
    return out[:l]
