"""Pure-jnp oracles for the Pallas kernels (the build-time correctness bar).

Every kernel in this package must match its oracle bit-exactly on integer
inputs — asserted by ``python/tests/test_kernels.py`` under hypothesis sweeps
of shapes, dtypes and values.
"""

import jax.numpy as jnp


def ref_size_reduce(counters):
    """[E, T, 2] counters -> [E] sizes; paper Fig. 6 computeSize per epoch."""
    counters = jnp.asarray(counters)
    return jnp.sum(counters[:, :, 0] - counters[:, :, 1], axis=1)


def ref_prefix_scan(deltas):
    """[L] deltas -> [L] inclusive running sums."""
    return jnp.cumsum(jnp.asarray(deltas))


def ref_history_stats(running, valid_len):
    """[min, max, final, neg-count] over running[:valid_len]."""
    running = jnp.asarray(running)
    dtype = running.dtype
    big = jnp.asarray(jnp.iinfo(dtype).max, dtype)
    valid = running[:valid_len]
    if valid_len == 0:
        return jnp.array([big, -big, 0, 0], dtype)
    return jnp.array(
        [jnp.min(valid), jnp.max(valid), valid[-1], jnp.sum(valid < 0)], dtype
    )
