"""Pallas kernel: batched size-from-counters reduction.

The Concurrent Size metadata is one (insertions, deletions) counter pair per
thread (paper Section 5).  The Rust coordinator samples the metadata array
once per analysis epoch, producing a batch ``counters[E, T, 2]`` where

* ``E`` — number of sampled epochs,
* ``T`` — number of registered threads,
* ``counters[e, t, 0]`` — thread ``t``'s insertion counter at epoch ``e``,
* ``counters[e, t, 1]`` — thread ``t``'s deletion counter at epoch ``e``.

The kernel computes per-epoch sizes exactly as ``CountersSnapshot.computeSize``
(paper Fig. 6, lines 102-105): ``size[e] = sum_t ins[e,t] - sum_t del[e,t]``.

TPU tiling notes (BlockSpec = the HBM<->VMEM schedule):
* The grid runs over epoch blocks; each step stages a ``[BLOCK_E, T, 2]`` tile
  into VMEM and emits a ``[BLOCK_E]`` tile of sizes.
* VMEM footprint per step is ``BLOCK_E * T * 2 * 8`` bytes; with the default
  ``BLOCK_E = 32`` and ``T = 64`` that is 32 KiB — far below the ~16 MiB VMEM
  budget, leaving room for double buffering by the Mosaic pipeline.
* The reduction is element-wise + row-sum (VPU work, no MXU); the kernel is
  memory-bound, so the tiling goal is simply full-bandwidth streaming of the
  counter tiles.

Lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 32


def _size_reduce_kernel(counters_ref, sizes_ref):
    """One grid step: reduce a [BLOCK_E, T, 2] counter tile to [BLOCK_E] sizes."""
    tile = counters_ref[...]
    ins = tile[:, :, 0]
    dels = tile[:, :, 1]
    # Keep the accumulator in the input dtype: jnp.sum would otherwise
    # promote int32 to the default int (int64 under x64) and the store
    # into the int32 output ref would fail.
    sizes_ref[...] = jnp.sum(ins - dels, axis=1, dtype=tile.dtype)


@functools.partial(jax.jit, static_argnames=("block_e",))
def size_reduce(counters: jax.Array, *, block_e: int = DEFAULT_BLOCK_E) -> jax.Array:
    """Per-epoch data-structure sizes from per-thread counter snapshots.

    Args:
      counters: integer array ``[E, T, 2]`` (insertion/deletion counters).
      block_e: epochs per grid step; ``E`` is padded up to a multiple of it.

    Returns:
      ``[E]`` array of sizes with the same dtype as ``counters``.
    """
    if counters.ndim != 3 or counters.shape[-1] != 2:
        raise ValueError(f"expected [E, T, 2] counters, got {counters.shape}")
    e, t, _ = counters.shape
    blk = min(block_e, max(e, 1))
    e_pad = pl.cdiv(e, blk) * blk if e > 0 else blk
    padded = jnp.zeros((e_pad, t, 2), counters.dtype).at[:e].set(counters)

    out = pl.pallas_call(
        _size_reduce_kernel,
        grid=(e_pad // blk,),
        in_specs=[pl.BlockSpec((blk, t, 2), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e_pad,), counters.dtype),
        interpret=True,
    )(padded)
    return out[:e]
