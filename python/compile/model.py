"""Layer-2 JAX compute graphs for Concurrent Size analytics.

These are the graphs the Rust coordinator executes through PJRT (after AOT
lowering by :mod:`compile.aot`).  They compose the Layer-1 Pallas kernels:

* :func:`epoch_sizes` / :func:`analyze_epochs` — per-epoch sizes (and deltas
  and extrema) from batched metadata-counter snapshots.  This is the batch
  form of ``CountersSnapshot.computeSize`` (paper Fig. 6).
* :func:`validate_history` — running sizes + legality statistics from a
  linearization-ordered delta log (the offline half of the linearizability
  checker; see paper Sections 1, 8 and Figure 2's negative-size anomaly).

Shapes are static at AOT time; the Rust runtime pads inputs to the exported
shapes and passes the true length as ``valid_len``.
"""

import jax
import jax.numpy as jnp

from .kernels import history_stats, prefix_scan, size_reduce


def epoch_sizes(counters: jax.Array) -> jax.Array:
    """[E, T, 2] metadata-counter snapshots -> [E] data-structure sizes."""
    return size_reduce(counters)


def analyze_epochs(counters: jax.Array):
    """Batch epoch analytics.

    Args:
      counters: ``[E, T, 2]`` integer counter snapshots.

    Returns:
      Tuple of
      * ``sizes [E]`` — size at each epoch,
      * ``deltas [E]`` — size change between consecutive epochs (delta[0] is
        the size of the first epoch, i.e., relative to an empty structure),
      * ``stats [4]`` — [min, max, final, negative-count] over the sizes.
    """
    sizes = size_reduce(counters)
    deltas = jnp.diff(sizes, prepend=sizes.dtype.type(0))
    e = sizes.shape[0]
    stats = history_stats(sizes, jnp.asarray(e, sizes.dtype))
    return sizes, deltas, stats


def running_sizes(deltas: jax.Array) -> jax.Array:
    """[L] linearization-ordered op deltas -> [L] running sizes."""
    return prefix_scan(deltas)


def validate_history(deltas: jax.Array, valid_len: jax.Array):
    """Linearizability-oriented validation of an update history.

    Args:
      deltas: ``[L]`` op deltas (+1 insert, -1 delete, 0 padding), ordered by
        linearization point.
      valid_len: scalar number of meaningful entries.

    Returns:
      Tuple of
      * ``running [L]`` — size after each linearized update,
      * ``stats [4]`` — [min, max, final, negative-count] over the valid
        prefix.  A legal set history has ``min >= 0`` and ``neg-count == 0``.
    """
    running = prefix_scan(deltas)
    stats = history_stats(running, valid_len)
    return running, stats
