"""Minimal stand-in for the `hypothesis` API surface these tests use.

The offline test image does not ship `hypothesis`; installing it is not an
option. This shim covers exactly what the kernel/model tests need —
`@given(**kwargs)` with keyword strategies, `@settings(max_examples=...,
deadline=...)`, `st.integers(lo, hi)` and `st.sampled_from(seq)` — by
drawing `max_examples` seeded pseudo-random cases per test. The real
library is preferred whenever it is importable (see conftest.py); failures
report the case number and drawn arguments for reproduction.
"""

import functools
import inspect
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._hypothesis_lite_max_examples = max_examples
        return fn

    return decorate


def given(**strategy_kwargs):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings is conventionally stacked ABOVE @given, so it tags
            # this wrapper (decorators apply bottom-up); fall back to the
            # inner fn in case it was stacked underneath.
            max_examples = getattr(
                wrapper,
                "_hypothesis_lite_max_examples",
                getattr(fn, "_hypothesis_lite_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            # Derive the base seed from a stable digest of the test name
            # (builtin hash() is salted per process, which would make the
            # reported failing case irreproducible across runs).
            base_seed = zlib.crc32(fn.__qualname__.encode())
            for case in range(max_examples):
                rng = np.random.default_rng(base_seed + case)
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed on case {case} with "
                        f"arguments {drawn!r}: {e}"
                    ) from e

        # Hide the strategy parameters from pytest's fixture resolution:
        # expose only the non-drawn parameters (e.g. `self`).
        remaining = [
            p
            for name, p in inspect.signature(fn).parameters.items()
            if name not in strategy_kwargs
        ]
        wrapper.__signature__ = inspect.Signature(remaining)
        del wrapper.__wrapped__
        return wrapper

    return decorate
