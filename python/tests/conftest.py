import os
import sys

# Make `compile` importable when pytest is launched from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import compile  # noqa: E402,F401  (enables jax x64 as an import side effect)
