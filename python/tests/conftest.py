import os
import sys
import types

# Make `compile` importable when pytest is launched from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import compile  # noqa: E402,F401  (enables jax x64 as an import side effect)

# The offline image has no `hypothesis`; fall back to the local shim that
# covers the API surface these tests use (real hypothesis wins if present).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_lite

    shim = types.ModuleType("hypothesis")
    shim.given = _hypothesis_lite.given
    shim.settings = _hypothesis_lite.settings
    shim.strategies = _hypothesis_lite.strategies
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = _hypothesis_lite.strategies
