"""Negative tests for scripts/check_ablation_schema.py.

The schema gate is itself CI-load-bearing: if it silently accepted a
malformed report, the bench recorder could rot unnoticed. These tests
drive the script as a subprocess (exactly as `make schema-check` does)
against synthesized reports — one known-good, then targeted mutations
that each must be rejected with a pointed message.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.dirname(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)
SCRIPT = os.path.join(REPO_ROOT, "scripts", "check_ablation_schema.py")


def record(**overrides):
    """One fully-populated ablation record; override per test."""
    rec = {
        "scenario": "periodic-size",
        "policy": "linearizable",
        "mix": "update-heavy",
        "size_threads": 1,
        "size_call": "raw",
        "shards": 0,
        "key_dist": "uniform",
        "refresh_us": 0,
        "workload_ops_per_sec": 1000.0,
        "size_ops_per_sec": 10.0,
        "arbiter_rounds": 0,
        "arbiter_adoptions": 0,
        "arbiter_recent_hits": 0,
        "daemon_rounds": 0,
        "daemon_stalls": 0,
        "fallbacks": 0,
        "retry_budget": 0,
        "per_shard_sheds": 0,
        "reactors": 0,
        "pipeline_depth": 0,
        "scan_frac": 0.0,
        "scan_span": 0,
        "initial_buckets": 0,
        "final_buckets": 0,
        "migration_quanta": 0,
        "growth_windows": [],
    }
    rec.update(overrides)
    return rec


def growth_record(**overrides):
    """A resize_scale record shaped like a healthy growth run."""
    defaults = {
        "scenario": "resize_scale",
        "initial_buckets": 64,
        "final_buckets": 2048,
        "migration_quanta": 512,
        "growth_windows": [900.0, 700.0, 850.0, 780.0, 910.0],
    }
    defaults.update(overrides)
    return record(**defaults)


def report(records):
    return {
        "bench": "ablation_policies",
        "structure": "hashtable",
        "config": {
            "initial": 1024,
            "secs": 1.0,
            "runs": 1,
            "warmup": 0,
            "workload_threads": 4,
            "size_heavy_threads": 4,
            "staleness_ms": 1,
            "seed": 42,
        },
        "results": records,
    }


def run_check(tmp_path, payload):
    path = tmp_path / "BENCH_ablation.json"
    path.write_text(json.dumps(payload), encoding="utf-8")
    return subprocess.run(
        [sys.executable, SCRIPT, str(path)],
        capture_output=True,
        text=True,
        check=False,
    )


class TestSchemaCheck:
    def test_valid_report_passes(self, tmp_path):
        res = run_check(tmp_path, report([record(), growth_record()]))
        assert res.returncode == 0, res.stderr
        assert "OK" in res.stdout

    def test_growth_gate_prints_margin(self, tmp_path):
        res = run_check(tmp_path, report([growth_record()]))
        assert res.returncode == 0, res.stderr
        assert "resize_scale[64 -> 2048 buckets]" in res.stdout
        assert "margin" in res.stdout

    def test_missing_growth_keys_rejected(self, tmp_path):
        rec = record()
        del rec["growth_windows"]
        res = run_check(tmp_path, report([rec]))
        assert res.returncode == 1
        assert "growth_windows" in res.stderr

    def test_unknown_scenario_rejected(self, tmp_path):
        res = run_check(tmp_path, report([record(scenario="mystery")]))
        assert res.returncode == 1
        assert "unknown scenario" in res.stderr

    def test_collapse_window_rejected(self, tmp_path):
        # One window at 10% of the median = the stop-the-world signature
        # the gate exists to catch.
        rec = growth_record(
            growth_windows=[900.0, 880.0, 90.0, 910.0, 905.0]
        )
        res = run_check(tmp_path, report([rec]))
        assert res.returncode == 1
        assert "collapse" in res.stderr

    def test_empty_growth_windows_rejected(self, tmp_path):
        res = run_check(tmp_path, report([growth_record(growth_windows=[])]))
        assert res.returncode == 1
        assert "non-empty" in res.stderr

    def test_shrinking_table_rejected(self, tmp_path):
        res = run_check(
            tmp_path, report([growth_record(final_buckets=32)])
        )
        assert res.returncode == 1
        assert "final_buckets" in res.stderr

    def test_zero_initial_buckets_rejected(self, tmp_path):
        res = run_check(
            tmp_path, report([growth_record(initial_buckets=0)])
        )
        assert res.returncode == 1
        assert "initial_buckets" in res.stderr

    def test_nan_window_rejected(self, tmp_path):
        # json.dumps emits a bare NaN literal; the checker's
        # parse_constant hook must refuse it at parse time.
        rec = growth_record(
            growth_windows=[900.0, float("nan"), 850.0, 780.0, 910.0]
        )
        res = run_check(tmp_path, report([rec]))
        assert res.returncode == 1
        assert "NaN" in res.stderr or "non-finite" in res.stderr

    def test_negative_window_rejected(self, tmp_path):
        rec = growth_record(
            growth_windows=[900.0, -1.0, 850.0, 780.0, 910.0]
        )
        res = run_check(tmp_path, report([rec]))
        assert res.returncode == 1
        assert "non-negative" in res.stderr

    def test_negative_counter_rejected(self, tmp_path):
        res = run_check(
            tmp_path, report([growth_record(migration_quanta=-3)])
        )
        assert res.returncode == 1
        assert "migration_quanta" in res.stderr

    @pytest.mark.parametrize("windows", [[0.0, 100.0, 100.0]])
    def test_zero_rate_window_rejected(self, tmp_path, windows):
        res = run_check(
            tmp_path, report([growth_record(growth_windows=windows)])
        )
        assert res.returncode == 1
        assert "positive" in res.stderr
