"""AOT export tests: artifact shape contract + HLO-text interchange format."""

import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def hlos():
    return aot.lower_all()


class TestAotExport:
    def test_all_artifacts_present(self, hlos):
        assert set(hlos) == {
            "size_reduce.hlo.txt",
            "prefix_scan.hlo.txt",
            "history_stats.hlo.txt",
        }

    def test_hlo_text_not_proto(self, hlos):
        for name, text in hlos.items():
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_size_reduce_shape_contract(self, hlos):
        text = hlos["size_reduce.hlo.txt"]
        assert f"s64[{aot.AOT_E},{aot.AOT_T},2]" in text
        assert f"(s64[{aot.AOT_E}]" in text  # tuple return

    def test_prefix_scan_shape_contract(self, hlos):
        text = hlos["prefix_scan.hlo.txt"]
        assert f"s64[{aot.AOT_L}]" in text

    def test_history_stats_shape_contract(self, hlos):
        text = hlos["history_stats.hlo.txt"]
        assert f"s64[{aot.AOT_L}]" in text
        assert "s64[4]" in text

    def test_no_custom_calls(self, hlos):
        # interpret=True must fully lower pallas: a Mosaic custom-call would
        # be unloadable by the CPU PJRT client in rust/src/runtime.
        for name, text in hlos.items():
            assert "custom-call" not in text, name

    def test_entry_layout_is_tuple(self, hlos):
        # return_tuple=True: rust side unwraps with to_tuple*.
        for name, text in hlos.items():
            m = re.search(r"entry_computation_layout=\{.*->\((.*)\)\}", text)
            assert m, name

    def test_main_writes_files(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            "sys.argv", ["aot", "--out-dir", str(tmp_path)]
        )
        aot.main()
        for name in (
            "size_reduce.hlo.txt",
            "prefix_scan.hlo.txt",
            "history_stats.hlo.txt",
        ):
            assert os.path.getsize(tmp_path / name) > 100
