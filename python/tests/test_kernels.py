"""Pallas kernels vs pure-jnp oracles — the core build-time correctness bar.

Hypothesis sweeps shapes, dtypes and values; every comparison is exact
(integer kernels must be bit-exact against the reference).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import history_stats, prefix_scan, size_reduce
from compile.kernels.ref import (
    ref_history_stats,
    ref_prefix_scan,
    ref_size_reduce,
)

DTYPES = [np.int32, np.int64]


def ids(dt):
    return np.dtype(dt).name


# ---------------------------------------------------------------- size_reduce
class TestSizeReduce:
    @pytest.mark.parametrize("dtype", DTYPES, ids=ids)
    def test_matches_ref_basic(self, dtype):
        rng = np.random.default_rng(0)
        counters = rng.integers(0, 1000, (64, 8, 2)).astype(dtype)
        got = size_reduce(jnp.asarray(counters))
        np.testing.assert_array_equal(got, ref_size_reduce(counters))
        assert got.dtype == dtype

    def test_empty_structure_is_zero(self):
        counters = np.zeros((4, 16, 2), np.int64)
        np.testing.assert_array_equal(size_reduce(jnp.asarray(counters)),
                                      np.zeros(4, np.int64))

    def test_single_epoch_single_thread(self):
        counters = np.array([[[5, 2]]], np.int64)
        np.testing.assert_array_equal(size_reduce(jnp.asarray(counters)), [3])

    def test_non_block_multiple_epochs(self):
        # E not divisible by the default block: exercises the padding path.
        rng = np.random.default_rng(1)
        counters = rng.integers(0, 50, (33, 3, 2)).astype(np.int64)
        np.testing.assert_array_equal(size_reduce(jnp.asarray(counters)),
                                      ref_size_reduce(counters))

    def test_deletes_never_exceed_inserts_invariant_not_assumed(self):
        # Kernel must compute the raw difference, even if negative (the
        # validator is what flags negatives — not the reduction).
        counters = np.array([[[0, 4], [1, 0]]], np.int64)
        np.testing.assert_array_equal(size_reduce(jnp.asarray(counters)), [-3])

    @settings(max_examples=40, deadline=None)
    @given(
        e=st.integers(0, 70),
        t=st.integers(1, 9),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**32 - 1),
        block_e=st.sampled_from([1, 2, 8, 32]),
    )
    def test_matches_ref_property(self, e, t, dtype, seed, block_e):
        rng = np.random.default_rng(seed)
        counters = rng.integers(0, 2**20, (e, t, 2)).astype(dtype)
        got = size_reduce(jnp.asarray(counters), block_e=block_e)
        np.testing.assert_array_equal(got, ref_size_reduce(counters))

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            size_reduce(jnp.zeros((3, 4), jnp.int64))
        with pytest.raises(ValueError):
            size_reduce(jnp.zeros((3, 4, 3), jnp.int64))


# ---------------------------------------------------------------- prefix_scan
class TestPrefixScan:
    @pytest.mark.parametrize("dtype", DTYPES, ids=ids)
    def test_matches_ref_basic(self, dtype):
        rng = np.random.default_rng(2)
        deltas = rng.integers(-1, 2, (10_000,)).astype(dtype)
        got = prefix_scan(jnp.asarray(deltas))
        np.testing.assert_array_equal(got, ref_prefix_scan(deltas))
        assert got.dtype == dtype

    def test_all_inserts(self):
        deltas = np.ones(100, np.int64)
        np.testing.assert_array_equal(prefix_scan(jnp.asarray(deltas)),
                                      np.arange(1, 101))

    def test_insert_delete_pairs_return_to_zero(self):
        deltas = np.tile([1, -1], 50).astype(np.int64)
        got = np.asarray(prefix_scan(jnp.asarray(deltas)))
        assert got[-1] == 0
        assert got.min() == 0 and got.max() == 1

    def test_block_boundary_carry(self):
        # Force multiple grid steps with a tiny block; the carry must thread.
        deltas = np.ones(1000, np.int64)
        got = prefix_scan(jnp.asarray(deltas), block_l=16)
        np.testing.assert_array_equal(got, np.arange(1, 1001))

    @settings(max_examples=40, deadline=None)
    @given(
        l=st.integers(0, 3000),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**32 - 1),
        block_l=st.sampled_from([1, 7, 64, 4096]),
    )
    def test_matches_ref_property(self, l, dtype, seed, block_l):
        rng = np.random.default_rng(seed)
        deltas = rng.integers(-3, 4, (l,)).astype(dtype)
        got = prefix_scan(jnp.asarray(deltas), block_l=block_l)
        np.testing.assert_array_equal(got, ref_prefix_scan(deltas))

    def test_rejects_bad_shape(self):
        with pytest.raises(ValueError):
            prefix_scan(jnp.zeros((3, 4), jnp.int64))


# -------------------------------------------------------------- history_stats
class TestHistoryStats:
    def test_simple(self):
        running = np.array([1, 2, 1, 0, -1, 5], np.int64)
        got = history_stats(jnp.asarray(running), 6)
        np.testing.assert_array_equal(got, [-1, 5, 5, 1])

    def test_valid_len_masks_padding(self):
        running = np.array([1, 2, -7, -7], np.int64)
        got = history_stats(jnp.asarray(running), 2)
        np.testing.assert_array_equal(got, [1, 2, 2, 0])

    def test_legal_history_has_no_negatives(self):
        deltas = np.tile([1, 1, -1], 100).astype(np.int64)
        running = ref_prefix_scan(deltas)
        got = np.asarray(history_stats(jnp.asarray(running), len(running)))
        assert got[0] >= 0 and got[3] == 0

    @settings(max_examples=40, deadline=None)
    @given(
        l=st.integers(1, 2000),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**32 - 1),
        block_l=st.sampled_from([1, 13, 4096]),
    )
    def test_matches_ref_property(self, l, dtype, seed, block_l):
        rng = np.random.default_rng(seed)
        running = rng.integers(-100, 100, (l,)).astype(dtype)
        vlen = int(rng.integers(0, l + 1))
        got = history_stats(jnp.asarray(running), vlen, block_l=block_l)
        np.testing.assert_array_equal(got, ref_history_stats(running, vlen))

    def test_final_at_block_boundary(self):
        running = np.arange(1, 65, dtype=np.int64)
        got = history_stats(jnp.asarray(running), 32, block_l=32)
        np.testing.assert_array_equal(got, [1, 32, 32, 0])
