"""Layer-2 graph tests: epoch analytics + history validation semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ref_prefix_scan, ref_size_reduce


def _simulate_counters(rng, epochs, threads):
    """Monotone per-thread counters with del <= ins per thread (a real run)."""
    ins = np.cumsum(rng.integers(0, 5, (epochs, threads)), axis=0)
    dels = (ins * rng.uniform(0, 1, (epochs, threads))).astype(np.int64)
    return np.stack([ins.astype(np.int64), dels], axis=-1)


class TestAnalyzeEpochs:
    def test_sizes_match_ref(self):
        rng = np.random.default_rng(3)
        counters = _simulate_counters(rng, 20, 8)
        sizes, deltas, stats = model.analyze_epochs(jnp.asarray(counters))
        np.testing.assert_array_equal(sizes, ref_size_reduce(counters))

    def test_deltas_telescope_to_sizes(self):
        rng = np.random.default_rng(4)
        counters = _simulate_counters(rng, 31, 4)
        sizes, deltas, _ = model.analyze_epochs(jnp.asarray(counters))
        np.testing.assert_array_equal(np.cumsum(deltas), sizes)

    def test_stats_over_sizes(self):
        rng = np.random.default_rng(5)
        counters = _simulate_counters(rng, 16, 3)
        sizes, _, stats = model.analyze_epochs(jnp.asarray(counters))
        s = np.asarray(sizes)
        np.testing.assert_array_equal(
            stats, [s.min(), s.max(), s[-1], (s < 0).sum()]
        )

    def test_monotone_run_never_negative(self):
        rng = np.random.default_rng(6)
        counters = _simulate_counters(rng, 64, 6)
        _, _, stats = model.analyze_epochs(jnp.asarray(counters))
        assert int(stats[3]) == 0


class TestValidateHistory:
    def test_running_and_stats(self):
        deltas = np.array([1, 1, -1, 1, -1, -1, 1], np.int64)
        running, stats = model.validate_history(jnp.asarray(deltas), 7)
        np.testing.assert_array_equal(running, ref_prefix_scan(deltas))
        np.testing.assert_array_equal(stats, [0, 2, 1, 0])

    def test_illegal_history_flagged(self):
        # A delete linearized before its insert: the Figure 2 anomaly.
        deltas = np.array([-1, 1], np.int64)
        _, stats = model.validate_history(jnp.asarray(deltas), 2)
        assert int(stats[0]) == -1 and int(stats[3]) == 1

    def test_padding_is_ignored(self):
        deltas = np.zeros(128, np.int64)
        deltas[:3] = [1, 1, -1]
        _, stats = model.validate_history(jnp.asarray(deltas), 3)
        np.testing.assert_array_equal(stats, [1, 2, 1, 0])

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), l=st.integers(1, 1000))
    def test_legal_set_history_never_negative(self, seed, l):
        # Generate a legal history: delete only when non-empty.
        rng = np.random.default_rng(seed)
        deltas, cur = [], 0
        for _ in range(l):
            if cur > 0 and rng.random() < 0.5:
                deltas.append(-1)
                cur -= 1
            else:
                deltas.append(1)
                cur += 1
        deltas = np.array(deltas, np.int64)
        running, stats = model.validate_history(jnp.asarray(deltas), l)
        assert int(stats[0]) >= 0 and int(stats[3]) == 0
        assert int(stats[2]) == cur
