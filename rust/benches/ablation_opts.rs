//! Ablation A: the Section 7 optimizations, individually toggled.
//!
//! * §7.1 `clear_insert_info` — spares every later op on an inserted node a
//!   redundant `updateMetadata` call.
//! * §7.2 `backoff` — reduces CAS contention among concurrent size calls.
//! * §7.3 `early_size_check` — adopts an already-agreed size instead of
//!   re-collecting.
//!
//! Reports workload + size throughput on the skip list (update-heavy, one
//! size thread) for each configuration.

use concurrent_size::bench_util::BenchScale;
use concurrent_size::cli::Args;
use concurrent_size::harness::run;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::size::{LinearizableSize, SizeOpts, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::workload::{self, UPDATE_HEAVY};
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 3);
    let s = args.get_usize("size-threads", 2);

    println!("=== Ablation: Section 7 optimizations (SizeSkipList, update-heavy) ===");
    println!(
        "(initial={} keys, {w} workload + {s} size threads)",
        scale.initial
    );

    let configs: Vec<(&str, SizeOpts)> = vec![
        ("all on (default)", SizeOpts::default()),
        ("all off", SizeOpts::NONE),
        (
            "no 7.1 clear-insert-info",
            SizeOpts {
                clear_insert_info: false,
                ..SizeOpts::default()
            },
        ),
        (
            "no 7.2 backoff",
            SizeOpts {
                backoff: false,
                ..SizeOpts::default()
            },
        ),
        (
            "no 7.3 early-size-check",
            SizeOpts {
                early_size_check: false,
                ..SizeOpts::default()
            },
        ),
    ];

    let mut table = Table::new(&["configuration", "workload ops/s", "size ops/s"]);
    for (name, opts) in configs {
        let mut workload_sum = 0.0;
        let mut size_sum = 0.0;
        for i in 0..(scale.repeat.warmup + scale.repeat.runs) {
            let set: SkipListSet<LinearizableSize> =
                SkipListSet::with_policy(LinearizableSize::new(MAX_THREADS, opts));
            let cfg = scale.config(w, s, UPDATE_HEAVY, scale.initial);
            workload::prefill(&set, scale.initial, cfg.key_range, scale.seed);
            let res = run(&set, &cfg);
            if i >= scale.repeat.warmup {
                workload_sum += res.workload_throughput();
                size_sum += res.size_throughput();
            }
            concurrent_size::ebr::collect();
        }
        let n = scale.repeat.runs as f64;
        table.row(&[
            name.to_string(),
            fmt_rate(workload_sum / n),
            fmt_rate(size_sum / n),
        ]);
    }
    table.print();
}
