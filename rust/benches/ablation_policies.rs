//! Ablation B: size-policy alternatives the paper argues against
//! (Section 1): naive counter-after-op (incorrect) and a global lock
//! (correct but a bottleneck), against the methodology and the baseline.
//!
//! Reports workload throughput (and size throughput where applicable) on
//! the hash table under both mixes with one concurrent size thread.

use concurrent_size::bench_util::{BenchScale, MIXES};
use concurrent_size::cli::Args;
use concurrent_size::harness::run;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, LockSize, NaiveSize, NoSize};
use concurrent_size::workload;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 4);

    println!("=== Ablation: size-policy alternatives (HashTable) ===");
    println!("(initial={} keys, {w} workload threads + 1 size thread)", scale.initial);

    for mix in MIXES {
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&["policy", "workload ops/s", "size ops/s", "linearizable?"]);
        let policies: Vec<(&str, Box<dyn Fn() -> Box<dyn ConcurrentSet>>, bool, &str)> = vec![
            (
                "baseline (no size)",
                Box::new(|| {
                    Box::new(HashTableSet::<NoSize>::new(MAX_THREADS, scale.initial as usize))
                        as Box<dyn ConcurrentSet>
                }),
                false,
                "n/a",
            ),
            (
                "LinearizableSize (paper)",
                Box::new(|| {
                    Box::new(HashTableSet::<LinearizableSize>::new(
                        MAX_THREADS,
                        scale.initial as usize,
                    )) as Box<dyn ConcurrentSet>
                }),
                true,
                "yes",
            ),
            (
                "NaiveSize (Java-style)",
                Box::new(|| {
                    Box::new(HashTableSet::<NaiveSize>::new(
                        MAX_THREADS,
                        scale.initial as usize,
                    )) as Box<dyn ConcurrentSet>
                }),
                true,
                "NO",
            ),
            (
                "LockSize (global lock)",
                Box::new(|| {
                    Box::new(HashTableSet::<LockSize>::new(
                        MAX_THREADS,
                        scale.initial as usize,
                    )) as Box<dyn ConcurrentSet>
                }),
                true,
                "yes",
            ),
        ];
        for (name, factory, with_size_thread, linearizable) in policies {
            let mut workload_sum = 0.0;
            let mut size_sum = 0.0;
            for i in 0..(scale.repeat.warmup + scale.repeat.runs) {
                let set = factory();
                let cfg = scale.config(w, usize::from(with_size_thread), mix, scale.initial);
                workload::prefill(set.as_ref(), scale.initial, cfg.key_range, scale.seed);
                let res = run(set.as_ref(), &cfg);
                if i >= scale.repeat.warmup {
                    workload_sum += res.workload_throughput();
                    size_sum += res.size_throughput();
                }
                concurrent_size::ebr::collect();
            }
            let n = scale.repeat.runs as f64;
            table.row(&[
                name.to_string(),
                fmt_rate(workload_sum / n),
                if with_size_thread { fmt_rate(size_sum / n) } else { "-".into() },
                linearizable.to_string(),
            ]);
        }
        table.print();
    }
}
