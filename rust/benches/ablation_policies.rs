//! Ablation B: the size-methods design space on one structure.
//!
//! Seven scenarios, all recorded to a machine-readable report
//! (`BENCH_ablation.json` by default, `--json PATH` to override) so the
//! perf trajectory is tracked PR over PR:
//!
//! * **periodic-size** — all **six** size policies under both paper mixes
//!   with one raw-`size()` thread: the paper's four (baseline, wait-free
//!   linearizable, Java-style naive, global lock — Section 1) plus the
//!   synchronization-methods study's two optimized methods (handshake,
//!   optimistic — arXiv 2506.16350). Handshake should lead the
//!   update-heavy workload column while paying on the size column;
//!   optimistic should match the paper's workload numbers with cheaper
//!   size calls when collects succeed.
//! * **size-heavy** — the availability-gap mix this PR targets: several
//!   size threads hammering concurrently (`--size-heavy-threads`,
//!   default 4) under the update-heavy mix, sweeping the size-call axis
//!   (`raw` = every caller synchronizes itself, `exact` = combining
//!   arbiter, `recent` = published wait-free reads, `refresh` = published
//!   reads kept warm by a background `SizeRefresher`). The arbiter's
//!   combining win shows up as `exact`/`recent` size throughput beating
//!   `raw` on the serialized policies (handshake, lock), with arbiter
//!   round/adoption counts recorded alongside.
//! * **scale** — the sharded-mirror × refresh-period grid on the two
//!   calculator-backed policies: `--size-shards`-style stripe counts
//!   crossed with `SizeRefresher` periods under `refresh` size calls,
//!   recording daemon rounds and the optimistic retry-budget auto-tuner's
//!   end state alongside both throughputs.
//! * **shard_scale** — the sharded **store** over the server path: a real
//!   reactor server mounted on a [`ShardStore`] with per-shard admission
//!   watermarks, driven by a client swarm sweeping store-shard counts
//!   (1 vs auto-detected) × key distributions (uniform vs `zipf:0.99`).
//!   Records swarm throughput plus the per-shard shed total from `STATS`
//!   (the hot-shard tax under skew) — here the `shards` column means
//!   *store* shards, not mirror stripes.
//! * **reactor_scale** — the multi-reactor server over the socket path:
//!   a plain linearizable store mounted on `--reactors` shards, swarmed
//!   with and without client pipelining (reactors 1→4 crossed with
//!   commands-per-write 1 vs 16). The `reactors`/`pipeline_depth`
//!   columns only mean something here (every other scenario records 0);
//!   the pipelined column shows what batch dispatch + reply coalescing
//!   buy once the acceptor spreads connections over shards.
//! * **scan_scale** — the range-scan tax over the server path: a
//!   pipelined swarm mixing `SCAN`/`COUNT` range reads into the
//!   update-heavy stream (`scan_frac` {0.05, 0.25} × `scan_span`
//!   {16, 256}), against a two-reactor linearizable server. Scans ride
//!   the validated double-collect, so the interesting column is how
//!   throughput degrades as scans get more frequent and wider — the
//!   `scan_frac`/`scan_span` columns only mean something here (every
//!   other scenario records 0).
//! * **resize_scale** — the incremental-resize growth phase: a fresh
//!   hashtable at a deliberately small bucket count (64 and 4× that),
//!   flooded with 10× its trigger capacity of inserts under concurrent
//!   readers and a `size()` thread, timed in fixed-op windows
//!   ([`growth_run`]). Records the per-window throughput curve
//!   (`growth_windows`), the start/end bucket counts, and the number of
//!   migration quanta — the CI gate asserts no window collapses below
//!   50% of the median, i.e. migration debt is paid incrementally
//!   instead of in one stop-the-world stall. The `initial_buckets`/
//!   `final_buckets`/`migration_quanta`/`growth_windows` columns only
//!   mean something here (every other scenario records 0 / `[]`).

use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{BenchScale, make_set_opts, MIXES, STRUCTURES};
use concurrent_size::cli::{Args, PolicyKind, SizeCallKind};
use concurrent_size::harness::{client_swarm, growth_run, run, GrowthConfig, SizeCall, SwarmConfig};
use concurrent_size::metrics::{fmt_rate, json_escape, json_f64, Table};
use concurrent_size::server::{parse_stats, BlockingClient, Server, ServerConfig, Watermarks};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::shardstore::make_shard_store;
use concurrent_size::size::{detect_shards, LinearizableSize, SizeOpts};
use concurrent_size::workload::{self, KeyDist, Mix, UPDATE_HEAVY};

/// One measured configuration, ready for the JSON report.
struct Record {
    scenario: &'static str,
    policy: PolicyKind,
    mix: Mix,
    size_threads: usize,
    size_call: &'static str,
    /// Mirror stripes in the in-process scenarios; **store** shards in
    /// `shard_scale`.
    shards: usize,
    /// Key distribution surface form (`uniform` / `zipf:0.99`).
    key_dist: String,
    refresh_us: u64,
    workload_ops_per_sec: f64,
    size_ops_per_sec: f64,
    arbiter_rounds: u64,
    arbiter_adoptions: u64,
    arbiter_recent_hits: u64,
    daemon_rounds: u64,
    daemon_stalls: u64,
    fallbacks: u64,
    retry_budget: u64,
    /// `PUT`s shed by the per-shard admission tier (`shard_scale` only).
    per_shard_sheds: u64,
    /// Reactor shards serving the run (`reactor_scale` only; 0 for the
    /// in-process scenarios, 1 for `shard_scale`'s default server).
    reactors: usize,
    /// Client commands per write (`reactor_scale` only; 1 = lock-step).
    pipeline_depth: usize,
    /// Fraction of swarm ops issued as SCAN/COUNT (`scan_scale` only).
    scan_frac: f64,
    /// Key width of each swarm scan range (`scan_scale` only).
    scan_span: u64,
    /// Starting bucket count of the growth run (`resize_scale` only).
    initial_buckets: usize,
    /// Bucket count after every migration drained (`resize_scale` only).
    final_buckets: usize,
    /// Bucket-migration quanta completed (`resize_scale` only).
    migration_quanta: u64,
    /// Per-window insert throughput (ops/s) across the growth phase
    /// (`resize_scale` only; empty for every other scenario).
    growth_windows: Vec<f64>,
}

impl Record {
    fn to_json(&self) -> String {
        let windows: Vec<String> = self.growth_windows.iter().map(|w| json_f64(*w)).collect();
        format!(
            concat!(
                "{{\"scenario\":\"{}\",\"policy\":\"{}\",\"mix\":\"{}\",",
                "\"size_threads\":{},\"size_call\":\"{}\",",
                "\"shards\":{},\"key_dist\":\"{}\",\"refresh_us\":{},",
                "\"workload_ops_per_sec\":{},\"size_ops_per_sec\":{},",
                "\"arbiter_rounds\":{},\"arbiter_adoptions\":{},",
                "\"arbiter_recent_hits\":{},\"daemon_rounds\":{},",
                "\"daemon_stalls\":{},\"fallbacks\":{},\"retry_budget\":{},",
                "\"per_shard_sheds\":{},\"reactors\":{},\"pipeline_depth\":{},",
                "\"scan_frac\":{},\"scan_span\":{},",
                "\"initial_buckets\":{},\"final_buckets\":{},",
                "\"migration_quanta\":{},\"growth_windows\":[{}]}}"
            ),
            json_escape(self.scenario),
            json_escape(self.policy.label()),
            json_escape(self.mix.label()),
            self.size_threads,
            json_escape(self.size_call),
            self.shards,
            json_escape(&self.key_dist),
            self.refresh_us,
            json_f64(self.workload_ops_per_sec),
            json_f64(self.size_ops_per_sec),
            self.arbiter_rounds,
            self.arbiter_adoptions,
            self.arbiter_recent_hits,
            self.daemon_rounds,
            self.daemon_stalls,
            self.fallbacks,
            self.retry_budget,
            self.per_shard_sheds,
            self.reactors,
            self.pipeline_depth,
            json_f64(self.scan_frac),
            self.scan_span,
            self.initial_buckets,
            self.final_buckets,
            self.migration_quanta,
            windows.join(","),
        )
    }
}

/// One measurement cell: everything `measure` needs beyond the shared
/// scale (the grid scenarios vary shards and the daemon period per cell).
#[derive(Clone, Copy)]
struct Cell {
    kind: PolicyKind,
    w: usize,
    s: usize,
    mix: Mix,
    size_call: SizeCall,
    shards: usize,
    refresh_period: Option<Duration>,
}

/// Mean workload/size throughput plus end-of-run arbiter stats over
/// `runs` fresh prefilled sets (after `warmup` discarded runs).
fn measure(
    structure: &str,
    scale: &BenchScale,
    cell: Cell,
) -> (f64, f64, concurrent_size::size::ArbiterStats) {
    let mut workload_sum = 0.0;
    let mut size_sum = 0.0;
    let mut stats = concurrent_size::size::ArbiterStats::default();
    let opts = SizeOpts::default().with_shards(cell.shards);
    for i in 0..(scale.repeat.warmup + scale.repeat.runs) {
        let set = make_set_opts(structure, cell.kind, scale.initial as usize, opts)
            .unwrap_or_else(|| panic!("unknown structure {structure:?}"));
        let mut cfg = scale.config(cell.w, cell.s, cell.mix, scale.initial);
        cfg.size_call = cell.size_call;
        cfg.refresh_period = cell.refresh_period;
        workload::prefill(set.as_ref(), scale.initial, cfg.key_range, scale.seed);
        let res = run(set.as_ref(), &cfg);
        if i >= scale.repeat.warmup {
            workload_sum += res.workload_throughput();
            size_sum += res.size_throughput();
            stats = set.size_stats().unwrap_or_default();
        }
        concurrent_size::ebr::collect();
    }
    let n = scale.repeat.runs as f64;
    (workload_sum / n, size_sum / n, stats)
}

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 4);
    let heavy_size_threads = args.get_usize("size-heavy-threads", 4);
    let staleness = Duration::from_millis(args.get_u64("staleness-ms", 1));
    let json_path = args.get("json").unwrap_or("BENCH_ablation.json").to_string();
    let structure = args.get("structure").unwrap_or("hashtable").to_string();
    if !STRUCTURES.contains(&structure.as_str()) {
        eprintln!(
            "unknown --structure {structure:?} (use {})",
            STRUCTURES.join("|")
        );
        std::process::exit(2);
    }

    let mut records: Vec<Record> = Vec::new();

    println!("=== Ablation: size methods on {structure} ===");
    println!(
        "(initial={} keys, {w} workload threads, {} runs of {}s)",
        scale.initial, scale.repeat.runs, scale.secs
    );

    // -- Scenario 1: both paper mixes, one raw size thread --------------
    for mix in MIXES {
        println!("\n-- {} workload + 1 size thread --", mix.label());
        let mut table = Table::new(&["policy", "workload ops/s", "size ops/s", "linearizable?"]);
        for kind in PolicyKind::ALL {
            let s = usize::from(kind.provides_size());
            let cell = Cell {
                kind,
                w,
                s,
                mix,
                size_call: SizeCall::Raw,
                shards: 0,
                refresh_period: None,
            };
            let (workload_tput, size_tput, _) = measure(&structure, &scale, cell);
            records.push(Record {
                scenario: "periodic-size",
                policy: kind,
                mix,
                size_threads: s,
                size_call: SizeCall::Raw.label(),
                shards: 0,
                key_dist: KeyDist::Uniform.label(),
                refresh_us: 0,
                workload_ops_per_sec: workload_tput,
                size_ops_per_sec: size_tput,
                arbiter_rounds: 0,
                arbiter_adoptions: 0,
                arbiter_recent_hits: 0,
                daemon_rounds: 0,
                daemon_stalls: 0,
                fallbacks: 0,
                retry_budget: 0,
                per_shard_sheds: 0,
                reactors: 0,
                pipeline_depth: 0,
                scan_frac: 0.0,
                scan_span: 0,
                initial_buckets: 0,
                final_buckets: 0,
                migration_quanta: 0,
                growth_windows: Vec::new(),
            });
            table.row(&[
                kind.label().to_string(),
                fmt_rate(workload_tput),
                if s == 1 {
                    fmt_rate(size_tput)
                } else {
                    "-".into()
                },
                if s == 1 {
                    (if kind.linearizable() { "yes" } else { "NO" }).to_string()
                } else {
                    "n/a".into()
                },
            ]);
        }
        table.print();
    }

    // -- Scenario 2: the size-heavy availability-gap mix ----------------
    println!(
        "\n-- size-heavy: update-heavy workload + {heavy_size_threads} size threads \
         (recent staleness {staleness:?}) --"
    );
    let mut table = Table::new(&[
        "policy",
        "size call",
        "workload ops/s",
        "size ops/s",
        "rounds",
        "adopted",
        "recent hits",
    ]);
    for kind in PolicyKind::ALL {
        if !kind.provides_size() {
            continue;
        }
        for call_kind in SizeCallKind::ALL {
            let call = SizeCall::from_kind(call_kind, staleness);
            let cell = Cell {
                kind,
                w,
                s: heavy_size_threads,
                mix: UPDATE_HEAVY,
                size_call: call,
                shards: 0,
                refresh_period: None,
            };
            let (workload_tput, size_tput, stats) = measure(&structure, &scale, cell);
            records.push(Record {
                scenario: "size-heavy",
                policy: kind,
                mix: UPDATE_HEAVY,
                size_threads: heavy_size_threads,
                size_call: call.label(),
                shards: 0,
                key_dist: KeyDist::Uniform.label(),
                refresh_us: 0,
                workload_ops_per_sec: workload_tput,
                size_ops_per_sec: size_tput,
                arbiter_rounds: stats.rounds,
                arbiter_adoptions: stats.adoptions,
                arbiter_recent_hits: stats.recent_hits,
                daemon_rounds: stats.daemon_rounds,
                daemon_stalls: stats.daemon_stalls,
                fallbacks: stats.fallbacks,
                retry_budget: stats.retry_budget,
                per_shard_sheds: 0,
                reactors: 0,
                pipeline_depth: 0,
                scan_frac: 0.0,
                scan_span: 0,
                initial_buckets: 0,
                final_buckets: 0,
                migration_quanta: 0,
                growth_windows: Vec::new(),
            });
            table.row(&[
                kind.label().to_string(),
                call.label().to_string(),
                fmt_rate(workload_tput),
                fmt_rate(size_tput),
                stats.rounds.to_string(),
                stats.adoptions.to_string(),
                stats.recent_hits.to_string(),
            ]);
        }
    }
    table.print();

    // -- Scenario 3: scale — sharded mirror × refresh period -------------
    let detected = detect_shards();
    let shard_axis = [0usize, detected];
    let refresh_axis_us = args.get_u64_list("refresh-us", &[500, 2000]);
    println!(
        "\n-- scale: update-heavy + 2 refresh-served size threads \
         (shards x refresh period; auto-detected shards = {detected}) --"
    );
    let mut table = Table::new(&[
        "policy",
        "shards",
        "refresh us",
        "workload ops/s",
        "size ops/s",
        "daemon rounds",
        "fallbacks",
        "budget",
    ]);
    for kind in [PolicyKind::Linearizable, PolicyKind::Optimistic] {
        for &shards in &shard_axis {
            for &refresh_us in &refresh_axis_us {
                let period = Duration::from_micros(refresh_us);
                let cell = Cell {
                    kind,
                    w,
                    s: 2,
                    mix: UPDATE_HEAVY,
                    size_call: SizeCall::Refresh(staleness),
                    shards,
                    refresh_period: Some(period),
                };
                let (workload_tput, size_tput, stats) = measure(&structure, &scale, cell);
                records.push(Record {
                    scenario: "scale",
                    policy: kind,
                    mix: UPDATE_HEAVY,
                    size_threads: 2,
                    size_call: SizeCallKind::Refresh.label(),
                    shards,
                    key_dist: KeyDist::Uniform.label(),
                    refresh_us,
                    workload_ops_per_sec: workload_tput,
                    size_ops_per_sec: size_tput,
                    arbiter_rounds: stats.rounds,
                    arbiter_adoptions: stats.adoptions,
                    arbiter_recent_hits: stats.recent_hits,
                    daemon_rounds: stats.daemon_rounds,
                    daemon_stalls: stats.daemon_stalls,
                    fallbacks: stats.fallbacks,
                    retry_budget: stats.retry_budget,
                    per_shard_sheds: 0,
                    reactors: 0,
                    pipeline_depth: 0,
                    scan_frac: 0.0,
                    scan_span: 0,
                    initial_buckets: 0,
                    final_buckets: 0,
                    migration_quanta: 0,
                    growth_windows: Vec::new(),
                });
                table.row(&[
                    kind.label().to_string(),
                    shards.to_string(),
                    refresh_us.to_string(),
                    fmt_rate(workload_tput),
                    fmt_rate(size_tput),
                    stats.daemon_rounds.to_string(),
                    stats.fallbacks.to_string(),
                    stats.retry_budget.to_string(),
                ]);
            }
        }
    }
    table.print();

    // -- Scenario 4: shard_scale — sharded store over the server path ----
    // A real server on a ShardStore with per-shard admission watermarks,
    // swarmed over the socket path: store shards (1 = monolithic vs the
    // machine's detected parallelism) crossed with key skew (uniform vs
    // YCSB's zipf:0.99). The per-shard shed total out of STATS is the
    // hot-shard tax: under skew, one shard's gate does most of the work.
    let swarm_clients = args.get_usize("swarm-clients", 8);
    let swarm_ops = args.get_u64("swarm-ops", 1_500);
    let swarm_range = 4096u64;
    let mut store_shard_axis = vec![1usize, detected];
    store_shard_axis.dedup();
    let key_dists = [KeyDist::Uniform, KeyDist::Zipf(0.99)];
    println!(
        "\n-- shard_scale: {swarm_clients}x{swarm_ops}-op swarm against a sharded-store \
         server (store shards x key dist; per-shard admission) --"
    );
    let mut table = Table::new(&[
        "store shards",
        "key dist",
        "swarm ops/s",
        "shard sheds",
        "global sheds",
    ]);
    for &store_shards in &store_shard_axis {
        for key_dist in key_dists {
            // Per-shard watermark scaled so both distributions can trip
            // it: steady-state live keys under update-heavy are ~60% of
            // the touched range, split across shards.
            let shard_high = (1_200 / store_shards as i64).max(8);
            let store: Arc<dyn ConcurrentSet> = Arc::from(
                make_shard_store(
                    PolicyKind::Linearizable,
                    store_shards,
                    swarm_range as usize,
                    SizeOpts::default().with_shards(detected),
                )
                .expect("shard store factory"),
            );
            let config = ServerConfig {
                shard_admission: Some(Watermarks::new(shard_high, shard_high / 2)),
                ..Default::default()
            };
            let server =
                Server::bind("127.0.0.1:0", store.clone(), config).expect("bind shard_scale");
            let swarm = client_swarm(
                server.local_addr(),
                SwarmConfig {
                    key_dist,
                    ..SwarmConfig::new(
                        swarm_clients,
                        swarm_ops,
                        UPDATE_HEAVY,
                        swarm_range,
                        scale.seed,
                    )
                },
            )
            .expect("shard_scale swarm");
            let mut probe = BlockingClient::connect(server.local_addr());
            let stats = parse_stats(&probe.cmd("STATS")).expect("shard_scale STATS");
            let per_shard_sheds = stats["shard_shed"];
            let global_sheds = stats["shed"];
            let arbiter = store.size_stats().unwrap_or_default();
            drop(probe);
            drop(server);
            records.push(Record {
                scenario: "shard_scale",
                policy: PolicyKind::Linearizable,
                mix: UPDATE_HEAVY,
                size_threads: 0,
                size_call: SizeCall::Raw.label(),
                shards: store_shards,
                key_dist: key_dist.label(),
                refresh_us: 0,
                workload_ops_per_sec: swarm.throughput(),
                size_ops_per_sec: 0.0,
                arbiter_rounds: arbiter.rounds,
                arbiter_adoptions: arbiter.adoptions,
                arbiter_recent_hits: arbiter.recent_hits,
                daemon_rounds: arbiter.daemon_rounds,
                daemon_stalls: arbiter.daemon_stalls,
                fallbacks: arbiter.fallbacks,
                retry_budget: arbiter.retry_budget,
                per_shard_sheds,
                reactors: 1,
                pipeline_depth: 1,
                scan_frac: 0.0,
                scan_span: 0,
                initial_buckets: 0,
                final_buckets: 0,
                migration_quanta: 0,
                growth_windows: Vec::new(),
            });
            table.row(&[
                store_shards.to_string(),
                key_dist.label(),
                fmt_rate(swarm.throughput()),
                per_shard_sheds.to_string(),
                global_sheds.to_string(),
            ]);
        }
    }
    table.print();

    // -- Scenario 5: reactor_scale — reactor shards × client pipelining --
    // The multi-reactor ablation: the same uniform update-heavy swarm
    // against 1, 2, and 4 reactor shards, lock-step vs 16 commands per
    // write. The lock-step column isolates the accept/sweep sharding;
    // the pipelined column adds batch dispatch + coalesced replies on
    // top (one Job per burst instead of one per command).
    let reactor_axis = [1usize, 2, 4];
    let pipeline_axis = [1usize, 16];
    println!(
        "\n-- reactor_scale: {swarm_clients}x{swarm_ops}-op swarm \
         (reactor shards x commands per write) --"
    );
    let mut table = Table::new(&["reactors", "pipeline", "swarm ops/s", "queue drained?"]);
    for &reactors in &reactor_axis {
        for &pipeline in &pipeline_axis {
            let store: Arc<dyn ConcurrentSet> = Arc::from(
                make_set_opts(
                    "hashtable",
                    PolicyKind::Linearizable,
                    swarm_range as usize,
                    SizeOpts::default().with_shards(detected),
                )
                .expect("hashtable factory"),
            );
            let config = ServerConfig {
                reactors,
                ..Default::default()
            };
            let server =
                Server::bind("127.0.0.1:0", store, config).expect("bind reactor_scale");
            let swarm = client_swarm(
                server.local_addr(),
                SwarmConfig::new(
                    swarm_clients,
                    swarm_ops,
                    UPDATE_HEAVY,
                    swarm_range,
                    scale.seed,
                )
                .pipelined(pipeline),
            )
            .expect("reactor_scale swarm");
            let stats = server.stats();
            drop(server);
            records.push(Record {
                scenario: "reactor_scale",
                policy: PolicyKind::Linearizable,
                mix: UPDATE_HEAVY,
                size_threads: 0,
                size_call: SizeCall::Raw.label(),
                shards: 0,
                key_dist: KeyDist::Uniform.label(),
                refresh_us: 0,
                workload_ops_per_sec: swarm.throughput(),
                size_ops_per_sec: 0.0,
                arbiter_rounds: 0,
                arbiter_adoptions: 0,
                arbiter_recent_hits: 0,
                daemon_rounds: 0,
                daemon_stalls: 0,
                fallbacks: 0,
                retry_budget: 0,
                per_shard_sheds: 0,
                reactors,
                pipeline_depth: pipeline,
                scan_frac: 0.0,
                scan_span: 0,
                initial_buckets: 0,
                final_buckets: 0,
                migration_quanta: 0,
                growth_windows: Vec::new(),
            });
            table.row(&[
                reactors.to_string(),
                pipeline.to_string(),
                fmt_rate(swarm.throughput()),
                (if stats.queue_depth == 0 { "yes" } else { "NO" }).to_string(),
            ]);
        }
    }
    table.print();

    // -- Scenario 6: scan_scale — range-scan frequency × span -------------
    // SCAN/COUNT range reads mixed into a pipelined update-heavy swarm
    // against a two-reactor linearizable server: frequency (fraction of
    // ops that are range reads) crossed with span (keys per range). The
    // validated double-collect makes wide, frequent scans the expensive
    // corner; this grid prices it.
    let scan_frac_axis = [0.05f64, 0.25];
    let scan_span_axis = [16u64, 256];
    println!(
        "\n-- scan_scale: {swarm_clients}x{swarm_ops}-op pipelined swarm \
         (scan fraction x scan span, 2 reactors) --"
    );
    let mut table = Table::new(&["scan frac", "scan span", "swarm ops/s", "errors"]);
    for &scan_frac in &scan_frac_axis {
        for &scan_span in &scan_span_axis {
            let store: Arc<dyn ConcurrentSet> = Arc::from(
                make_set_opts(
                    "hashtable",
                    PolicyKind::Linearizable,
                    swarm_range as usize,
                    SizeOpts::default().with_shards(detected),
                )
                .expect("hashtable factory"),
            );
            let config = ServerConfig {
                reactors: 2,
                ..Default::default()
            };
            let server = Server::bind("127.0.0.1:0", store, config).expect("bind scan_scale");
            let swarm = client_swarm(
                server.local_addr(),
                SwarmConfig::new(
                    swarm_clients,
                    swarm_ops,
                    UPDATE_HEAVY,
                    swarm_range,
                    scale.seed,
                )
                .pipelined(16)
                .with_scans(scan_frac, scan_span),
            )
            .expect("scan_scale swarm");
            drop(server);
            records.push(Record {
                scenario: "scan_scale",
                policy: PolicyKind::Linearizable,
                mix: UPDATE_HEAVY,
                size_threads: 0,
                size_call: SizeCall::Raw.label(),
                shards: 0,
                key_dist: KeyDist::Uniform.label(),
                refresh_us: 0,
                workload_ops_per_sec: swarm.throughput(),
                size_ops_per_sec: 0.0,
                arbiter_rounds: 0,
                arbiter_adoptions: 0,
                arbiter_recent_hits: 0,
                daemon_rounds: 0,
                daemon_stalls: 0,
                fallbacks: 0,
                retry_budget: 0,
                per_shard_sheds: 0,
                reactors: 2,
                pipeline_depth: 16,
                scan_frac,
                scan_span,
                initial_buckets: 0,
                final_buckets: 0,
                migration_quanta: 0,
                growth_windows: Vec::new(),
            });
            table.row(&[
                format!("{scan_frac:.2}"),
                scan_span.to_string(),
                fmt_rate(swarm.throughput()),
                swarm.errors.to_string(),
            ]);
        }
    }
    table.print();

    // -- Scenario 7: resize_scale — the incremental-resize growth phase --
    // A deliberately undersized hashtable flooded with 10x its trigger
    // capacity of inserts under concurrent readers and one size() thread,
    // timed in fixed-op windows. The per-window curve is the payoff: with
    // incremental migration the trigger windows dip but never collapse;
    // a stop-the-world rehash would flatline one window. The CI schema
    // gate (scripts/check_ablation_schema.py) asserts min(window) >= 50%
    // of the median.
    let growth_bucket_axis = [
        args.get_usize("resize-initial-buckets", 64),
        args.get_usize("resize-initial-buckets", 64) * 4,
    ];
    println!(
        "\n-- resize_scale: insert flood to 10x trigger capacity \
         (initial buckets axis; {} windows) --",
        GrowthConfig::default().windows
    );
    let mut table = Table::new(&[
        "initial buckets",
        "final buckets",
        "resizes",
        "quanta",
        "mean ops/s",
        "min/median",
    ]);
    for &initial_buckets in &growth_bucket_axis {
        let cfg = GrowthConfig {
            initial_buckets,
            seed: scale.seed,
            ..GrowthConfig::default()
        };
        let res = growth_run::<LinearizableSize>(&cfg);
        let mean = if res.windows.is_empty() {
            0.0
        } else {
            res.windows.iter().sum::<f64>() / res.windows.len() as f64
        };
        records.push(Record {
            scenario: "resize_scale",
            policy: PolicyKind::Linearizable,
            mix: UPDATE_HEAVY,
            size_threads: cfg.size_threads,
            size_call: SizeCall::Raw.label(),
            shards: 0,
            key_dist: KeyDist::Uniform.label(),
            refresh_us: 0,
            workload_ops_per_sec: mean,
            size_ops_per_sec: 0.0,
            arbiter_rounds: 0,
            arbiter_adoptions: 0,
            arbiter_recent_hits: 0,
            daemon_rounds: 0,
            daemon_stalls: 0,
            fallbacks: 0,
            retry_budget: 0,
            per_shard_sheds: 0,
            reactors: 0,
            pipeline_depth: 0,
            scan_frac: 0.0,
            scan_span: 0,
            initial_buckets: res.initial_buckets,
            final_buckets: res.final_buckets,
            migration_quanta: res.migration_quanta,
            growth_windows: res.windows.clone(),
        });
        table.row(&[
            res.initial_buckets.to_string(),
            res.final_buckets.to_string(),
            res.resizes.to_string(),
            res.migration_quanta.to_string(),
            fmt_rate(mean),
            format!("{:.2}", res.collapse_ratio()),
        ]);
    }
    table.print();

    // -- Machine-readable report ----------------------------------------
    let rows: Vec<String> = records.iter().map(Record::to_json).collect();
    let json = format!(
        concat!(
            "{{\"bench\":\"ablation_policies\",\"structure\":\"{}\",",
            "\"config\":{{\"initial\":{},\"secs\":{},\"runs\":{},\"warmup\":{},",
            "\"workload_threads\":{},\"size_heavy_threads\":{},",
            "\"staleness_ms\":{},\"seed\":{}}},\n",
            "\"results\":[\n{}\n]}}\n"
        ),
        json_escape(&structure),
        scale.initial,
        json_f64(scale.secs),
        scale.repeat.runs,
        scale.repeat.warmup,
        w,
        heavy_size_threads,
        staleness.as_millis(),
        scale.seed,
        rows.join(",\n"),
    );
    match std::fs::write(&json_path, &json) {
        Ok(()) => println!("\nwrote {} records to {json_path}", records.len()),
        Err(e) => eprintln!("\nfailed to write {json_path}: {e}"),
    }
}
