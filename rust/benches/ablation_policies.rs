//! Ablation B: the size-methods design space on one structure.
//!
//! Sweeps all **six** size policies on the hash table under both paper
//! mixes with one concurrent size thread: the paper's four (baseline,
//! wait-free linearizable, Java-style naive, global lock — Section 1) plus
//! the synchronization-methods study's two optimized methods (handshake,
//! optimistic — arXiv 2506.16350). Reports workload *and* size-call
//! throughput so both sides of each method's trade-off are visible:
//! handshake should lead the update-heavy workload column while paying on
//! the size column; optimistic should match the paper's workload numbers
//! with cheaper size calls when collects succeed.

use concurrent_size::bench_util::{make_set, BenchScale, MIXES, STRUCTURES};
use concurrent_size::cli::{Args, PolicyKind};
use concurrent_size::harness::run;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::workload;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 4);
    let structure = args.get("structure").unwrap_or("hashtable").to_string();
    if !STRUCTURES.contains(&structure.as_str()) {
        eprintln!(
            "unknown --structure {structure:?} (use {})",
            STRUCTURES.join("|")
        );
        std::process::exit(2);
    }

    println!("=== Ablation: size methods on {structure} ===");
    println!(
        "(initial={} keys, {w} workload threads + 1 size thread, {} runs of {}s)",
        scale.initial, scale.repeat.runs, scale.secs
    );

    for mix in MIXES {
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&["policy", "workload ops/s", "size ops/s", "linearizable?"]);
        for kind in PolicyKind::ALL {
            let with_size_thread = kind.provides_size();
            let mut workload_sum = 0.0;
            let mut size_sum = 0.0;
            for i in 0..(scale.repeat.warmup + scale.repeat.runs) {
                let set = make_set(&structure, kind, scale.initial as usize)
                    .unwrap_or_else(|| panic!("unknown structure {structure:?}"));
                let cfg = scale.config(w, usize::from(with_size_thread), mix, scale.initial);
                workload::prefill(set.as_ref(), scale.initial, cfg.key_range, scale.seed);
                let res = run(set.as_ref(), &cfg);
                if i >= scale.repeat.warmup {
                    workload_sum += res.workload_throughput();
                    size_sum += res.size_throughput();
                }
                concurrent_size::ebr::collect();
            }
            let n = scale.repeat.runs as f64;
            table.row(&[
                kind.label().to_string(),
                fmt_rate(workload_sum / n),
                if with_size_thread {
                    fmt_rate(size_sum / n)
                } else {
                    "-".into()
                },
                if with_size_thread {
                    (if kind.linearizable() { "yes" } else { "NO" }).to_string()
                } else {
                    "n/a".into()
                },
            ]);
        }
        table.print();
    }
}
