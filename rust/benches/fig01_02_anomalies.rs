//! Figures 1–2 reproduction: the anomalies of the naive (Java-style)
//! size implementation, and their absence under the methodology.
//!
//! * Figure 1 — `contains(1)` observes the element but an immediately
//!   following `size()` returns 0 (metadata lags the structure update).
//! * Figure 2 — `size()` returns a negative number (a delete's decrement
//!   lands before the racing insert's delayed increment).
//!
//! The paper reproduced Figure 1 on Java's `ConcurrentSkipListMap`; we
//! reproduce both on the `NaiveSize` policy (with an insert-side
//! preemption window standing in for the paper's 64-thread scheduler) and
//! verify the `LinearizableSize` policy never exhibits them.

use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies};
use concurrent_size::cli::Args;
use concurrent_size::size::{LinearizableSize, NaiveSize, SizeOpts, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let trials = args.get_usize("trials", 2_000);
    let rounds = args.get_usize("rounds", 500);

    println!("=== Figures 1-2: naive-size anomalies vs the methodology ===");

    let mut naive_policy = NaiveSize::new(MAX_THREADS, SizeOpts::default());
    naive_policy.set_insert_window(Duration::from_micros(80));
    let naive: Arc<SkipListSet<NaiveSize>> = Arc::new(SkipListSet::with_policy(naive_policy));
    let lin: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));

    let f1_naive = fig1_anomalies(naive.as_ref(), trials);
    let f1_lin = fig1_anomalies(lin.as_ref(), trials);
    println!("Figure 1 (contains=true then size=0), {trials} trials:");
    println!("  NaiveSize        : {f1_naive} anomalies");
    println!("  LinearizableSize : {f1_lin} anomalies (must be 0)");
    assert_eq!(f1_lin, 0);

    let f2_naive = fig2_anomalies(naive.as_ref(), rounds);
    let f2_lin = fig2_anomalies(lin.as_ref(), rounds);
    println!("Figure 2 (negative size), {rounds} rounds:");
    println!("  NaiveSize        : {f2_naive} rounds with a negative size");
    println!("  LinearizableSize : {f2_lin} (must be 0)");
    assert_eq!(f2_lin, 0);

    println!(
        "\nShape check: naive anomalies observed = {} (> 0 expected), linearizable = 0.",
        f1_naive + f2_naive
    );
}
