//! Figure 7 reproduction: overhead of the size mechanism on hash-table
//! operations (paper Section 9, Fig. 7).
//!
//! Grid: {read-heavy, update-heavy} × {no size thread, 1 size thread} ×
//! thread ladder; reports baseline vs transformed throughput and the ratio
//! (the paper observes ratios of 80–99%).

use concurrent_size::bench_util::{BenchScale, overhead_figure};
use concurrent_size::cli::Args;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NoSize};
use concurrent_size::MAX_THREADS;

fn main() {
    let scale = BenchScale::from_args(&Args::from_env());
    overhead_figure(
        "Figure 7",
        "HashTable",
        &|initial| {
            Box::new(HashTableSet::<NoSize>::new(MAX_THREADS, initial as usize))
                as Box<dyn ConcurrentSet>
        },
        &|initial| {
            Box::new(HashTableSet::<LinearizableSize>::new(
                MAX_THREADS,
                initial as usize,
            )) as Box<dyn ConcurrentSet>
        },
        &scale,
    );
}
