//! Figure 8 reproduction: overhead of the size mechanism on BST operations
//! (paper Section 9, Fig. 8). Same grid as Figure 7.

use concurrent_size::bench_util::{BenchScale, overhead_figure};
use concurrent_size::bst::BstSet;
use concurrent_size::cli::Args;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NoSize};
use concurrent_size::MAX_THREADS;

fn main() {
    let scale = BenchScale::from_args(&Args::from_env());
    overhead_figure(
        "Figure 8",
        "BST",
        &|_| Box::new(BstSet::<NoSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>,
        &|_| Box::new(BstSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>,
        &scale,
    );
}
