//! Figure 9 reproduction: overhead of the size mechanism on skip-list
//! operations (paper Section 9, Fig. 9). Same grid as Figure 7.

use concurrent_size::bench_util::{BenchScale, overhead_figure};
use concurrent_size::cli::Args;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NoSize};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let scale = BenchScale::from_args(&Args::from_env());
    overhead_figure(
        "Figure 9",
        "SkipList",
        &|_| Box::new(SkipListSet::<NoSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>,
        &|_| Box::new(SkipListSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>,
        &scale,
    );
}
