//! Figure 10 reproduction: size-thread throughput as a function of the
//! data-structure size (paper Section 9, Fig. 10).
//!
//! The paper's claim: the methodology's `size()` is **insensitive to the
//! data-structure size** (it reads 2·#threads counters, never the
//! structure). The curves here should be flat across the size sweep, in
//! contrast to the snapshot competitors of Figure 11.
//!
//! Setup (scaled): 1 size thread + `--workload-threads` workload threads,
//! per the paper's "one size thread and 31 workload threads".

use concurrent_size::bench_util::{BenchScale, measure_size_tput, MIXES};
use concurrent_size::bst::BstSet;
use concurrent_size::cli::Args;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 3);

    println!("=== Figure 10: size throughput vs data-structure size ===");
    println!(
        "(sizes={:?}, {w} workload threads + 1 size thread; paper: 1M/10M/100M, 31+1 threads)",
        scale.sizes
    );

    let factories: Vec<(&str, concurrent_size::bench_util::SetFactory)> = vec![
        ("SizeHashTable", &|initial| {
            Box::new(HashTableSet::<LinearizableSize>::new(
                MAX_THREADS,
                initial as usize,
            )) as Box<dyn ConcurrentSet>
        }),
        ("SizeSkipList", &|_| {
            Box::new(SkipListSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
        ("SizeBST", &|_| {
            Box::new(BstSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
    ];

    for mix in MIXES {
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&["structure", "data size", "size ops/s", "CoV %"]);
        for (name, factory) in &factories {
            for &n in &scale.sizes {
                let cfg = scale.config(w, 1, mix, n);
                let stats = measure_size_tput(*factory, &scale, &cfg, n);
                table.row(&[
                    name.to_string(),
                    n.to_string(),
                    fmt_rate(stats.mean),
                    format!("{:.1}", 100.0 * stats.cov()),
                ]);
            }
        }
        table.print();
    }
    println!("\nExpected shape: flat size throughput across data sizes (paper Fig. 10).");
}
