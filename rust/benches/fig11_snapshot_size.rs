//! Figure 11 reproduction: snapshot-based size throughput as a function of
//! the data-structure size (paper Section 9, Fig. 11).
//!
//! The competitors pay per-element (SnapshotSkipList) or per-64-element-leaf
//! (VcasBST-64 model) costs, so their size throughput *degrades* as the
//! structure grows — the contrast to Figure 10's flat curves. The paper
//! reports SnapshotSkipList at ~1 size/s on 1M keys and quotes
//! SizeSkipList ≥ 54806× SnapshotSkipList, SizeBST 83–60423× VcasBST-64.

use concurrent_size::bench_util::{BenchScale, measure_size_tput, MIXES};
use concurrent_size::cli::Args;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::snapshot::SnapshotSkipList;
use concurrent_size::vcas::VcasSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 3);

    println!("=== Figure 11: snapshot-based size throughput vs data-structure size ===");
    println!(
        "(sizes={:?}, {w} workload threads + 1 size thread)",
        scale.sizes
    );

    let factories: Vec<(&str, concurrent_size::bench_util::SetFactory)> = vec![
        ("SnapshotSkipList", &|_| {
            Box::new(SnapshotSkipList::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
        ("VcasSet-64", &|initial| {
            Box::new(VcasSet::new(MAX_THREADS, initial as usize)) as Box<dyn ConcurrentSet>
        }),
    ];

    for mix in MIXES {
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&["structure", "data size", "size ops/s", "CoV %"]);
        for (name, factory) in &factories {
            for &n in &scale.sizes {
                let cfg = scale.config(w, 1, mix, n);
                let stats = measure_size_tput(*factory, &scale, &cfg, n);
                table.row(&[
                    name.to_string(),
                    n.to_string(),
                    fmt_rate(stats.mean),
                    format!("{:.1}", 100.0 * stats.cov()),
                ]);
            }
        }
        table.print();
    }
    println!("\nExpected shape: size throughput degrades with data size (paper Fig. 11),");
    println!("with VcasSet-64 well above SnapshotSkipList but well below Figure 10.");
}
