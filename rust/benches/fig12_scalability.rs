//! Figure 12 reproduction: size-operation scalability (paper Section 9,
//! Fig. 12).
//!
//! `s` size threads (ladder) run against a fixed pool of workload threads;
//! the paper's claim is that total size throughput *grows* with `s` for the
//! transformed structures, while the snapshot competitors sit orders of
//! magnitude below.

use concurrent_size::bench_util::{BenchScale, measure_size_tput, MIXES};
use concurrent_size::bst::BstSet;
use concurrent_size::cli::Args;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::metrics::{fmt_rate, Table};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::snapshot::SnapshotSkipList;
use concurrent_size::vcas::VcasSet;
use concurrent_size::MAX_THREADS;

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 2);

    println!("=== Figure 12: size scalability ===");
    println!(
        "(initial={} keys, {w} workload threads, size-thread ladder {:?}; \
         paper: 32 workload, s=1..16)",
        scale.initial, scale.size_threads
    );

    let factories: Vec<(&str, concurrent_size::bench_util::SetFactory)> = vec![
        ("SizeHashTable", &|initial| {
            Box::new(HashTableSet::<LinearizableSize>::new(
                MAX_THREADS,
                initial as usize,
            )) as Box<dyn ConcurrentSet>
        }),
        ("SizeSkipList", &|_| {
            Box::new(SkipListSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
        ("SizeBST", &|_| {
            Box::new(BstSet::<LinearizableSize>::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
        ("SnapshotSkipList", &|_| {
            Box::new(SnapshotSkipList::new(MAX_THREADS)) as Box<dyn ConcurrentSet>
        }),
        ("VcasSet-64", &|initial| {
            Box::new(VcasSet::new(MAX_THREADS, initial as usize)) as Box<dyn ConcurrentSet>
        }),
    ];

    for mix in MIXES {
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&["structure", "size threads", "total size ops/s", "CoV %"]);
        for (name, factory) in &factories {
            for &s in &scale.size_threads {
                let cfg = scale.config(w, s, mix, scale.initial);
                let stats = measure_size_tput(*factory, &scale, &cfg, scale.initial);
                table.row(&[
                    name.to_string(),
                    s.to_string(),
                    fmt_rate(stats.mean),
                    format!("{:.1}", 100.0 * stats.cov()),
                ]);
            }
        }
        table.print();
    }
    println!("\nExpected shape: transformed structures' total size throughput grows with s");
    println!("and sits orders of magnitude above the snapshot competitors (paper Fig. 12).");
}
