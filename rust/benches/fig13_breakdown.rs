//! Figure 13 reproduction: overhead breakdown by operation type (paper
//! Section 9.1, Fig. 13).
//!
//! As in the paper, each workload thread repeatedly picks a uniform type
//! for its next 100 operations and times the batch, yielding per-type
//! throughput; the table reports transformed/baseline ratios per type.
//! The paper observes the highest loss for insert and the lowest for
//! contains.

use std::time::Duration;

use concurrent_size::bench_util::{BenchScale, MIXES};
use concurrent_size::bst::BstSet;
use concurrent_size::cli::Args;
use concurrent_size::harness::{run, RunConfig};
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::metrics::Table;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NoSize};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::workload::{self, key_range, OpType};
use concurrent_size::MAX_THREADS;

fn per_type(set: &dyn ConcurrentSet, scale: &BenchScale, cfg: &RunConfig) -> [f64; 3] {
    workload::prefill(set, scale.initial, cfg.key_range, scale.seed ^ 0xF111);
    let res = run(set, cfg);
    [
        res.type_throughput(OpType::Insert),
        res.type_throughput(OpType::Delete),
        res.type_throughput(OpType::Contains),
    ]
}

fn main() {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let w = args.get_usize("workload-threads", 4);

    println!("=== Figure 13: overhead breakdown by operation type ===");
    println!(
        "(initial={} keys, {w} workload threads, 100-op uniform batches)",
        scale.initial
    );

    for mix in MIXES {
        // Fresh structures per mix: prefill must start from empty.
        let pairs: Vec<(&str, Box<dyn ConcurrentSet>, Box<dyn ConcurrentSet>)> = vec![
            (
                "HashTable",
                Box::new(HashTableSet::<NoSize>::new(MAX_THREADS, scale.initial as usize)),
                Box::new(HashTableSet::<LinearizableSize>::new(
                    MAX_THREADS,
                    scale.initial as usize,
                )),
            ),
            (
                "SkipList",
                Box::new(SkipListSet::<NoSize>::new(MAX_THREADS)),
                Box::new(SkipListSet::<LinearizableSize>::new(MAX_THREADS)),
            ),
            (
                "BST",
                Box::new(BstSet::<NoSize>::new(MAX_THREADS)),
                Box::new(BstSet::<LinearizableSize>::new(MAX_THREADS)),
            ),
        ];
        println!("\n-- {} workload --", mix.label());
        let mut table = Table::new(&[
            "structure",
            "insert %",
            "delete %",
            "contains %",
            "combined %",
        ]);
        for (name, baseline, transformed) in &pairs {
            let mut cfg = RunConfig::new(w, 0, mix, key_range(scale.initial, mix));
            cfg.duration = Duration::from_secs_f64(scale.secs);
            cfg.per_type_timing = true;
            cfg.seed = scale.seed;
            let base = per_type(baseline.as_ref(), &scale, &cfg);
            let tr = per_type(transformed.as_ref(), &scale, &cfg);
            let ratio = |i: usize| 100.0 * tr[i] / base[i];
            let combined =
                100.0 * (tr[0] + tr[1] + tr[2]) / (base[0] + base[1] + base[2]);
            table.row(&[
                name.to_string(),
                format!("{:.1}", ratio(0)),
                format!("{:.1}", ratio(1)),
                format!("{:.1}", ratio(2)),
                format!("{combined:.1}"),
            ]);
        }
        table.print();
    }
    println!("\nExpected shape: insert loses the most, contains the least (paper Fig. 13).");
}
