//! Epoch analytics: periodic metadata sampling fed through the
//! AOT-compiled Pallas pipeline.
//!
//! A run is divided into *epochs*; at each epoch boundary the coordinator
//! samples (a) the linearizable `size()` and (b) the raw per-thread
//! metadata counters of the [`crate::size::SizeCalculator`]. Offline, the
//! PJRT pipeline reduces the counter samples to per-epoch sizes
//! (`size_reduce` kernel) and the validator checks invariants.
//!
//! Exactness note: raw counter samples are taken cell-by-cell and are not
//! by themselves linearizable (that is the paper's whole point!). They are
//! recorded at *near-quiescent* epoch boundaries for trend analytics; the
//! final epoch is taken at full quiescence, where the pipeline's size must
//! equal the linearizable `size()` bit-exactly — asserted by the e2e
//! example and the integration tests.

use crate::runtime::Artifacts;
use crate::size::SizeCalculator;

/// One epoch sample.
#[derive(Clone, Debug)]
pub struct EpochSample {
    /// Raw per-thread `[insertions, deletions]` counters.
    pub counters: Vec<[u64; 2]>,
    /// The linearizable size at (about) the same moment.
    pub linearizable_size: i64,
}

/// Collects epoch samples during a run.
#[derive(Default)]
pub struct EpochRecorder {
    samples: Vec<EpochSample>,
}

impl EpochRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sample `calc` now.
    pub fn record(&mut self, calc: &SizeCalculator) {
        self.samples.push(EpochSample {
            counters: calc.sample_counters(),
            linearizable_size: calc.compute(),
        });
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[EpochSample] {
        &self.samples
    }
}

/// The artifact-computed report over an epoch recording.
#[derive(Clone, Debug)]
pub struct EpochReport {
    /// Pallas-computed per-epoch sizes (from raw counter samples).
    pub pallas_sizes: Vec<i64>,
    /// Linearizable sizes observed online.
    pub linearizable_sizes: Vec<i64>,
    /// Per-epoch size deltas.
    pub deltas: Vec<i64>,
}

impl EpochReport {
    /// Max |pallas − linearizable| across epochs (sampling skew; must be 0
    /// at quiescent epochs).
    pub fn max_skew(&self) -> i64 {
        self.pallas_sizes
            .iter()
            .zip(&self.linearizable_sizes)
            .map(|(a, b)| (a - b).abs())
            .max()
            .unwrap_or(0)
    }

    /// Exactness at the final (quiescent) epoch.
    pub fn final_exact(&self) -> bool {
        match (self.pallas_sizes.last(), self.linearizable_sizes.last()) {
            (Some(a), Some(b)) => a == b,
            _ => true,
        }
    }
}

/// Run the recorded epochs through the PJRT pipeline.
pub fn analyze(artifacts: &Artifacts, rec: &EpochRecorder) -> crate::runtime::Result<EpochReport> {
    let counters: Vec<Vec<[u64; 2]>> =
        rec.samples().iter().map(|s| s.counters.clone()).collect();
    let pallas_sizes = artifacts.epoch_sizes(&counters)?;
    let linearizable_sizes: Vec<i64> =
        rec.samples().iter().map(|s| s.linearizable_size).collect();
    let deltas: Vec<i64> = pallas_sizes
        .iter()
        .scan(0i64, |prev, &s| {
            let d = s - *prev;
            *prev = s;
            Some(d)
        })
        .collect();
    Ok(EpochReport {
        pallas_sizes,
        linearizable_sizes,
        deltas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{OpKind, SizeOpts, UpdateInfo};

    #[test]
    fn recorder_snapshots_counters() {
        let calc = SizeCalculator::new(4, SizeOpts::default());
        let mut rec = EpochRecorder::new();
        rec.record(&calc);
        calc.update_metadata(UpdateInfo { tid: 0, counter: 1 }.pack(), OpKind::Insert);
        rec.record(&calc);
        assert_eq!(rec.len(), 2);
        assert_eq!(rec.samples()[0].linearizable_size, 0);
        assert_eq!(rec.samples()[1].linearizable_size, 1);
        assert_eq!(rec.samples()[1].counters[0][0], 1);
    }

    #[test]
    fn analyze_agrees_with_linearizable_at_quiescence() {
        let artifacts = match Artifacts::load_default() {
            Ok(a) => a,
            Err(_) => return, // artifacts not built in this context
        };
        let calc = SizeCalculator::new(4, SizeOpts::default());
        let mut rec = EpochRecorder::new();
        for c in 1..=20u64 {
            calc.update_metadata(UpdateInfo { tid: 1, counter: c }.pack(), OpKind::Insert);
            if c % 2 == 0 {
                calc.update_metadata(
                    UpdateInfo {
                        tid: 1,
                        counter: c / 2,
                    }
                    .pack(),
                    OpKind::Delete,
                );
            }
            rec.record(&calc);
        }
        let report = analyze(&artifacts, &rec).unwrap();
        // All samples here are quiescent: zero skew everywhere.
        assert_eq!(report.max_skew(), 0);
        assert!(report.final_exact());
        assert_eq!(*report.pallas_sizes.last().unwrap(), 10);
        // Deltas telescope back to the sizes.
        let resum: i64 = report.deltas.iter().sum();
        assert_eq!(resum, 10);
    }
}
