//! Shared scaffolding for the figure-reproduction benches
//! (`rust/benches/fig*.rs`).
//!
//! Every bench accepts the same overrides so the paper-scale experiment is
//! one flag away from the CI-scale default:
//! `--threads 1,2,4,8` `--secs 0.5` `--runs 2` `--warmup 1`
//! `--initial 20000` `--sizes 10000,50000,200000` `--seed 42`.
//!
//! Scale notes (DESIGN.md §2): this container exposes a single core, so
//! thread ladders default to ≤ 8 (the paper uses up to 64 hardware
//! threads) and data sizes to ≤ 200K (paper: 1M–100M). The reported
//! quantities are the *relative* ones the paper's claims are about.

use std::time::Duration;

use crate::bst::BstSet;
use crate::cli::{Args, PolicyKind};
use crate::harness::{Repeat, run, RunConfig};
use crate::hashtable::HashTableSet;
use crate::list::LinkedListSet;
use crate::metrics::{fmt_rate, Stats, Table};
use crate::set_api::ConcurrentSet;
use crate::size::{
    HandshakeSize, LinearizableSize, LockSize, NaiveSize, NoSize, OptimisticSize, SizeOpts,
};
use crate::skiplist::SkipListSet;
use crate::workload::{self, key_range, Mix, READ_HEAVY, UPDATE_HEAVY};
use crate::MAX_THREADS;

/// Common bench scale, assembled from CLI/env overrides.
#[derive(Clone, Debug)]
pub struct BenchScale {
    pub threads: Vec<usize>,
    pub size_threads: Vec<usize>,
    pub secs: f64,
    pub repeat: Repeat,
    pub initial: u64,
    pub sizes: Vec<u64>,
    pub seed: u64,
}

impl BenchScale {
    pub fn from_args(args: &Args) -> Self {
        Self {
            threads: args
                .get_u64_list("threads", &[1, 2, 4, 8])
                .into_iter()
                .map(|x| x as usize)
                .collect(),
            size_threads: args
                .get_u64_list("size-threads", &[1, 2, 4, 8])
                .into_iter()
                .map(|x| x as usize)
                .collect(),
            secs: args.get_f64("secs", 0.4),
            repeat: Repeat {
                warmup: args.get_usize("warmup", 1),
                runs: args.get_usize("runs", 2),
            },
            initial: args.get_u64("initial", 20_000),
            sizes: args.get_u64_list("sizes", &[10_000, 50_000, 200_000]),
            seed: args.get_u64("seed", 42),
        }
    }

    pub fn config(&self, w: usize, s: usize, mix: Mix, initial: u64) -> RunConfig {
        let mut cfg = RunConfig::new(w, s, mix, key_range(initial, mix));
        cfg.duration = Duration::from_secs_f64(self.secs);
        cfg.seed = self.seed;
        cfg
    }
}

/// Both paper mixes with their labels.
pub const MIXES: [Mix; 2] = [READ_HEAVY, UPDATE_HEAVY];

/// The four size-transformable structures, by CLI name.
pub const STRUCTURES: [&str; 4] = ["hashtable", "skiplist", "bst", "list"];

/// Build `structure` instantiated with `policy` — the one factory behind
/// `csize bench`, the ablation benches and `kv_server`, so every surface
/// speaks the same six-policy vocabulary. `expected` sizes the hash table;
/// `None` for an unknown structure name. Uses the default [`SizeOpts`]
/// (sharded mirror off); see [`make_set_opts`] for the tuned variant.
pub fn make_set(
    structure: &str,
    policy: PolicyKind,
    expected: usize,
) -> Option<Box<dyn ConcurrentSet>> {
    make_set_opts(structure, policy, expected, SizeOpts::default())
}

/// [`make_set`] with explicit [`SizeOpts`] — the path CLI surfaces use to
/// thread `--size-shards` (and the `ablation_opts` toggles) into any
/// structure/policy combination.
pub fn make_set_opts(
    structure: &str,
    policy: PolicyKind,
    expected: usize,
    opts: SizeOpts,
) -> Option<Box<dyn ConcurrentSet>> {
    use PolicyKind::*;
    let t = MAX_THREADS;
    Some(match (structure, policy) {
        ("hashtable", Baseline) => Box::new(HashTableSet::<NoSize>::with_opts(t, expected, opts)),
        ("hashtable", Linearizable) => {
            Box::new(HashTableSet::<LinearizableSize>::with_opts(t, expected, opts))
        }
        ("hashtable", Naive) => Box::new(HashTableSet::<NaiveSize>::with_opts(t, expected, opts)),
        ("hashtable", Lock) => Box::new(HashTableSet::<LockSize>::with_opts(t, expected, opts)),
        ("hashtable", Handshake) => {
            Box::new(HashTableSet::<HandshakeSize>::with_opts(t, expected, opts))
        }
        ("hashtable", Optimistic) => {
            Box::new(HashTableSet::<OptimisticSize>::with_opts(t, expected, opts))
        }
        ("skiplist", Baseline) => Box::new(SkipListSet::<NoSize>::with_opts(t, opts)),
        ("skiplist", Linearizable) => Box::new(SkipListSet::<LinearizableSize>::with_opts(t, opts)),
        ("skiplist", Naive) => Box::new(SkipListSet::<NaiveSize>::with_opts(t, opts)),
        ("skiplist", Lock) => Box::new(SkipListSet::<LockSize>::with_opts(t, opts)),
        ("skiplist", Handshake) => Box::new(SkipListSet::<HandshakeSize>::with_opts(t, opts)),
        ("skiplist", Optimistic) => Box::new(SkipListSet::<OptimisticSize>::with_opts(t, opts)),
        ("bst", Baseline) => Box::new(BstSet::<NoSize>::with_opts(t, opts)),
        ("bst", Linearizable) => Box::new(BstSet::<LinearizableSize>::with_opts(t, opts)),
        ("bst", Naive) => Box::new(BstSet::<NaiveSize>::with_opts(t, opts)),
        ("bst", Lock) => Box::new(BstSet::<LockSize>::with_opts(t, opts)),
        ("bst", Handshake) => Box::new(BstSet::<HandshakeSize>::with_opts(t, opts)),
        ("bst", Optimistic) => Box::new(BstSet::<OptimisticSize>::with_opts(t, opts)),
        ("list", Baseline) => Box::new(LinkedListSet::<NoSize>::with_opts(t, opts)),
        ("list", Linearizable) => Box::new(LinkedListSet::<LinearizableSize>::with_opts(t, opts)),
        ("list", Naive) => Box::new(LinkedListSet::<NaiveSize>::with_opts(t, opts)),
        ("list", Lock) => Box::new(LinkedListSet::<LockSize>::with_opts(t, opts)),
        ("list", Handshake) => Box::new(LinkedListSet::<HandshakeSize>::with_opts(t, opts)),
        ("list", Optimistic) => Box::new(LinkedListSet::<OptimisticSize>::with_opts(t, opts)),
        _ => return None,
    })
}

/// A named way to build a fresh set for one measured run.
pub type SetFactory<'a> = &'a (dyn Fn(u64) -> Box<dyn ConcurrentSet> + Sync);

/// Measure mean workload throughput over fresh prefilled sets.
pub fn measure_workload(
    factory: SetFactory,
    scale: &BenchScale,
    cfg: &RunConfig,
    initial: u64,
) -> Stats {
    measure_metric(factory, scale, cfg, initial, |r| r.workload_throughput())
}

/// Measure mean size-thread throughput.
pub fn measure_size_tput(
    factory: SetFactory,
    scale: &BenchScale,
    cfg: &RunConfig,
    initial: u64,
) -> Stats {
    measure_metric(factory, scale, cfg, initial, |r| r.size_throughput())
}

fn measure_metric(
    factory: SetFactory,
    scale: &BenchScale,
    cfg: &RunConfig,
    initial: u64,
    metric: impl Fn(&crate::harness::RunResult) -> f64,
) -> Stats {
    let mut samples = Vec::new();
    for i in 0..(scale.repeat.warmup + scale.repeat.runs) {
        let set = factory(initial);
        workload::prefill(set.as_ref(), initial, cfg.key_range, scale.seed ^ 0xF111);
        let res = run(set.as_ref(), cfg);
        if i >= scale.repeat.warmup {
            samples.push(metric(&res));
        }
        crate::ebr::collect();
    }
    Stats::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_all_structures_and_policies() {
        for structure in STRUCTURES {
            for policy in PolicyKind::ALL {
                let set = make_set(structure, policy, 256)
                    .unwrap_or_else(|| panic!("no factory for {structure}/{policy:?}"));
                assert!(set.insert(7), "{structure}/{policy:?} insert");
                assert!(set.contains(7));
                if policy.provides_size() {
                    assert_eq!(set.size(), Some(1), "{structure}/{policy:?}");
                    assert_eq!(
                        set.size_exact().map(|v| v.value),
                        Some(1),
                        "{structure}/{policy:?} size_exact"
                    );
                    assert_eq!(
                        set.size_recent(std::time::Duration::from_secs(1))
                            .map(|v| v.value),
                        Some(1),
                        "{structure}/{policy:?} size_recent"
                    );
                } else {
                    assert_eq!(set.size(), None, "{structure}/{policy:?}");
                    assert_eq!(set.size_exact(), None, "{structure}/{policy:?}");
                }
                assert!(
                    set.size_stats().is_some(),
                    "{structure}/{policy:?} must expose arbiter stats"
                );
            }
        }
        assert!(make_set("btree", PolicyKind::Baseline, 0).is_none());
    }

    #[test]
    fn opts_factory_threads_the_sharded_mirror() {
        for structure in STRUCTURES {
            for (policy, mirrored) in [
                (PolicyKind::Linearizable, true),
                (PolicyKind::Optimistic, true),
                (PolicyKind::Handshake, false), // no calculator => no mirror
            ] {
                let opts = SizeOpts::default().with_shards(2);
                let set = make_set_opts(structure, policy, 64, opts).unwrap();
                for k in 1..=5u64 {
                    set.insert(k);
                }
                if mirrored {
                    assert_eq!(
                        set.size_estimate(),
                        Some(5),
                        "{structure}/{policy:?} estimate at quiescence"
                    );
                } else {
                    assert_eq!(set.size_estimate(), None, "{structure}/{policy:?}");
                }
                // Default opts keep the mirror off everywhere.
                let plain = make_set(structure, policy, 64).unwrap();
                plain.insert(1);
                assert_eq!(plain.size_estimate(), None, "{structure}/{policy:?}");
            }
        }
    }
}

/// Figure 1 schedule: a writer inserts a fresh key while a prober runs
/// `contains(k)` then `size()`; an anomaly is `contains == true` with
/// `size == 0` (paper Fig. 1). Returns the number of anomalous trials.
pub fn fig1_anomalies(set: &dyn ConcurrentSet, trials: usize) -> usize {
    use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
    let mut anomalies = 0;
    for k in 1..=trials as u64 {
        let hit = AtomicBool::new(false);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                set.insert(k);
            });
            scope.spawn(|| {
                if set.contains(k) && set.size().unwrap() == 0 {
                    hit.store(true, SeqCst);
                }
            });
        });
        anomalies += hit.load(SeqCst) as usize;
        set.delete(k);
    }
    anomalies
}

/// Figure 2 schedule: per round, `T_ins` inserts a fresh key and `T_del`
/// races to delete it (its decrement can land before the insert's delayed
/// increment); the prober counts negative `size()` results (paper Fig. 2).
pub fn fig2_anomalies(set: &dyn ConcurrentSet, rounds: usize) -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering::SeqCst};
    let negatives = AtomicUsize::new(0);
    for k in 1..=rounds as u64 {
        std::thread::scope(|scope| {
            scope.spawn(|| {
                set.insert(k); // T_ins (its metadata update may lag)
            });
            scope.spawn(|| {
                while !set.delete(k) {
                    std::hint::spin_loop(); // T_del: delete as soon as visible
                }
            });
            scope.spawn(|| {
                for _ in 0..64 {
                    if set.size().unwrap() < 0 {
                        negatives.fetch_add(1, SeqCst);
                        break;
                    }
                }
            });
        });
    }
    negatives.load(SeqCst)
}

/// The Figures 7–9 experiment: baseline vs transformed workload throughput
/// across the thread ladder, with and without a concurrent size thread.
pub fn overhead_figure(
    figure: &str,
    structure: &str,
    baseline: SetFactory,
    transformed: SetFactory,
    scale: &BenchScale,
) {
    println!("=== {figure}: overhead on {structure} operations ===");
    println!(
        "(initial={} secs={} runs={}; paper setup: 1M keys, 5s, 10 runs, 64 hw threads)",
        scale.initial, scale.secs, scale.repeat.runs
    );
    for mix in MIXES {
        for size_thread in [0usize, 1] {
            println!(
                "\n-- {} workload{} --",
                mix.label(),
                if size_thread == 1 {
                    " + 1 concurrent size thread"
                } else {
                    ""
                }
            );
            let mut table = Table::new(&[
                "w",
                "baseline ops/s",
                &format!("{structure}+size ops/s"),
                "ratio %",
                "CoV %",
            ]);
            for &w in &scale.threads {
                let cfg_base = scale.config(w, 0, mix, scale.initial);
                let base = measure_workload(baseline, scale, &cfg_base, scale.initial);
                let cfg_tr = scale.config(w, size_thread, mix, scale.initial);
                let tr = measure_workload(transformed, scale, &cfg_tr, scale.initial);
                table.row(&[
                    w.to_string(),
                    fmt_rate(base.mean),
                    fmt_rate(tr.mean),
                    format!("{:.1}", 100.0 * tr.mean / base.mean),
                    format!("{:.1}", 100.0 * base.cov().max(tr.cov())),
                ]);
            }
            table.print();
        }
    }
}
