//! Non-blocking external binary search tree (Ellen, Fatourou, Ruppert,
//! van Breugel, PODC 2010), generic over the size policy.
//!
//! Keys live in leaves; internal nodes route. Updates coordinate through
//! per-internal-node `update` words (`info-pointer | state`), with states
//! CLEAN / IFLAG / DFLAG / MARK and helping.
//!
//! ## The paper's adaptation (Section 4.2 / Section 9)
//!
//! The original tree linearizes `delete` at the *unlinking* (dchild CAS).
//! The size methodology requires delete to linearize at the *marking* step,
//! so — like the authors — we use the variant where a successful delete is
//! linearized at the MARK CAS on the parent; the packed delete `UpdateInfo`
//! rides inside the operation's `Info` record (installed atomically with
//! the flag/mark, paper Section 4: "a deleteInfo field ... may be simply
//! placed inside that object"). `helpMarked` updates the size metadata
//! **before** the dchild unlink, and operations that observe a marked
//! parent targeting their leaf help the delete reach its metadata
//! linearization point before treating the key as absent.

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Arc;

use crate::ebr;
use crate::set_api::ConcurrentSet;
use crate::size::{RefresherSlot, SizeArbiter, SizeCore, SizeOpts, SizePolicy};
use crate::thread_id;

/// Sentinel keys (Ellen et al.'s ∞1 < ∞2). Application keys must be
/// `< INF1`.
const INF1: u64 = u64::MAX - 1;
const INF2: u64 = u64::MAX;
/// Largest insertable key for the BST.
pub const BST_MAX_KEY: u64 = u64::MAX - 2;

// update-word states (low 2 bits of the info pointer)
const CLEAN: u64 = 0;
const IFLAG: u64 = 1;
const DFLAG: u64 = 2;
const MARK: u64 = 3;
const STATE_MASK: u64 = 3;

#[inline]
fn state(word: u64) -> u64 {
    word & STATE_MASK
}

#[inline]
fn info_ptr<P: SizePolicy>(word: u64) -> *mut Info<P> {
    (word & !STATE_MASK) as *mut Info<P>
}

struct BstNode<P: SizePolicy> {
    key: u64,
    leaf: bool,
    /// Dictionary payload; leaves only (an upsert over an existing key
    /// overwrites it in place — per-key atomic, not part of the
    /// membership protocol).
    value: AtomicU64,
    left: AtomicU64,
    right: AtomicU64,
    /// `info-pointer | state`; internal nodes only.
    update: AtomicU64,
    /// Published insert `UpdateInfo`; leaves only.
    insert_info: P::InfoSlot,
}

impl<P: SizePolicy> BstNode<P> {
    fn leaf(key: u64, value: u64) -> *mut Self {
        Box::into_raw(Box::new(BstNode {
            key,
            leaf: true,
            value: AtomicU64::new(value),
            left: AtomicU64::new(0),
            right: AtomicU64::new(0),
            update: AtomicU64::new(0),
            insert_info: P::InfoSlot::default(),
        }))
    }

    fn internal(key: u64, left: u64, right: u64) -> *mut Self {
        Box::into_raw(Box::new(BstNode {
            key,
            leaf: false,
            value: AtomicU64::new(0),
            left: AtomicU64::new(left),
            right: AtomicU64::new(right),
            update: AtomicU64::new(0),
            insert_info: P::InfoSlot::default(),
        }))
    }
}

#[derive(Clone, Copy, PartialEq)]
#[allow(dead_code)] // kept for debugging/teardown diagnostics
enum InfoKind {
    Insert,
    Delete,
}

/// Unified IInfo/DInfo record (one type so teardown can free type-erased
/// pointers parked in CLEAN update words).
struct Info<P: SizePolicy> {
    #[allow(dead_code)] // diagnostic tag; state bits carry the live kind
    kind: InfoKind,
    gparent: *mut BstNode<P>,
    parent: *mut BstNode<P>,
    leaf: *mut BstNode<P>,
    new_internal: *mut BstNode<P>,
    /// The parent's update word captured before flagging (DInfo).
    pupdate: u64,
    /// Packed size `UpdateInfo` of the delete (paper: the `deleteInfo`
    /// field placed inside the operation record). 0 when untracked.
    packed_delete: u64,
}

unsafe impl<P: SizePolicy> Send for Info<P> {}
unsafe impl<P: SizePolicy> Sync for Info<P> {}

struct SearchResult<P: SizePolicy> {
    gparent: *mut BstNode<P>,
    parent: *mut BstNode<P>,
    leaf: *mut BstNode<P>,
    pupdate: u64,
    gpupdate: u64,
}

pub struct BstSet<P: SizePolicy> {
    root: *mut BstNode<P>,
    /// Policy + arbiter, shared with the optional refresher daemon.
    core: Arc<SizeCore<P>>,
    graveyard: Graveyard,
    refresher: RefresherSlot,
}

unsafe impl<P: SizePolicy> Send for BstSet<P> {}
unsafe impl<P: SizePolicy> Sync for BstSet<P> {}

impl<P: SizePolicy> BstSet<P> {
    pub fn new(max_threads: usize) -> Self {
        Self::with_opts(max_threads, SizeOpts::default())
    }

    pub fn with_opts(max_threads: usize, opts: SizeOpts) -> Self {
        Self::with_policy(P::new(max_threads, opts))
    }

    pub fn with_policy(policy: P) -> Self {
        let l1 = BstNode::<P>::leaf(INF1, 0);
        let l2 = BstNode::<P>::leaf(INF2, 0);
        Self {
            root: BstNode::<P>::internal(INF2, l1 as u64, l2 as u64),
            core: Arc::new(SizeCore::new(policy)),
            graveyard: Graveyard::new(),
            refresher: RefresherSlot::new(),
        }
    }

    pub fn policy(&self) -> &P {
        &self.core.policy
    }

    /// The combining size arbiter behind `size_exact` / `size_recent`.
    pub fn arbiter(&self) -> &SizeArbiter {
        &self.core.arbiter
    }

    /// Ellen et al. Search: returns gparent/parent/leaf and the update
    /// words read *before* following the child pointers.
    fn search(&self, k: u64) -> SearchResult<P> {
        let mut gparent: *mut BstNode<P> = std::ptr::null_mut();
        let mut parent: *mut BstNode<P> = std::ptr::null_mut();
        let mut gpupdate = 0u64;
        let mut pupdate = 0u64;
        let mut l = self.root;
        while !unsafe { &*l }.leaf {
            gparent = parent;
            parent = l;
            gpupdate = pupdate;
            let p = unsafe { &*parent };
            pupdate = p.update.load(SeqCst);
            l = if k < p.key {
                p.left.load(SeqCst) as *mut BstNode<P>
            } else {
                p.right.load(SeqCst) as *mut BstNode<P>
            };
        }
        SearchResult {
            gparent,
            parent,
            leaf: l,
            pupdate,
            gpupdate,
        }
    }

    /// Swap `old` for `new` among `parent`'s children (side determined by
    /// the current value — a child pointer never migrates sides).
    fn cas_child(parent: *mut BstNode<P>, old: u64, new: u64) -> bool {
        let p = unsafe { &*parent };
        if p.left.load(SeqCst) == old {
            p.left.compare_exchange(old, new, SeqCst, SeqCst).is_ok()
        } else if p.right.load(SeqCst) == old {
            p.right.compare_exchange(old, new, SeqCst, SeqCst).is_ok()
        } else {
            false
        }
    }

    /// Generic helping dispatch on an update word.
    fn help(&self, word: u64) {
        if word == 0 {
            return;
        }
        let info = info_ptr::<P>(word);
        match state(word) {
            IFLAG => self.help_insert_op(info),
            MARK => self.help_marked(info),
            DFLAG => {
                self.help_delete_op(info);
            }
            _ => {}
        }
    }

    /// IFLAG helper: perform the ichild CAS, then unflag.
    fn help_insert_op(&self, info: *mut Info<P>) {
        let i = unsafe { &*info };
        Self::cas_child(i.parent, i.leaf as u64, i.new_internal as u64);
        let flag_word = info as u64 | IFLAG;
        let _ = unsafe { &*i.parent }.update.compare_exchange(
            flag_word,
            info as u64 | CLEAN,
            SeqCst,
            SeqCst,
        );
    }

    /// DFLAG helper: try to MARK the parent; on success finish via
    /// [`Self::help_marked`], otherwise help the obstruction and unflag.
    /// Returns whether the delete operation owning `info` succeeded.
    fn help_delete_op(&self, info: *mut Info<P>) -> bool {
        let d = unsafe { &*info };
        let mark_word = info as u64 | MARK;
        let p_update = unsafe { &*d.parent }.update.compare_exchange(
            d.pupdate,
            mark_word,
            SeqCst,
            SeqCst,
        );
        match p_update {
            Ok(_) => {
                // The MARK CAS is the (adapted) original linearization point
                // of the delete. Retire the info parked in the replaced
                // CLEAN word.
                self.park_info(d.pupdate);
                self.help_marked(info);
                true
            }
            Err(witnessed) if witnessed == mark_word => {
                self.help_marked(info); // another helper marked for us
                true
            }
            Err(witnessed) => {
                self.help(witnessed);
                // Backtrack: unflag the grandparent (same info pointer).
                let _ = unsafe { &*d.gparent }.update.compare_exchange(
                    info as u64 | DFLAG,
                    info as u64 | CLEAN,
                    SeqCst,
                    SeqCst,
                );
                false
            }
        }
    }

    /// MARK helper. Paper adaptation: the delete's metadata is updated
    /// **before** the dchild unlink (Section 4: "Metadata is updated before
    /// unlinking a marked node").
    fn help_marked(&self, info: *mut Info<P>) {
        let d = unsafe { &*info };
        if P::TRACKED {
            self.core.policy.commit_delete(d.packed_delete);
        }
        let p = unsafe { &*d.parent };
        let l = d.leaf as u64;
        let left = p.left.load(SeqCst);
        let sibling = if left == l { p.right.load(SeqCst) } else { left };
        if Self::cas_child(d.gparent, d.parent as u64, sibling) {
            self.graveyard.push(d.parent as u64);
            self.graveyard.push(d.leaf as u64);
        }
        let _ = unsafe { &*d.gparent }.update.compare_exchange(
            info as u64 | DFLAG,
            info as u64 | CLEAN,
            SeqCst,
            SeqCst,
        );
    }

    /// Park the info record of a replaced CLEAN update word.
    fn park_info(&self, word: u64) {
        let ptr = info_ptr::<P>(word);
        if !ptr.is_null() {
            self.graveyard.push(ptr as u64 | GRAVE_INFO);
        }
    }

    /// Is `leaf` the target of a MARK on its parent (i.e., logically
    /// deleted under the adapted linearization)? Returns its packed
    /// delete-info.
    fn marked_delete_of(pupdate: u64, leaf: *mut BstNode<P>) -> Option<u64> {
        if state(pupdate) == MARK {
            let d = unsafe { &*info_ptr::<P>(pupdate) };
            if d.leaf == leaf {
                return Some(d.packed_delete);
            }
        }
        None
    }

    /// Quiescent full count of real leaves (tests).
    pub fn quiescent_count(&self) -> usize {
        fn walk<P: SizePolicy>(node: *mut BstNode<P>) -> usize {
            let n = unsafe { &*node };
            if n.leaf {
                return usize::from(n.key < INF1);
            }
            walk::<P>(n.left.load(SeqCst) as *mut BstNode<P>)
                + walk::<P>(n.right.load(SeqCst) as *mut BstNode<P>)
        }
        let _g = ebr::pin();
        walk::<P>(self.root)
    }

    /// In-order range collect: push every live `(key, value)` with
    /// `lo <= key <= hi` onto `out`, sorted, pruning subtrees outside the
    /// range. A leaf's logical deletion is decided against its parent's
    /// update word (read *before* following the child pointer, as
    /// `search` does); observed marked deletes are committed and pending
    /// inserts helped, so any tracked update the traversal could half-see
    /// bumps a counter and invalidates the surrounding double-collect.
    /// Caller must hold an EBR pin.
    fn collect_range(&self, lo: u64, hi: u64, out: &mut Vec<(u64, u64)>) {
        fn visit<P: SizePolicy>(
            set: &BstSet<P>,
            child: *mut BstNode<P>,
            pupdate: u64,
            lo: u64,
            hi: u64,
            out: &mut Vec<(u64, u64)>,
        ) {
            let c = unsafe { &*child };
            if !c.leaf {
                walk(set, child, lo, hi, out);
                return;
            }
            if c.key < lo || c.key > hi || c.key >= INF1 {
                return;
            }
            if let Some(dpacked) = BstSet::<P>::marked_delete_of(pupdate, child) {
                if P::TRACKED {
                    set.core.policy.commit_delete(dpacked);
                }
                return;
            }
            set.core.policy.help_insert(&c.insert_info);
            out.push((c.key, c.value.load(SeqCst)));
        }
        fn walk<P: SizePolicy>(
            set: &BstSet<P>,
            node: *mut BstNode<P>,
            lo: u64,
            hi: u64,
            out: &mut Vec<(u64, u64)>,
        ) {
            let n = unsafe { &*node };
            let pupdate = n.update.load(SeqCst);
            if lo < n.key {
                let left = n.left.load(SeqCst) as *mut BstNode<P>;
                visit(set, left, pupdate, lo, hi, out);
            }
            if hi >= n.key {
                let right = n.right.load(SeqCst) as *mut BstNode<P>;
                visit(set, right, pupdate, lo, hi, out);
            }
        }
        walk(self, self.root, lo, hi, out);
    }
}

/// Structure-lifetime deferred reclamation (see the skip list's
/// `Graveyard` rationale in DESIGN.md): retired nodes and info records are
/// parked and freed at `Drop`, deduplicated against the reachability walk,
/// eliminating any use-after-free window in the helping protocol.
struct Graveyard {
    head: AtomicU64,
}

struct GraveEntry {
    /// Tagged pointer: bit 0 set = info record, clear = tree node.
    tagged: u64,
    next: u64,
}

const GRAVE_INFO: u64 = 1;

impl Graveyard {
    fn new() -> Self {
        Self {
            head: AtomicU64::new(0),
        }
    }

    fn push(&self, tagged: u64) {
        let entry = Box::into_raw(Box::new(GraveEntry { tagged, next: 0 }));
        loop {
            let head = self.head.load(SeqCst);
            unsafe { &mut *entry }.next = head;
            if self.head.compare_exchange(head, entry as u64, SeqCst, SeqCst).is_ok() {
                return;
            }
        }
    }

    fn drain(&self) -> Vec<u64> {
        let mut out = Vec::new();
        let mut e = self.head.swap(0, SeqCst) as *mut GraveEntry;
        while !e.is_null() {
            let entry = unsafe { Box::from_raw(e) };
            out.push(entry.tagged);
            e = entry.next as *mut GraveEntry;
        }
        out
    }
}

impl<P: SizePolicy> BstSet<P> {
    /// Upsert engine shared by `insert` (`v = 0`, no overwrite) and `put`
    /// (overwrite): the original Ellen et al. insert with a value payload
    /// published with the new leaf.
    fn put_with(&self, k: u64, v: u64, overwrite: bool) -> bool {
        debug_assert!(k <= BST_MAX_KEY);
        let _guard = ebr::pin();
        let _op = self.core.policy.enter();
        let tid = thread_id::current();

        let packed = self.core.policy.begin_insert(tid);
        let mut new_leaf: *mut BstNode<P> = std::ptr::null_mut();
        let mut new_internal: *mut BstNode<P> = std::ptr::null_mut();

        loop {
            let s = self.search(k);
            let l = unsafe { &*s.leaf };
            if l.key == k {
                // Present — unless a linearized (marked) delete targets it,
                // in which case help it finish, then retry (Fig. 3 ll.19-21).
                if let Some(dpacked) = Self::marked_delete_of(s.pupdate, s.leaf) {
                    if P::TRACKED {
                        self.core.policy.commit_delete(dpacked);
                    }
                    self.help(s.pupdate);
                    continue;
                }
                self.core.policy.help_insert(&l.insert_info); // Fig. 3 ll.17-18
                if overwrite {
                    l.value.store(v, SeqCst);
                }
                unsafe { free_unpublished(new_leaf, new_internal) };
                return false;
            }
            if state(s.pupdate) != CLEAN {
                self.help(s.pupdate);
                continue;
            }
            if new_leaf.is_null() {
                new_leaf = BstNode::<P>::leaf(k, v);
                P::stash_insert_info(unsafe { &(*new_leaf).insert_info }, packed);
                new_internal = BstNode::<P>::internal(0, 0, 0);
            }
            // (Re)aim the new internal node at the current sibling leaf.
            let ni = unsafe { &mut *new_internal };
            ni.key = k.max(l.key);
            if k < l.key {
                *ni.left.get_mut() = new_leaf as u64;
                *ni.right.get_mut() = s.leaf as u64;
            } else {
                *ni.left.get_mut() = s.leaf as u64;
                *ni.right.get_mut() = new_leaf as u64;
            }
            let info = Box::into_raw(Box::new(Info::<P> {
                kind: InfoKind::Insert,
                gparent: std::ptr::null_mut(),
                parent: s.parent,
                leaf: s.leaf,
                new_internal,
                pupdate: 0,
                packed_delete: 0,
            }));
            match unsafe { &*s.parent }.update.compare_exchange(
                s.pupdate,
                info as u64 | IFLAG,
                SeqCst,
                SeqCst,
            ) {
                Ok(_) => {
                    self.park_info(s.pupdate);
                    self.help_insert_op(info);
                    // Original linearization (ichild) passed: reach the new
                    // linearization point (Fig. 3 line 25).
                    self.core
                        .policy
                        .commit_insert(unsafe { &(*new_leaf).insert_info }, packed);
                    return true;
                }
                Err(witnessed) => {
                    drop(unsafe { Box::from_raw(info) }); // never published
                    self.help(witnessed);
                }
            }
        }
    }
}

impl<P: SizePolicy> ConcurrentSet for BstSet<P> {
    fn insert(&self, k: u64) -> bool {
        self.put_with(k, 0, false)
    }

    fn put(&self, k: u64, v: u64) -> bool {
        self.put_with(k, v, true)
    }

    fn get(&self, k: u64) -> Option<u64> {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter_read();

        let s = self.search(k);
        let l = unsafe { &*s.leaf };
        if l.key != k {
            return None;
        }
        if let Some(dpacked) = Self::marked_delete_of(s.pupdate, s.leaf) {
            // Logically deleted under the adapted linearization: help its
            // metadata before reporting absence (Fig. 3 ll.12-13).
            if P::TRACKED {
                self.core.policy.commit_delete(dpacked);
            }
            return None;
        }
        self.core.policy.help_insert(&l.insert_info); // Fig. 3 ll.9-10
        Some(l.value.load(SeqCst))
    }

    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter_read();
        let (pairs, _validated) =
            crate::size::validated_collect(self.core.policy.calculator(), || {
                let mut out = Vec::new();
                self.collect_range(lo, hi, &mut out);
                out
            });
        Some(pairs)
    }

    fn delete(&self, k: u64) -> bool {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter();
        let tid = thread_id::current();

        let packed = self.core.policy.begin_delete(tid);

        loop {
            let s = self.search(k);
            let l = unsafe { &*s.leaf };
            if l.key != k {
                return false; // Fig. 3 line 29
            }
            // Fig. 3 line 33: ensure the found node's insert is linearized.
            self.core.policy.help_insert(&l.insert_info);
            // Found but already logically deleted (marked): help its
            // metadata, fail (Fig. 3 ll.30-32).
            if let Some(dpacked) = Self::marked_delete_of(s.pupdate, s.leaf) {
                if P::TRACKED {
                    self.core.policy.commit_delete(dpacked);
                }
                return false;
            }
            if state(s.gpupdate) != CLEAN {
                self.help(s.gpupdate);
                continue;
            }
            if state(s.pupdate) != CLEAN {
                self.help(s.pupdate);
                continue;
            }
            if s.gparent.is_null() {
                return false; // only sentinel leaves sit at depth 1
            }
            let info = Box::into_raw(Box::new(Info::<P> {
                kind: InfoKind::Delete,
                gparent: s.gparent,
                parent: s.parent,
                leaf: s.leaf,
                new_internal: std::ptr::null_mut(),
                pupdate: s.pupdate,
                packed_delete: packed,
            }));
            match unsafe { &*s.gparent }.update.compare_exchange(
                s.gpupdate,
                info as u64 | DFLAG,
                SeqCst,
                SeqCst,
            ) {
                Ok(_) => {
                    self.park_info(s.gpupdate);
                    if self.help_delete_op(info) {
                        if !P::TRACKED {
                            self.core.policy.commit_delete(0); // naive/lock bump
                        }
                        return true;
                    }
                    // Backtracked: retry with a fresh info record.
                }
                Err(witnessed) => {
                    drop(unsafe { Box::from_raw(info) }); // never published
                    self.help(witnessed);
                }
            }
        }
    }

    fn contains(&self, k: u64) -> bool {
        // The helping lookup lives in `get` (Fig. 3 ll.6-13).
        self.get(k).is_some()
    }

    crate::size::impl_size_surface!();

    fn name(&self) -> String {
        format!(
            "BST<{}>",
            std::any::type_name::<P>().rsplit("::").next().unwrap()
        )
    }
}

/// Free insert-path allocations that were never published.
unsafe fn free_unpublished<P: SizePolicy>(
    new_leaf: *mut BstNode<P>,
    new_internal: *mut BstNode<P>,
) {
    if !new_leaf.is_null() {
        drop(unsafe { Box::from_raw(new_leaf) });
    }
    if !new_internal.is_null() {
        drop(unsafe { Box::from_raw(new_internal) });
    }
}

impl<P: SizePolicy> Drop for BstSet<P> {
    fn drop(&mut self) {
        // Free nodes and info records exactly once: the union of the
        // reachability walk (nodes + infos parked in CLEAN update words)
        // and the graveyard, deduplicated.
        let mut nodes = std::collections::HashSet::new();
        let mut infos = std::collections::HashSet::new();
        let mut stack = vec![self.root];
        while let Some(node) = stack.pop() {
            if !nodes.insert(node as usize) {
                continue;
            }
            let n = unsafe { &*node };
            if !n.leaf {
                stack.push(n.left.load(SeqCst) as *mut BstNode<P>);
                stack.push(n.right.load(SeqCst) as *mut BstNode<P>);
                let info = info_ptr::<P>(n.update.load(SeqCst));
                if !info.is_null() {
                    infos.insert(info as usize);
                }
            }
        }
        for tagged in self.graveyard.drain() {
            if tagged & GRAVE_INFO != 0 {
                infos.insert((tagged & !GRAVE_INFO) as usize);
            } else {
                nodes.insert(tagged as usize);
            }
        }
        for &n in &nodes {
            drop(unsafe { Box::from_raw(n as *mut BstNode<P>) });
        }
        for &i in &infos {
            drop(unsafe { Box::from_raw(i as *mut Info<P>) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NoSize};
    use std::sync::Arc;

    fn bst() -> BstSet<LinearizableSize> {
        BstSet::new(crate::MAX_THREADS)
    }

    #[test]
    fn basic_ops() {
        let t = bst();
        assert!(!t.contains(10));
        assert!(t.insert(10));
        assert!(!t.insert(10));
        assert!(t.contains(10));
        assert!(t.delete(10));
        assert!(!t.delete(10));
        assert!(!t.contains(10));
        assert_eq!(t.size(), Some(0));
    }

    #[test]
    fn sequential_bulk() {
        let t = bst();
        for k in 0..1000u64 {
            assert!(t.insert(k));
        }
        assert_eq!(t.size(), Some(1000));
        assert_eq!(t.quiescent_count(), 1000);
        for k in (0..1000u64).step_by(3) {
            assert!(t.delete(k));
        }
        let expected = 1000 - 1000usize.div_ceil(3);
        assert_eq!(t.size(), Some(expected as i64));
        assert_eq!(t.quiescent_count(), expected);
    }

    #[test]
    fn random_shape() {
        let t = bst();
        let mut rng = crate::rng::Xoshiro256::new(13);
        let mut model = std::collections::BTreeSet::new();
        for _ in 0..4000 {
            let k = rng.gen_range(300);
            match rng.gen_range(3) {
                0 => assert_eq!(t.insert(k), model.insert(k), "insert {k}"),
                1 => assert_eq!(t.delete(k), model.remove(&k), "delete {k}"),
                _ => assert_eq!(t.contains(k), model.contains(&k), "contains {k}"),
            }
        }
        assert_eq!(t.size(), Some(model.len() as i64));
        assert_eq!(t.quiescent_count(), model.len());
    }

    #[test]
    fn dictionary_scan_matches_model() {
        let t = bst();
        let mut rng = crate::rng::Xoshiro256::new(41);
        let mut model = std::collections::BTreeMap::new();
        for _ in 0..2000 {
            let k = rng.gen_range(400);
            match rng.gen_range(3) {
                0 => {
                    let v = rng.next_u64() >> 1;
                    assert_eq!(t.put(k, v), model.insert(k, v).is_none(), "put {k}");
                }
                1 => assert_eq!(t.delete(k), model.remove(&k).is_some(), "delete {k}"),
                _ => assert_eq!(t.get(k), model.get(&k).copied(), "get {k}"),
            }
        }
        let want: Vec<_> = model.range(50..=350).map(|(&k, &v)| (k, v)).collect();
        assert_eq!(t.scan(50, 350), Some(want));
        assert_eq!(
            t.count_range(0, BST_MAX_KEY),
            Some(model.len() as i64)
        );
    }

    #[test]
    fn baseline_bst_without_size() {
        let t: BstSet<NoSize> = BstSet::new(crate::MAX_THREADS);
        assert!(t.insert(5));
        assert!(t.contains(5));
        assert_eq!(t.size(), None);
        assert!(t.delete(5));
        assert_eq!(t.quiescent_count(), 0);
    }

    #[test]
    fn concurrent_disjoint_inserts() {
        let t = Arc::new(bst());
        let hs: Vec<_> = (0..4u64)
            .map(|i| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for k in (i * 10_000)..(i * 10_000 + 500) {
                        assert!(t.insert(k));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.size(), Some(2000));
        assert_eq!(t.quiescent_count(), 2000);
    }

    #[test]
    fn concurrent_same_key_single_winner() {
        for _ in 0..30 {
            let t = Arc::new(bst());
            let ins: Vec<_> = (0..4)
                .map(|_| {
                    let t = t.clone();
                    std::thread::spawn(move || t.insert(7) as usize)
                })
                .collect();
            assert_eq!(ins.into_iter().map(|h| h.join().unwrap()).sum::<usize>(), 1);
            let dels: Vec<_> = (0..4)
                .map(|_| {
                    let t = t.clone();
                    std::thread::spawn(move || t.delete(7) as usize)
                })
                .collect();
            assert_eq!(
                dels.into_iter().map(|h| h.join().unwrap()).sum::<usize>(),
                1
            );
            assert_eq!(t.size(), Some(0));
        }
    }

    #[test]
    fn churn_size_in_bounds() {
        let t = Arc::new(bst());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..4u64)
            .map(|i| {
                let t = t.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(i + 31);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(100);
                        if rng.gen_bool(0.5) {
                            t.insert(k);
                        } else {
                            t.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..800 {
            let s = t.size().unwrap();
            assert!((0..=100).contains(&s), "size {s} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(t.size().unwrap() as usize, t.quiescent_count());
    }

    #[test]
    fn interleaved_insert_delete_same_keys() {
        let t = Arc::new(bst());
        let hs: Vec<_> = (0..4u64)
            .map(|i| {
                let t = t.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(i + 77);
                    for _ in 0..2500 {
                        let k = rng.gen_range(32);
                        if rng.gen_bool(0.5) {
                            t.insert(k);
                        } else {
                            t.delete(k);
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(t.size().unwrap() as usize, t.quiescent_count());
    }
}
