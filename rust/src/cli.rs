//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `csize <subcommand> [--key value]... [--flag]...`.
//! Benches reuse [`Args::from_env`] so every figure reproduction accepts
//! `--threads`, `--secs`, `--size`, `--runs`, ... overrides.

use std::collections::HashMap;

/// The six size policies selectable from every CLI surface (`csize bench
/// --policy`, the ablation benches, `kv_server --policy`): the paper's four
/// plus the synchronization-methods study's two optimized methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Untransformed structure; no `size()` at all.
    Baseline,
    /// The paper's wait-free linearizable size.
    Linearizable,
    /// Java-style counter-after-op; **not** linearizable (Figs. 1–2).
    Naive,
    /// Global reader-writer lock.
    Lock,
    /// Handshake-based method (arXiv 2506.16350): cheap updates, blocking
    /// size.
    Handshake,
    /// Optimistic double-collect with wait-free fallback (arXiv
    /// 2506.16350).
    Optimistic,
}

impl PolicyKind {
    /// Every policy, in ablation-report order.
    pub const ALL: [PolicyKind; 6] = [
        PolicyKind::Baseline,
        PolicyKind::Linearizable,
        PolicyKind::Naive,
        PolicyKind::Lock,
        PolicyKind::Handshake,
        PolicyKind::Optimistic,
    ];

    /// Parse a CLI spelling (the historical `size` alias maps to the
    /// paper's policy).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "baseline" | "nosize" => PolicyKind::Baseline,
            "size" | "linearizable" => PolicyKind::Linearizable,
            "naive" => PolicyKind::Naive,
            "lock" => PolicyKind::Lock,
            "handshake" => PolicyKind::Handshake,
            "optimistic" => PolicyKind::Optimistic,
            _ => return None,
        })
    }

    /// Canonical CLI / report name.
    pub fn label(self) -> &'static str {
        match self {
            PolicyKind::Baseline => "baseline",
            PolicyKind::Linearizable => "linearizable",
            PolicyKind::Naive => "naive",
            PolicyKind::Lock => "lock",
            PolicyKind::Handshake => "handshake",
            PolicyKind::Optimistic => "optimistic",
        }
    }

    /// Whether the policy implements `size()` at all.
    pub fn provides_size(self) -> bool {
        self != PolicyKind::Baseline
    }

    /// Whether the provided `size()` is linearizable.
    pub fn linearizable(self) -> bool {
        matches!(
            self,
            PolicyKind::Linearizable
                | PolicyKind::Lock
                | PolicyKind::Handshake
                | PolicyKind::Optimistic
        )
    }
}

/// The four ways a size thread (or server endpoint) can read the size,
/// selectable via `--size-call` on `csize bench` and the ablation bench:
/// the policy's raw `size()`, the arbiter's combining `size_exact()`, the
/// published bounded-staleness `size_recent()`, or `refresh` — the same
/// `size_recent()` with a background [`crate::size::SizeRefresher`]
/// keeping the publication warm, so reads are passive.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeCallKind {
    Raw,
    Exact,
    Recent,
    Refresh,
}

impl SizeCallKind {
    /// Every call kind, in ablation-report order.
    pub const ALL: [SizeCallKind; 4] = [
        SizeCallKind::Raw,
        SizeCallKind::Exact,
        SizeCallKind::Recent,
        SizeCallKind::Refresh,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "raw" => SizeCallKind::Raw,
            "exact" => SizeCallKind::Exact,
            "recent" => SizeCallKind::Recent,
            "refresh" => SizeCallKind::Refresh,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            SizeCallKind::Raw => "raw",
            SizeCallKind::Exact => "exact",
            SizeCallKind::Recent => "recent",
            SizeCallKind::Refresh => "refresh",
        }
    }
}

/// Parsed command line: one optional subcommand plus `--key [value]` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand unless it
    /// starts with `--`).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Self {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            }
            // bare positional tokens after the subcommand are ignored
        }
        out
    }

    /// Parse from `std::env::args()` (skipping argv[0], and a stray `--bench`
    /// token that `cargo bench` passes to harness=false benches).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1).filter(|a| a != "--bench"))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_u64(key, default as u64) as usize
    }

    /// Optional integer: `None` when the flag is absent (panics on
    /// garbage, like [`Self::get_u64`]). For flags whose mere presence
    /// changes behavior — `--admission-high` with no default makes
    /// admission control opt-in — so a value-less spelling (`--foo` with
    /// the value forgotten) fails loudly instead of silently reading as
    /// "absent" and disabling the feature the caller asked for.
    pub fn get_opt_u64(&self, key: &str) -> Option<u64> {
        if self.has_flag(key) {
            panic!("--{key} expects an integer value, got none");
        }
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated integer list, e.g. `--sizes 10000,100000`.
    pub fn get_u64_list(&self, key: &str, default: &[u64]) -> Vec<u64> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--{key}: bad integer {t:?}")))
                .collect(),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// The `--size-shards` convention shared by every CLI surface:
    /// absent → `default` stripes, `auto` → machine-detected
    /// ([`crate::size::detect_shards`]), `0` → mirror disabled, `N` → `N`
    /// stripes. Pass `0` as `default` to keep the mirror off unless asked.
    pub fn size_shards(&self, default: usize) -> usize {
        self.auto_shards("size-shards", default)
    }

    /// The `--store-shards` convention (same `auto|N` grammar as
    /// `--size-shards`, but for [`crate::shardstore::ShardStore`] store
    /// shards): absent → `default`, `auto` → machine-detected, `N` → `N`.
    /// `1` means a monolithic store.
    pub fn store_shards(&self, default: usize) -> usize {
        self.auto_shards("store-shards", default)
    }

    /// The `--reactors` convention (same `auto|N` grammar as the shard
    /// knobs, but for server reactor shards): absent → `default`,
    /// `auto` → machine-detected, `N` → `N` reactor threads. The server
    /// clamps the result to >= 1.
    pub fn reactors(&self, default: usize) -> usize {
        self.auto_shards("reactors", default)
    }

    fn auto_shards(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            None => default,
            Some("auto") => crate::size::detect_shards(),
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer or 'auto', got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = args("bench --threads 8 --secs 2");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get_u64("threads", 0), 8);
        assert_eq!(a.get_u64("secs", 0), 2);
    }

    #[test]
    fn flags_without_values() {
        let a = args("demo --verbose --runs 3");
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        assert_eq!(a.get_u64("runs", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = args("x");
        assert_eq!(a.get_u64("missing", 7), 7);
        assert_eq!(a.get_f64("missing", 0.5), 0.5);
    }

    #[test]
    fn optional_integers() {
        let a = args("serve --admission-high 1000");
        assert_eq!(a.get_opt_u64("admission-high"), Some(1000));
        assert_eq!(a.get_opt_u64("admission-low"), None);
    }

    #[test]
    #[should_panic(expected = "--admission-high expects an integer")]
    fn optional_integer_rejects_garbage() {
        args("serve --admission-high lots").get_opt_u64("admission-high");
    }

    #[test]
    #[should_panic(expected = "--admission-high expects an integer value, got none")]
    fn optional_integer_rejects_valueless_flag() {
        // `--admission-high` with the value forgotten (next token is
        // another flag) must not silently read as "absent".
        args("serve --admission-high --listen 1:2").get_opt_u64("admission-high");
    }

    #[test]
    fn no_subcommand_when_leading_flag() {
        let a = args("--threads 4");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_u64("threads", 0), 4);
    }

    #[test]
    fn integer_lists() {
        let a = args("b --sizes 10,20,30");
        assert_eq!(a.get_u64_list("sizes", &[1]), vec![10, 20, 30]);
        assert_eq!(a.get_u64_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "--threads expects an integer")]
    fn bad_integer_panics() {
        args("b --threads abc").get_u64("threads", 0);
    }

    #[test]
    fn policy_kind_parses_all_spellings() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(PolicyKind::parse("size"), Some(PolicyKind::Linearizable));
        assert_eq!(PolicyKind::parse("nosize"), Some(PolicyKind::Baseline));
        assert_eq!(PolicyKind::parse("bogus"), None);
    }

    #[test]
    fn size_call_kind_parses_all_spellings() {
        for kind in SizeCallKind::ALL {
            assert_eq!(SizeCallKind::parse(kind.label()), Some(kind));
        }
        assert_eq!(SizeCallKind::parse("bogus"), None);
    }

    #[test]
    fn size_shards_spellings() {
        assert_eq!(args("b").size_shards(0), 0);
        assert_eq!(args("b").size_shards(4), 4);
        assert_eq!(args("b --size-shards 6").size_shards(0), 6);
        assert_eq!(args("b --size-shards 0").size_shards(4), 0);
        let auto = args("b --size-shards auto").size_shards(0);
        assert!((1..=crate::MAX_THREADS).contains(&auto));
    }

    #[test]
    #[should_panic(expected = "--size-shards expects an integer or 'auto'")]
    fn size_shards_rejects_garbage() {
        args("b --size-shards many").size_shards(0);
    }

    #[test]
    fn store_shards_spellings() {
        assert_eq!(args("b").store_shards(1), 1);
        assert_eq!(args("b --store-shards 8").store_shards(1), 8);
        let auto = args("b --store-shards auto").store_shards(1);
        assert!((1..=crate::MAX_THREADS).contains(&auto));
    }

    #[test]
    #[should_panic(expected = "--store-shards expects an integer or 'auto'")]
    fn store_shards_rejects_garbage() {
        args("b --store-shards several").store_shards(1);
    }

    #[test]
    fn policy_kind_classification() {
        assert!(!PolicyKind::Baseline.provides_size());
        assert!(PolicyKind::Naive.provides_size());
        assert!(!PolicyKind::Naive.linearizable());
        for kind in [
            PolicyKind::Linearizable,
            PolicyKind::Lock,
            PolicyKind::Handshake,
            PolicyKind::Optimistic,
        ] {
            assert!(kind.provides_size() && kind.linearizable(), "{kind:?}");
        }
    }
}
