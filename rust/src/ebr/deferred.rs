//! Type-erased deferred destruction of a heap allocation.

/// A pointer plus the monomorphized dropper that knows its real type.
pub struct Deferred {
    ptr: *mut u8,
    dropper: unsafe fn(*mut u8),
}

// The pointee is required to be `Send` at construction, and `Deferred` is
// only ever executed once, by one thread.
unsafe impl Send for Deferred {}

impl Deferred {
    /// Wrap a `Box::into_raw` pointer.
    pub fn from_box_raw<T: Send>(ptr: *mut T) -> Self {
        unsafe fn drop_box<T>(p: *mut u8) {
            drop(unsafe { Box::from_raw(p.cast::<T>()) });
        }
        Self {
            ptr: ptr.cast(),
            dropper: drop_box::<T>,
        }
    }

    /// Run the destructor.
    ///
    /// # Safety
    /// Must be called exactly once, after no thread can reference the
    /// pointee.
    pub unsafe fn execute(self) {
        unsafe { (self.dropper)(self.ptr) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    #[test]
    fn executes_the_right_destructor() {
        struct Flag(Arc<AtomicBool>);
        impl Drop for Flag {
            fn drop(&mut self) {
                self.0.store(true, Ordering::SeqCst);
            }
        }
        let hit = Arc::new(AtomicBool::new(false));
        let d = Deferred::from_box_raw(Box::into_raw(Box::new(Flag(hit.clone()))));
        unsafe { d.execute() };
        assert!(hit.load(Ordering::SeqCst));
    }
}
