//! Epoch-based memory reclamation (EBR), from scratch.
//!
//! The Java original of the paper leans on the JVM garbage collector: a
//! node unlinked from a lock-free structure is freed only when no thread
//! can still hold a reference. This module provides the same guarantee:
//!
//! * every data-structure operation runs inside a [`pin`] [`Guard`];
//! * unlinked nodes (and replaced [`crate::size::CountersSnapshot`]
//!   instances) are [`retire`]d, not dropped;
//! * a retired object tagged with epoch `t` is freed only once the global
//!   epoch reaches `t + 2`, which requires every pinned thread to have
//!   passed through an unpinned state after the retirement — at which point
//!   no live reference can remain.
//!
//! The design is the classic 3-epoch scheme (Fraser 2004): a global epoch
//! counter, one padded per-thread-slot state word (`epoch << 1 | pinned`),
//! per-thread garbage bags tagged with the retirement epoch, and an orphan
//! list that adopts the bags of exiting threads. Pinning is wait-free;
//! collection is opportunistic and amortized.

mod deferred;

pub use deferred::Deferred;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Mutex;

use crate::pad::CachePadded;
use crate::thread_id;
use crate::MAX_THREADS;

/// Collect (attempt epoch advance + free) every this many retirements.
const COLLECT_THRESHOLD: usize = 64;

/// Global epoch. Starts at 1 so a state word of 0 unambiguously means
/// "not pinned".
static EPOCH: AtomicU64 = AtomicU64::new(1);

/// Per-slot state: `epoch << 1 | 1` while pinned, `0` while not.
static SLOT_STATE: [CachePadded<AtomicU64>; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const UNPINNED: CachePadded<AtomicU64> = CachePadded::new(AtomicU64::new(0));
    [UNPINNED; MAX_THREADS]
};

/// Bags of exited threads, adopted by future collections.
static ORPHANS: Mutex<Vec<(u64, Deferred)>> = Mutex::new(Vec::new());

/// Total objects freed by the reclaimer (test/diagnostic counter).
static FREED: AtomicU64 = AtomicU64::new(0);
/// Total objects retired (test/diagnostic counter).
static RETIRED: AtomicU64 = AtomicU64::new(0);

struct Local {
    garbage: Vec<(u64, Deferred)>,
    since_collect: usize,
}

impl Drop for Local {
    fn drop(&mut self) {
        if !self.garbage.is_empty() {
            ORPHANS.lock().unwrap().append(&mut self.garbage);
        }
    }
}

thread_local! {
    // Pin depth on the hot path is a plain Cell (every operation pins);
    // the garbage bags sit behind a RefCell touched only on retire/collect.
    static DEPTH: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
    static LOCAL: RefCell<Local> = RefCell::new(Local {
        garbage: Vec::new(),
        since_collect: 0,
    });
}

/// An active pin on the current thread. Operations may nest pins freely;
/// the slot is released when the outermost guard drops.
pub struct Guard {
    tid: usize,
}

impl Guard {
    /// The dense thread id of the pinned thread (also the metadata-counter
    /// index the size mechanism uses).
    #[inline]
    pub fn tid(&self) -> usize {
        self.tid
    }
}

impl Drop for Guard {
    #[inline]
    fn drop(&mut self) {
        let depth = DEPTH.with(|d| {
            let v = d.get() - 1;
            d.set(v);
            v
        });
        if depth == 0 {
            SLOT_STATE[self.tid].store(0, SeqCst);
        }
    }
}

/// Pin the current thread: while the returned [`Guard`] lives, no object
/// retired after this point will be freed. Wait-free.
#[inline]
pub fn pin() -> Guard {
    let tid = thread_id::current();
    let depth = DEPTH.with(|d| {
        let v = d.get() + 1;
        d.set(v);
        v
    });
    if depth == 1 {
        // Publish the epoch we are entering; re-check so the published
        // value is never older than the global epoch at publication.
        loop {
            let e = EPOCH.load(SeqCst);
            SLOT_STATE[tid].store((e << 1) | 1, SeqCst);
            if EPOCH.load(SeqCst) == e {
                break;
            }
        }
    }
    Guard { tid }
}

/// Whether the calling thread currently holds a pin (debug contract checks).
#[inline]
pub fn is_pinned() -> bool {
    DEPTH.with(|d| d.get() > 0)
}

/// Hand an unlinked, heap-allocated object to the reclaimer.
///
/// # Safety
/// `ptr` must come from `Box::into_raw`, be unreachable to any thread that
/// pins *after* this call, and not be retired twice.
pub unsafe fn retire<T: Send>(ptr: *mut T) {
    retire_deferred(Deferred::from_box_raw(ptr));
}

/// Variant taking a prebuilt [`Deferred`] (for type-erased call sites).
pub fn retire_deferred(d: Deferred) {
    RETIRED.fetch_add(1, SeqCst);
    let epoch = EPOCH.load(SeqCst);
    let should_collect = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.garbage.push((epoch, d));
        l.since_collect += 1;
        l.since_collect >= COLLECT_THRESHOLD
    });
    if should_collect {
        collect();
    }
}

/// Attempt an epoch advance and free everything that became safe.
/// Called automatically every [`COLLECT_THRESHOLD`] retirements; exposed
/// for tests and for structure teardown.
pub fn collect() {
    let ge = EPOCH.load(SeqCst);
    let mut can_advance = true;
    for slot in SLOT_STATE.iter() {
        let s = slot.load(SeqCst);
        if s & 1 == 1 && (s >> 1) != ge {
            can_advance = false;
            break;
        }
    }
    if can_advance {
        // A failed CAS means someone else advanced — equally good.
        let _ = EPOCH.compare_exchange(ge, ge + 1, SeqCst, SeqCst);
    }
    let safe = EPOCH.load(SeqCst);

    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        l.since_collect = 0;
        free_ready(&mut l.garbage, safe);
    });

    // Adopt orphans opportunistically (never on the fast path: only here).
    if let Ok(mut orphans) = ORPHANS.try_lock() {
        free_ready(&mut orphans, safe);
    }
}

fn free_ready(bag: &mut Vec<(u64, Deferred)>, safe_epoch: u64) {
    let mut i = 0;
    while i < bag.len() {
        if bag[i].0 + 2 <= safe_epoch {
            let (_, d) = bag.swap_remove(i);
            unsafe { d.execute() };
            FREED.fetch_add(1, SeqCst);
        } else {
            i += 1;
        }
    }
}

/// Repeatedly collect until the local + orphan bags drain (or `rounds`
/// attempts pass). Used by tests and `Drop` impls of whole structures.
pub fn flush(rounds: usize) {
    for _ in 0..rounds {
        collect();
        let done = LOCAL.with(|l| l.borrow().garbage.is_empty())
            && ORPHANS.lock().unwrap().is_empty();
        if done {
            return;
        }
    }
}

/// Called by the thread registry when a thread's slot is recycled.
pub(crate) fn on_thread_exit(tid: usize) {
    SLOT_STATE[tid].store(0, SeqCst);
}

/// Diagnostic counters: `(retired, freed)` so far, process-wide.
pub fn stats() -> (u64, u64) {
    (RETIRED.load(SeqCst), FREED.load(SeqCst))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    struct DropCounter(Arc<AtomicUsize>);
    impl Drop for DropCounter {
        fn drop(&mut self) {
            self.0.fetch_add(1, SeqCst);
        }
    }

    #[test]
    fn retired_object_is_eventually_freed() {
        let drops = Arc::new(AtomicUsize::new(0));
        let p = Box::into_raw(Box::new(DropCounter(drops.clone())));
        unsafe { retire(p) };
        flush(16);
        assert_eq!(drops.load(SeqCst), 1);
    }

    #[test]
    fn object_not_freed_while_another_thread_is_pinned() {
        let drops = Arc::new(AtomicUsize::new(0));
        let d2 = drops.clone();
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (tx2, rx2) = std::sync::mpsc::channel::<()>();
        let pinner = std::thread::spawn(move || {
            let _g = pin();
            tx.send(()).unwrap();
            rx2.recv().unwrap(); // hold the pin until told otherwise
        });
        rx.recv().unwrap();
        let p = Box::into_raw(Box::new(DropCounter(d2)));
        unsafe { retire(p) };
        flush(16);
        assert_eq!(drops.load(SeqCst), 0, "freed under an active pin");
        tx2.send(()).unwrap();
        pinner.join().unwrap();
        flush(16);
        assert_eq!(drops.load(SeqCst), 1);
    }

    #[test]
    fn nested_pins_unpin_once() {
        let g1 = pin();
        let tid = g1.tid();
        {
            let _g2 = pin();
            assert_eq!(_g2.tid(), tid);
        }
        // Still pinned: slot state non-zero.
        assert_ne!(SLOT_STATE[tid].load(SeqCst), 0);
        drop(g1);
        assert_eq!(SLOT_STATE[tid].load(SeqCst), 0);
    }

    #[test]
    fn exiting_thread_hands_garbage_to_orphans() {
        let drops = Arc::new(AtomicUsize::new(0));
        let d2 = drops.clone();
        std::thread::spawn(move || {
            let p = Box::into_raw(Box::new(DropCounter(d2)));
            unsafe { retire(p) };
            // exit immediately without collecting
        })
        .join()
        .unwrap();
        flush(16);
        assert_eq!(drops.load(SeqCst), 1);
    }

    #[test]
    fn stress_concurrent_retires_all_freed() {
        let drops = Arc::new(AtomicUsize::new(0));
        const PER_THREAD: usize = 2_000;
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let d = drops.clone();
                std::thread::spawn(move || {
                    for _ in 0..PER_THREAD {
                        let _g = pin();
                        let p = Box::into_raw(Box::new(DropCounter(d.clone())));
                        unsafe { retire(p) };
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        flush(64);
        assert_eq!(drops.load(SeqCst), 4 * PER_THREAD);
    }
}
