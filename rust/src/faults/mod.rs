//! Deterministic fault-injection plane (the chaos layer).
//!
//! The size protocol's guarantees — exactly-once counter-CAS, arbiter
//! combining, bounded staleness, admission hysteresis — are only as good
//! as the schedules they survive. This module plants **injection sites**
//! at the protocol's racy edges ([`FaultSite`]) and lets tests and the
//! `csize fuzz` subcommand install a seed-deterministic [`FaultPlane`]
//! that perturbs them: delays, yields, forced `OptimisticSize` fallbacks,
//! handler panics, and partial/short socket writes.
//!
//! Determinism: each thread keeps a per-site hit counter, and whether the
//! `n`-th hit of a site fires is a pure function of
//! `(seed, site, spec, thread, n)` — a splitmix64 mix — so a pinned seed
//! replays the same *per-thread* schedule regardless of interleaving.
//! (Thread ids are assigned in order of first site hit, so schedules are
//! stable for a fixed thread structure.)
//!
//! Cost: the whole runtime is gated behind the `faults` cargo feature.
//! Without it every hook compiles to an `#[inline(always)]` no-op — the
//! release binary carries no fault-plane overhead. With the feature on
//! but no plane installed, each site is a single relaxed atomic load.
//!
//! Only one plane can be active per process: [`install`] serializes
//! installers on a global mutex and the returned [`FaultGuard`] uninstalls
//! on drop, so concurrent `cargo test` threads that install planes run one
//! at a time. Targeted injections (`poison_key` / `stall_key`) only
//! trigger on a specific key, so they cannot disturb unrelated tests that
//! happen to run while such a plane is active.

use std::time::Duration;

/// Injection points wired through the size subsystem and the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// `SizeCalculator::update_metadata`, before the exactly-once
    /// counter-CAS (widens the window where helpers race the owner).
    PreCounterCas = 0,
    /// `SizeCalculator::update_metadata`, after a won counter-CAS
    /// (delays the sharded-mirror sync and `clear_applied`).
    PostCounterCas = 1,
    /// `SizeArbiter::size_exact`, combiner section after winning the
    /// combine lock, before the round stamp.
    ArbiterRoundStart = 2,
    /// `SizeArbiter::size_exact`, combiner section right before the
    /// publish swap (stretches the collect-to-publish window).
    ArbiterPublish = 3,
    /// `SizeRefresher::run`, top of each daemon wake (a `Delay` here
    /// stalls the refresher and exercises the stall-detection fallback).
    RefresherTick = 4,
    /// Server handler pool, before executing a dequeued request
    /// (`Delay` = stalled handler driving `ERR TIMEOUT`; `Panic` =
    /// poisoned handler driving the `catch_unwind` path).
    HandlerDispatch = 5,
    /// `Conn::pump_write` (a `ShortWrite(n)` caps each syscall at `n`
    /// bytes, exercising the partial-write cursor).
    ConnWrite = 6,
    /// `HandshakeSize::size`, between the flag raise and the ack drain
    /// (stretches the handshake's quiescence window).
    HandshakeDrain = 7,
    /// `OptimisticSize::size` entry (a `Fire` hit forces the wait-free
    /// fallback as if the double-collect retry budget were exhausted).
    OptimisticRetry = 8,
    /// The acceptor's socket handoff to a reactor shard (a `Delay`
    /// stretches the accept→adopt window where a connection is counted
    /// in the shard's handoff gauge but not yet in its table; a `Panic`
    /// — contained per handoff — drops that one socket).
    AcceptHandoff = 9,
    /// `Conn::pump_write` flushing a coalesced reply batch (a
    /// `ShortWrite(n)` truncates the batched write, exercising the
    /// partial-write cursor across reply boundaries).
    ReplyCoalesce = 10,
    /// `size::validated_collect`, between the first counter sample and
    /// the range traversal (widens the double-collect window so racing
    /// updates land mid-scan and force validation retries).
    ScanCollect = 11,
    /// `HashTableSet` incremental resize, inside a bucket-migration
    /// quantum (after the freeze, between node copies). A `Delay`/`Yield`
    /// stretches the frozen window where lookups chase the seal
    /// indirection; a `Panic` kills the helper mid-quantum so another
    /// updater must finish the bucket (self-repair).
    ResizeMigrate = 12,
}

impl FaultSite {
    /// Number of sites (array dimension for per-thread hit counters).
    pub const COUNT: usize = 13;

    /// All sites, in index order.
    pub const ALL: [FaultSite; FaultSite::COUNT] = [
        FaultSite::PreCounterCas,
        FaultSite::PostCounterCas,
        FaultSite::ArbiterRoundStart,
        FaultSite::ArbiterPublish,
        FaultSite::RefresherTick,
        FaultSite::HandlerDispatch,
        FaultSite::ConnWrite,
        FaultSite::HandshakeDrain,
        FaultSite::OptimisticRetry,
        FaultSite::AcceptHandoff,
        FaultSite::ReplyCoalesce,
        FaultSite::ScanCollect,
        FaultSite::ResizeMigrate,
    ];

    /// Stable label (README site list, panic messages, fuzz reports).
    pub fn label(self) -> &'static str {
        match self {
            FaultSite::PreCounterCas => "pre-counter-cas",
            FaultSite::PostCounterCas => "post-counter-cas",
            FaultSite::ArbiterRoundStart => "arbiter-round-start",
            FaultSite::ArbiterPublish => "arbiter-publish",
            FaultSite::RefresherTick => "refresher-tick",
            FaultSite::HandlerDispatch => "handler-dispatch",
            FaultSite::ConnWrite => "conn-write",
            FaultSite::HandshakeDrain => "handshake-drain",
            FaultSite::OptimisticRetry => "optimistic-retry",
            FaultSite::AcceptHandoff => "accept-handoff",
            FaultSite::ReplyCoalesce => "reply-coalesce",
            FaultSite::ScanCollect => "scan-collect",
            FaultSite::ResizeMigrate => "resize-migrate",
        }
    }
}

/// What a firing site does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// `thread::yield_now()` — the cheapest schedule perturbation.
    Yield,
    /// `thread::sleep(d)` — stretches a protocol window.
    Delay(Duration),
    /// `panic!` at the site (only safe where a `catch_unwind` contains
    /// it — the server handler pool; never used at size-subsystem sites
    /// by the built-in profiles, where unwinding would poison locks).
    Panic,
    /// No side effect; makes [`fires`] return `true` (consumed by
    /// decision sites such as the forced `OptimisticSize` fallback).
    Fire,
    /// Cap the next write syscall at `n` bytes ([`write_cap`]).
    ShortWrite(usize),
}

/// One armed injection: fire `action` on roughly one in `one_in` hits of
/// `site` (per thread, deterministically; `one_in = 1` fires always).
#[derive(Clone, Copy, Debug)]
pub struct SiteSpec {
    pub site: FaultSite,
    pub one_in: u64,
    pub action: FaultAction,
}

/// A seed-deterministic fault schedule: a set of armed sites plus
/// optional key-targeted server injections.
#[derive(Clone, Debug, Default)]
pub struct FaultPlane {
    seed: u64,
    specs: Vec<SiteSpec>,
    poison_key: Option<u64>,
    stall_key: Option<(u64, Duration)>,
}

impl FaultPlane {
    /// An empty plane (no sites armed) with the given seed.
    pub fn new(seed: u64) -> Self {
        FaultPlane {
            seed,
            specs: Vec::new(),
            poison_key: None,
            stall_key: None,
        }
    }

    /// Arm `site` to fire `action` on ~one in `one_in` hits per thread.
    pub fn with(mut self, site: FaultSite, one_in: u64, action: FaultAction) -> Self {
        assert!(one_in >= 1, "one_in must be >= 1");
        self.specs.push(SiteSpec {
            site,
            one_in,
            action,
        });
        self
    }

    /// Arm a targeted handler panic: a `PUT <key>` for exactly this key
    /// panics in the handler pool (contained by its `catch_unwind`).
    pub fn with_poison_key(mut self, key: u64) -> Self {
        self.poison_key = Some(key);
        self
    }

    /// Arm a targeted handler stall: a `PUT <key>` for exactly this key
    /// sleeps `delay` in the handler before executing (drives the
    /// per-request deadline / `ERR TIMEOUT` path).
    pub fn with_stall_key(mut self, key: u64, delay: Duration) -> Self {
        self.stall_key = Some((key, delay));
        self
    }

    /// The plane's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The distinct sites this plane arms, in [`FaultSite::ALL`] order
    /// (the coverage contract `csize fuzz` holds a run to: every armed
    /// site must fire at least once or the run fails).
    pub fn armed_sites(&self) -> Vec<FaultSite> {
        FaultSite::ALL
            .into_iter()
            .filter(|site| self.specs.iter().any(|spec| spec.site == *site))
            .collect()
    }

    /// The documented chaos profile used by `csize fuzz` and the
    /// fuzz-smoke CI job: jitter at every size-protocol edge, a stalled
    /// refresher, slow + panicking handlers, 1-byte socket writes, and
    /// forced optimistic fallbacks. Handler panics are contained by the
    /// pool's `catch_unwind`; no size-subsystem site panics.
    pub fn chaos(seed: u64) -> Self {
        FaultPlane::new(seed)
            .with(FaultSite::PreCounterCas, 7, FaultAction::Yield)
            .with(
                FaultSite::PreCounterCas,
                97,
                FaultAction::Delay(Duration::from_micros(50)),
            )
            .with(FaultSite::PostCounterCas, 5, FaultAction::Yield)
            .with(
                FaultSite::ArbiterRoundStart,
                9,
                FaultAction::Delay(Duration::from_micros(100)),
            )
            .with(FaultSite::ArbiterPublish, 3, FaultAction::Yield)
            .with(
                FaultSite::RefresherTick,
                2,
                FaultAction::Delay(Duration::from_millis(5)),
            )
            .with(
                FaultSite::HandlerDispatch,
                13,
                FaultAction::Delay(Duration::from_millis(2)),
            )
            .with(FaultSite::HandlerDispatch, 41, FaultAction::Panic)
            .with(FaultSite::ConnWrite, 2, FaultAction::ShortWrite(1))
            .with(FaultSite::HandshakeDrain, 4, FaultAction::Yield)
            .with(FaultSite::OptimisticRetry, 6, FaultAction::Fire)
            .with(
                FaultSite::AcceptHandoff,
                3,
                FaultAction::Delay(Duration::from_micros(500)),
            )
            .with(FaultSite::ReplyCoalesce, 3, FaultAction::ShortWrite(2))
            .with(FaultSite::ScanCollect, 2, FaultAction::Yield)
            .with(
                FaultSite::ScanCollect,
                19,
                FaultAction::Delay(Duration::from_micros(200)),
            )
            .with(FaultSite::ResizeMigrate, 3, FaultAction::Yield)
            .with(
                FaultSite::ResizeMigrate,
                23,
                FaultAction::Delay(Duration::from_micros(100)),
            )
    }
}

#[cfg(feature = "faults")]
mod runtime {
    use std::cell::RefCell;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, MutexGuard, RwLock};
    use std::time::Duration;

    use super::{FaultAction, FaultPlane, FaultSite};

    static ENABLED: AtomicBool = AtomicBool::new(false);
    static ACTIVE: RwLock<Option<FaultPlane>> = RwLock::new(None);
    static INSTALL: Mutex<()> = Mutex::new(());
    static GENERATION: AtomicU64 = AtomicU64::new(0);
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    /// Process-lifetime fire tally per site (monotonic across planes;
    /// consumers diff snapshots around the window they care about).
    static FIRES: [AtomicU64; FaultSite::COUNT] = [const { AtomicU64::new(0) }; FaultSite::COUNT];

    thread_local! {
        /// (plane generation, fault-local thread id, per-site hit counts).
        static LOCAL: RefCell<(u64, u64, [u64; FaultSite::COUNT])> =
            const { RefCell::new((0, 0, [0; FaultSite::COUNT])) };
    }

    /// Scoped installation: uninstalls the plane on drop and serializes
    /// concurrent installers (tests) on a process-wide mutex.
    pub struct FaultGuard {
        _serial: MutexGuard<'static, ()>,
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            ENABLED.store(false, Ordering::SeqCst);
            *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) = None;
        }
    }

    /// Install `plane` for the lifetime of the returned guard.
    pub fn install(plane: FaultPlane) -> FaultGuard {
        let serial = INSTALL.lock().unwrap_or_else(|e| e.into_inner());
        GENERATION.fetch_add(1, Ordering::SeqCst);
        *ACTIVE.write().unwrap_or_else(|e| e.into_inner()) = Some(plane);
        ENABLED.store(true, Ordering::SeqCst);
        FaultGuard { _serial: serial }
    }

    /// splitmix64 finalizer: the decision hash.
    fn mix(mut z: u64) -> u64 {
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Evaluate `site` for the calling thread: bump its hit counter and
    /// return the first armed spec that fires, if any.
    fn decide(site: FaultSite) -> Option<FaultAction> {
        if !ENABLED.load(Ordering::Relaxed) {
            return None;
        }
        let generation = GENERATION.load(Ordering::Relaxed);
        let (tid, n) = LOCAL.with(|local| {
            let mut local = local.borrow_mut();
            if local.0 != generation {
                *local = (generation, local.1, [0; FaultSite::COUNT]);
            }
            if local.1 == 0 {
                local.1 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            }
            let n = local.2[site as usize];
            local.2[site as usize] = n + 1;
            (local.1, n)
        });
        let guard = ACTIVE.read().unwrap_or_else(|e| e.into_inner());
        let plane = guard.as_ref()?;
        for (j, spec) in plane.specs.iter().enumerate() {
            if spec.site != site {
                continue;
            }
            let h = mix(
                plane
                    .seed
                    .wrapping_add(mix(((site as u64) << 32) | j as u64))
                    .wrapping_add(mix(tid))
                    .wrapping_add(n),
            );
            if h % spec.one_in == 0 {
                FIRES[site as usize].fetch_add(1, Ordering::Relaxed);
                return Some(spec.action);
            }
        }
        None
    }

    /// Injections fired so far, indexed by [`FaultSite`] (process-wide,
    /// monotonic). The `csize fuzz` coverage table and the server's
    /// `STATS faults=` gauge read this.
    pub fn fire_counts() -> [u64; FaultSite::COUNT] {
        let mut counts = [0u64; FaultSite::COUNT];
        for (count, fired) in counts.iter_mut().zip(FIRES.iter()) {
            *count = fired.load(Ordering::Relaxed);
        }
        counts
    }

    /// Perturb the schedule at `site`: yield, sleep, or panic per the
    /// active plane. (`Fire`/`ShortWrite` hits are inert here.)
    #[inline]
    pub fn jitter(site: FaultSite) {
        match decide(site) {
            Some(FaultAction::Yield) => std::thread::yield_now(),
            Some(FaultAction::Delay(d)) => std::thread::sleep(d),
            Some(FaultAction::Panic) => {
                panic!("faults: injected panic at {}", site.label())
            }
            _ => {}
        }
    }

    /// Did a `Fire` spec hit at `site`? (Forced-fallback decisions.)
    #[inline]
    pub fn fires(site: FaultSite) -> bool {
        matches!(decide(site), Some(FaultAction::Fire))
    }

    /// Cap for the next write syscall at `site`: a firing `ShortWrite(n)`
    /// truncates `len` to `n` (at least 1 byte so writers still make
    /// progress). `ConnWrite` models a short single-reply write;
    /// `ReplyCoalesce` a short *batched* write that splits a coalesced
    /// reply flush across reply boundaries.
    #[inline]
    pub fn write_cap_at(site: FaultSite, len: usize) -> usize {
        match decide(site) {
            Some(FaultAction::ShortWrite(n)) if len > 0 => n.clamp(1, len),
            _ => len,
        }
    }

    /// [`write_cap_at`] at the historical `ConnWrite` site.
    #[inline]
    pub fn write_cap(len: usize) -> usize {
        write_cap_at(FaultSite::ConnWrite, len)
    }

    /// Is `key` the plane's targeted poison key (handler panic)?
    #[inline]
    pub fn poisoned_put(key: u64) -> bool {
        if !ENABLED.load(Ordering::Relaxed) {
            return false;
        }
        let guard = ACTIVE.read().unwrap_or_else(|e| e.into_inner());
        guard.as_ref().and_then(|p| p.poison_key) == Some(key)
    }

    /// Is `key` the plane's targeted stall key? Returns the stall delay.
    #[inline]
    pub fn stalled_put(key: u64) -> Option<Duration> {
        if !ENABLED.load(Ordering::Relaxed) {
            return None;
        }
        let guard = ACTIVE.read().unwrap_or_else(|e| e.into_inner());
        let (k, d) = guard.as_ref()?.stall_key?;
        (k == key).then_some(d)
    }
}

#[cfg(not(feature = "faults"))]
mod runtime {
    use std::time::Duration;

    use super::{FaultPlane, FaultSite};

    /// No-op guard (feature off): nothing was installed.
    pub struct FaultGuard {
        _private: (),
    }

    /// Feature off: accepts and discards the plane so call sites compile
    /// unchanged; every hook below is a zero-cost no-op.
    pub fn install(_plane: FaultPlane) -> FaultGuard {
        FaultGuard { _private: () }
    }

    #[inline(always)]
    pub fn jitter(_site: FaultSite) {}

    #[inline(always)]
    pub fn fires(_site: FaultSite) -> bool {
        false
    }

    #[inline(always)]
    pub fn write_cap_at(_site: FaultSite, len: usize) -> usize {
        len
    }

    #[inline(always)]
    pub fn write_cap(len: usize) -> usize {
        len
    }

    #[inline(always)]
    pub fn poisoned_put(_key: u64) -> bool {
        false
    }

    #[inline(always)]
    pub fn stalled_put(_key: u64) -> Option<Duration> {
        None
    }

    /// Feature off: nothing can fire, so the tally is all zeros.
    pub fn fire_counts() -> [u64; FaultSite::COUNT] {
        [0; FaultSite::COUNT]
    }
}

pub use runtime::{
    fire_counts, fires, install, jitter, poisoned_put, stalled_put, write_cap, write_cap_at,
    FaultGuard,
};

/// Whether the `faults` feature was compiled in (used by `csize fuzz`
/// and `kv_server --fault-seed` to warn instead of silently no-opping).
pub const COMPILED: bool = cfg!(feature = "faults");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_indices_are_dense() {
        for (i, site) in FaultSite::ALL.iter().enumerate() {
            assert_eq!(*site as usize, i);
        }
    }

    #[test]
    fn plane_builder_accumulates() {
        let plane = FaultPlane::chaos(7)
            .with_poison_key(11)
            .with_stall_key(12, Duration::from_millis(1));
        assert_eq!(plane.seed(), 7);
        assert!(plane.specs.len() >= FaultSite::COUNT);
        assert_eq!(plane.poison_key, Some(11));
        assert_eq!(plane.stall_key.map(|(k, _)| k), Some(12));
    }

    #[cfg(feature = "faults")]
    #[test]
    fn targeted_keys_only_fire_when_installed() {
        assert!(!poisoned_put(99));
        let guard = install(
            FaultPlane::new(1)
                .with_poison_key(99)
                .with_stall_key(98, Duration::from_millis(3)),
        );
        assert!(poisoned_put(99));
        assert!(!poisoned_put(98));
        assert_eq!(stalled_put(98), Some(Duration::from_millis(3)));
        assert_eq!(stalled_put(99), None);
        drop(guard);
        assert!(!poisoned_put(99));
    }

    #[cfg(feature = "faults")]
    #[test]
    fn one_in_one_always_fires() {
        let before = fire_counts()[FaultSite::OptimisticRetry as usize];
        let _guard = install(FaultPlane::new(3).with(
            FaultSite::OptimisticRetry,
            1,
            FaultAction::Fire,
        ));
        for _ in 0..32 {
            assert!(fires(FaultSite::OptimisticRetry));
        }
        assert!(!fires(FaultSite::RefresherTick));
        let after = fire_counts()[FaultSite::OptimisticRetry as usize];
        assert!(after >= before + 32, "fire tally must count every hit");
    }

    #[cfg(feature = "faults")]
    #[test]
    fn short_write_caps_are_per_site() {
        let plane =
            FaultPlane::new(5).with(FaultSite::ReplyCoalesce, 1, FaultAction::ShortWrite(2));
        let _guard = install(plane);
        assert_eq!(write_cap_at(FaultSite::ReplyCoalesce, 10), 2);
        assert_eq!(
            write_cap_at(FaultSite::ConnWrite, 10),
            10,
            "an unarmed site must never cap"
        );
        assert_eq!(
            write_cap_at(FaultSite::ReplyCoalesce, 1),
            1,
            "the cap never exceeds the remaining length"
        );
    }

    #[test]
    fn armed_sites_deduplicates_in_index_order() {
        let plane = FaultPlane::new(0)
            .with(FaultSite::ConnWrite, 2, FaultAction::ShortWrite(1))
            .with(FaultSite::PreCounterCas, 7, FaultAction::Yield)
            .with(FaultSite::PreCounterCas, 97, FaultAction::Yield);
        assert_eq!(
            plane.armed_sites(),
            vec![FaultSite::PreCounterCas, FaultSite::ConnWrite]
        );
        assert_eq!(FaultPlane::chaos(1).armed_sites(), FaultSite::ALL.to_vec());
        assert!(FaultPlane::new(1).armed_sites().is_empty());
    }

    #[cfg(not(feature = "faults"))]
    #[test]
    fn fire_counts_are_zero_when_compiled_out() {
        assert_eq!(fire_counts(), [0; FaultSite::COUNT]);
    }
}
