//! Multi-threaded throughput engine: the experiment driver behind every
//! figure reproduction (paper Section 9, *Methodology*).
//!
//! A run spawns `w` workload threads (insert/delete/contains per the mix)
//! and `s` size threads (repeated `size()` calls) for a fixed duration, and
//! reports per-category operation counts. A per-op-type mode times
//! 100-operation uniform batches for the Figure 13 breakdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::time::{Duration, Instant};

use crate::cli::SizeCallKind;
use crate::metrics::Stats;
use crate::set_api::ConcurrentSet;
use crate::workload::{self, KeyDist, Mix, OpStream, OpType};

/// How the size threads call `size` (the arbiter ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SizeCall {
    /// The policy's own `size()`: every caller synchronizes itself.
    Raw,
    /// Combining `size_exact()` through the structure's arbiter.
    Exact,
    /// Published wait-free `size_recent` under the given staleness bound.
    Recent(Duration),
    /// `size_recent` under the given bound **with a background
    /// `SizeRefresher`** keeping the publication warm — [`run`] starts a
    /// daemon (period [`RunConfig::refresh_period`], default half the
    /// bound) for the duration of the run, so size threads read passively.
    Refresh(Duration),
}

impl SizeCall {
    /// Build from the CLI spelling plus the staleness `Recent`/`Refresh`
    /// should use (the single conversion point for every CLI surface).
    pub fn from_kind(kind: SizeCallKind, staleness: Duration) -> Self {
        match kind {
            SizeCallKind::Raw => SizeCall::Raw,
            SizeCallKind::Exact => SizeCall::Exact,
            SizeCallKind::Recent => SizeCall::Recent(staleness),
            SizeCallKind::Refresh => SizeCall::Refresh(staleness),
        }
    }

    /// The CLI-facing kind of this call (drops the staleness payload).
    pub fn kind(self) -> SizeCallKind {
        match self {
            SizeCall::Raw => SizeCallKind::Raw,
            SizeCall::Exact => SizeCallKind::Exact,
            SizeCall::Recent(_) => SizeCallKind::Recent,
            SizeCall::Refresh(_) => SizeCallKind::Refresh,
        }
    }

    /// Report label (delegates to [`SizeCallKind::label`], the single
    /// source of truth for the spellings).
    pub fn label(self) -> &'static str {
        self.kind().label()
    }
}

/// Configuration of one timed run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub workload_threads: usize,
    pub size_threads: usize,
    pub duration: Duration,
    pub mix: Mix,
    pub key_range: u64,
    /// Key-popularity distribution over `[1, key_range]` (uniform by
    /// default; `zipf:<theta>` skews traffic onto a hot head — the
    /// sharded-store hot-shard axis).
    pub key_dist: KeyDist,
    pub seed: u64,
    /// Fig. 13 mode: run 100-op uniform-type batches and time each type.
    pub per_type_timing: bool,
    /// Which size path the size threads drive.
    pub size_call: SizeCall,
    /// Explicit `SizeRefresher` period for the run. `None` + a
    /// [`SizeCall::Refresh`] call derives half its staleness bound; `None`
    /// otherwise runs no daemon.
    pub refresh_period: Option<Duration>,
}

impl RunConfig {
    pub fn new(workload_threads: usize, size_threads: usize, mix: Mix, key_range: u64) -> Self {
        Self {
            workload_threads,
            size_threads,
            duration: Duration::from_millis(500),
            mix,
            key_range,
            key_dist: KeyDist::Uniform,
            seed: 0xBEEF,
            per_type_timing: false,
            size_call: SizeCall::Raw,
            refresh_period: None,
        }
    }

    /// The daemon period this config implies (see
    /// [`Self::refresh_period`]); `None` means no daemon.
    pub fn effective_refresh_period(&self) -> Option<Duration> {
        self.refresh_period.or(match self.size_call {
            SizeCall::Refresh(staleness) => Some(staleness / 2),
            _ => None,
        })
    }
}

/// Aggregated result of one run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub elapsed: Duration,
    /// Total insert+delete+contains completed by workload threads.
    pub workload_ops: u64,
    /// Total `size()` calls completed by size threads.
    pub size_ops: u64,
    /// Per-type op counts (Fig. 13 mode): [insert, delete, contains].
    pub type_ops: [u64; 3],
    /// Per-type busy nanoseconds (Fig. 13 mode).
    pub type_nanos: [u64; 3],
}

impl RunResult {
    pub fn workload_throughput(&self) -> f64 {
        self.workload_ops as f64 / self.elapsed.as_secs_f64()
    }

    pub fn size_throughput(&self) -> f64 {
        self.size_ops as f64 / self.elapsed.as_secs_f64()
    }

    /// Fig. 13: throughput of one op type = ops / busy time of that type.
    pub fn type_throughput(&self, op: OpType) -> f64 {
        let i = op as usize;
        if self.type_nanos[i] == 0 {
            return 0.0;
        }
        self.type_ops[i] as f64 / (self.type_nanos[i] as f64 / 1e9)
    }
}

/// One timed run over `set`. A config implying a refresh daemon (see
/// [`RunConfig::effective_refresh_period`]) starts the structure's
/// `SizeRefresher` for the duration of the run and stops it before
/// returning.
pub fn run(set: &dyn ConcurrentSet, cfg: &RunConfig) -> RunResult {
    let stop = AtomicBool::new(false);
    let refresh = cfg.effective_refresh_period();
    if let Some(period) = refresh {
        set.set_refresh_period(Some(period));
    }
    let start = Instant::now();
    let mut result = RunResult::default();

    std::thread::scope(|scope| {
        let mut workers = Vec::new();
        for t in 0..cfg.workload_threads {
            let stop = &stop;
            let set: &dyn ConcurrentSet = set;
            let cfg = cfg.clone();
            workers.push(scope.spawn(move || {
                let mut stream = OpStream::with_dist(
                    cfg.seed ^ (t as u64) << 32,
                    cfg.mix,
                    cfg.key_range,
                    cfg.key_dist,
                );
                let mut ops = 0u64;
                let mut type_ops = [0u64; 3];
                let mut type_nanos = [0u64; 3];
                if cfg.per_type_timing {
                    // Fig. 13 mode: uniform 100-op batches, timed per batch.
                    let mut pick = OpStream::new(cfg.seed ^ 0xF13 ^ (t as u64), cfg.mix, 100);
                    while !stop.load(SeqCst) {
                        let (op, _) = pick.next();
                        let t0 = Instant::now();
                        for _ in 0..100 {
                            workload::apply(set, op, stream.next_key());
                        }
                        let dt = t0.elapsed().as_nanos() as u64;
                        type_ops[op as usize] += 100;
                        type_nanos[op as usize] += dt;
                        ops += 100;
                    }
                } else {
                    while !stop.load(SeqCst) {
                        let (op, key) = stream.next();
                        workload::apply(set, op, key);
                        ops += 1;
                    }
                }
                (ops, 0u64, type_ops, type_nanos)
            }));
        }
        for t in 0..cfg.size_threads {
            let stop = &stop;
            let set: &dyn ConcurrentSet = set;
            let _ = t;
            let size_call = cfg.size_call;
            workers.push(scope.spawn(move || {
                let mut sizes = 0u64;
                while !stop.load(SeqCst) {
                    let s = match size_call {
                        SizeCall::Raw => set.size(),
                        SizeCall::Exact => set.size_exact().map(|v| v.value),
                        SizeCall::Recent(bound) | SizeCall::Refresh(bound) => {
                            set.size_recent(bound).map(|v| v.value)
                        }
                    }
                    .expect("size thread on a size-less structure");
                    debug_assert!(s >= 0, "linearizable size went negative");
                    sizes += 1;
                }
                (0u64, sizes, [0u64; 3], [0u64; 3])
            }));
        }

        std::thread::sleep(cfg.duration);
        stop.store(true, SeqCst);

        for w in workers {
            let (ops, sizes, type_ops, type_nanos) = w.join().unwrap();
            result.workload_ops += ops;
            result.size_ops += sizes;
            for i in 0..3 {
                result.type_ops[i] += type_ops[i];
                result.type_nanos[i] += type_nanos[i];
            }
        }
    });

    result.elapsed = start.elapsed();
    if refresh.is_some() {
        set.set_refresh_period(None); // joins the daemon before returning
    }
    result
}

/// Configuration of a growth-phase run ([`growth_run`]): a writer drives
/// a fresh [`crate::hashtable::HashTableSet`] from `initial_buckets`
/// through `growth_factor`× its resize-trigger capacity while reader and
/// size threads run against it, recording per-window insert throughput —
/// the `resize_scale` ablation axis and the `resize-stress` CI gate both
/// consume this.
#[derive(Clone, Copy, Debug)]
pub struct GrowthConfig {
    /// Starting bucket count (the issue's growth workload starts at 64).
    pub initial_buckets: usize,
    /// Insert this many multiples of the initial *trigger* capacity
    /// (`initial_buckets * RESIZE_CHAIN`), forcing several doublings.
    pub growth_factor: u64,
    /// Concurrent `contains`/`get` readers over the growing key space.
    pub reader_threads: usize,
    /// Concurrent `size()` callers (0 for size-less policies).
    pub size_threads: usize,
    /// Fixed op-count windows the insert phase is split into; each
    /// window's throughput is reported separately so a migration stall
    /// shows up as a collapsed window.
    pub windows: usize,
    /// Growth rounds (fresh table each); per-window throughputs are
    /// averaged elementwise across rounds to damp scheduler noise.
    pub rounds: usize,
    pub seed: u64,
}

impl Default for GrowthConfig {
    fn default() -> Self {
        Self {
            initial_buckets: 64,
            growth_factor: 10,
            reader_threads: 2,
            size_threads: 1,
            windows: 16,
            rounds: 3,
            seed: 0xC12E,
        }
    }
}

/// Aggregated result of [`growth_run`].
#[derive(Clone, Debug, Default)]
pub struct GrowthResult {
    pub initial_buckets: usize,
    /// Bucket count after the last round's migrations completed.
    pub final_buckets: usize,
    /// Resizes triggered, summed over rounds.
    pub resizes: u64,
    /// Bucket-migration quanta completed, summed over rounds.
    pub migration_quanta: u64,
    /// Keys inserted per round.
    pub inserted: u64,
    /// Per-window insert throughput (ops/s), averaged across rounds.
    /// The CI collapse gate compares `min(windows)` against the median.
    pub windows: Vec<f64>,
    pub elapsed: Duration,
}

impl GrowthResult {
    /// `min(window) / median(window)` — 1.0 is perfectly flat; the
    /// acceptance gate requires this to stay above 0.5 (no window worse
    /// than half of steady-state).
    pub fn collapse_ratio(&self) -> f64 {
        if self.windows.is_empty() {
            return 1.0;
        }
        let mut sorted = self.windows.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = sorted[sorted.len() / 2];
        if median <= 0.0 {
            return 0.0;
        }
        sorted[0] / median
    }
}

/// The growth-phase workload: per round, build a fresh table at
/// `cfg.initial_buckets`, then insert `growth_factor × trigger-capacity`
/// distinct keys under concurrent read + size load, timing each fixed
/// op-count window of the insert stream. Inserts help migrate quanta
/// inline, so window throughput directly prices the incremental resize;
/// every round ends with a drained migration and a membership check.
pub fn growth_run<P: crate::size::SizePolicy>(cfg: &GrowthConfig) -> GrowthResult {
    use crate::hashtable::{HashTableSet, RESIZE_CHAIN};

    let total = cfg.growth_factor * cfg.initial_buckets as u64 * RESIZE_CHAIN as u64;
    let windows = cfg.windows.max(1);
    let window_ops = (total / windows as u64).max(1);
    let inserted = window_ops * windows as u64;
    let start = Instant::now();
    let mut result = GrowthResult {
        initial_buckets: cfg.initial_buckets,
        inserted,
        windows: vec![0.0; windows],
        ..GrowthResult::default()
    };

    for round in 0..cfg.rounds.max(1) {
        let set: HashTableSet<P> = HashTableSet::new(crate::MAX_THREADS, cfg.initial_buckets);
        let stop = AtomicBool::new(false);
        let mut round_windows = vec![0.0f64; windows];
        std::thread::scope(|scope| {
            let mut helpers = Vec::new();
            for t in 0..cfg.reader_threads {
                let stop = &stop;
                let set = &set;
                let seed = cfg.seed ^ ((round as u64) << 40) ^ ((t as u64) << 8);
                helpers.push(scope.spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(seed);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(total) + 1;
                        if rng.gen_bool(0.5) {
                            set.contains(k);
                        } else {
                            set.get(k);
                        }
                    }
                }));
            }
            for _ in 0..cfg.size_threads {
                let stop = &stop;
                let set = &set;
                helpers.push(scope.spawn(move || {
                    while !stop.load(SeqCst) {
                        if P::HAS_SIZE {
                            let s = set.size().expect("size-providing policy");
                            debug_assert!(s >= 0, "size went negative mid-growth");
                        } else {
                            std::thread::yield_now();
                        }
                    }
                }));
            }

            // The writer: the timed growth phase itself.
            let mut next = 1u64;
            for w in round_windows.iter_mut() {
                let t0 = Instant::now();
                for _ in 0..window_ops {
                    set.insert(next);
                    next += 1;
                }
                *w = window_ops as f64 / t0.elapsed().as_secs_f64().max(1e-12);
            }
            stop.store(true, SeqCst);
            for h in helpers {
                h.join().unwrap();
            }
        });

        set.finish_migration();
        assert_eq!(set.migration_pending(), 0, "migration failed to drain");
        assert_eq!(
            set.occupancy(),
            inserted as i64,
            "keys lost or duplicated across migration"
        );
        result.resizes += set.resizes();
        result.migration_quanta += set.migration_quanta();
        result.final_buckets = set.capacity();
        for (acc, w) in result.windows.iter_mut().zip(&round_windows) {
            *acc += w / cfg.rounds.max(1) as f64;
        }
        crate::ebr::collect();
    }

    result.elapsed = start.elapsed();
    result
}

/// Aggregate result of one [`client_swarm`] run against a live
/// [`crate::server::Server`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SwarmResult {
    /// Replies received (one per command sent).
    pub ops: u64,
    /// `ERR OVERLOAD` replies — `PUT`s shed by either admission tier
    /// (the per-shard tier's `ERR OVERLOAD shard=<i>` counts here too).
    pub overloads: u64,
    /// Other `ERR` replies (0 against a size-capable, mirrored store).
    pub errors: u64,
    pub elapsed: Duration,
}

impl SwarmResult {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// How often a swarm client probes the size endpoints instead of driving
/// the workload mix (every Nth command cycles `SIZE~`/`SIZE?`).
const SWARM_PROBE_EVERY: u64 = 61;

/// Everything [`client_swarm`] needs to drive a server, in one bundle
/// (the knob list outgrew a positional signature when pipelining
/// arrived).
#[derive(Clone, Copy, Debug)]
pub struct SwarmConfig {
    /// Concurrent TCP connections.
    pub clients: usize,
    /// Commands each connection issues (replies are always read).
    pub ops_per_client: u64,
    /// Workload mix (`PUT`/`DEL`/`HAS` ratios).
    pub mix: Mix,
    /// Keys are drawn from `[0, key_range)`.
    pub key_range: u64,
    /// Key distribution (uniform, or zipfian to light up a hot shard).
    pub key_dist: KeyDist,
    pub seed: u64,
    /// Commands issued per write: 1 (the floor) is the lock-step
    /// command/reply client; `K > 1` is the pipelined client — `K`
    /// command lines coalesced into one write, then `K` replies read
    /// back in order, exercising the server's batch dispatch and reply
    /// coalescing.
    pub pipeline: usize,
    /// Fraction of workload commands replaced by range ops (alternating
    /// `SCAN`/`COUNT`); 0 disables the scan mix entirely.
    pub scan_frac: f64,
    /// Width of each scanned range: `[lo, lo + scan_span]` with `lo`
    /// uniform over the key range.
    pub scan_span: u64,
}

impl SwarmConfig {
    /// A lock-step (non-pipelined) uniform-key swarm; override fields
    /// for anything fancier.
    pub fn new(clients: usize, ops_per_client: u64, mix: Mix, key_range: u64, seed: u64) -> Self {
        Self {
            clients,
            ops_per_client,
            mix,
            key_range,
            key_dist: KeyDist::Uniform,
            seed,
            pipeline: 1,
            scan_frac: 0.0,
            scan_span: 0,
        }
    }

    /// Same swarm, issuing `pipeline` commands per write.
    pub fn pipelined(mut self, pipeline: usize) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Same swarm, with `frac` of the workload commands replaced by
    /// `SCAN`/`COUNT` over spans of `span` keys.
    pub fn with_scans(mut self, frac: f64, span: u64) -> Self {
        self.scan_frac = frac;
        self.scan_span = span;
        self
    }
}

/// The server-path load mode: `cfg.clients` TCP connections each drive
/// `cfg.ops_per_client` commands from the workload mix (`PUT`/`DEL`/`HAS`
/// per [`Mix`], keys drawn per `cfg.key_dist`, with a periodic
/// `SIZE~`/`SIZE?` probe mixed in, and — when `cfg.scan_frac > 0` —
/// alternating `SCAN`/`COUNT` range ops whose multi-line replies are
/// drained to their `END` terminators) and read every reply. With
/// `cfg.pipeline > 1` each client sends that many commands in one write
/// before reading the replies back in order — the client half of the
/// server's command pipelining. This benchmarks the whole
/// acceptor + reactor-shard + handler-pool + admission path rather than
/// the bare structure; the server tests and `make server-smoke` both
/// drive it, and a zipfian `key_dist` is how the sharded-store tests
/// light up one hot shard.
///
/// Client threads never touch the store in-process, so they consume **no**
/// [`crate::thread_id`] slots — swarms far wider than the thread-slot
/// capacity are exactly the point (the reactor shards multiplex them).
pub fn client_swarm(addr: SocketAddr, cfg: SwarmConfig) -> std::io::Result<SwarmResult> {
    let start = Instant::now();
    let mut result = SwarmResult::default();
    let outcomes: Vec<std::io::Result<(u64, u64, u64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|c| {
                scope.spawn(move || -> std::io::Result<(u64, u64, u64)> {
                    let stream = TcpStream::connect(addr)?;
                    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
                    let mut out = stream.try_clone()?;
                    let mut reader = BufReader::new(stream);
                    let mut ops_stream = OpStream::with_dist(
                        cfg.seed ^ ((c as u64) << 24),
                        cfg.mix,
                        cfg.key_range,
                        cfg.key_dist,
                    );
                    let mut scan_rng =
                        crate::rng::Xoshiro256::new(cfg.seed ^ 0x5CA4 ^ (c as u64));
                    let mut scans_issued = 0u64;
                    let (mut ops, mut overloads, mut errors) = (0u64, 0u64, 0u64);
                    let pipeline = cfg.pipeline.max(1) as u64;
                    let mut line = String::new();
                    let mut wire = String::new();
                    // Per burst slot: does this command answer with a
                    // multi-line (`SCAN`) reply?
                    let mut multiline = Vec::with_capacity(pipeline as usize);
                    let mut issued = 0u64;
                    while issued < cfg.ops_per_client {
                        let burst = pipeline.min(cfg.ops_per_client - issued);
                        wire.clear();
                        multiline.clear();
                        for j in 0..burst {
                            let i = issued + j;
                            let mut multi = false;
                            let cmd = if i % SWARM_PROBE_EVERY == SWARM_PROBE_EVERY - 1 {
                                if (i / SWARM_PROBE_EVERY) % 2 == 0 {
                                    "SIZE~ 50".to_string()
                                } else {
                                    "SIZE?".to_string()
                                }
                            } else if cfg.scan_frac > 0.0 && scan_rng.gen_bool(cfg.scan_frac) {
                                let lo = scan_rng.gen_range(cfg.key_range.max(1));
                                let hi = lo.saturating_add(cfg.scan_span);
                                scans_issued += 1;
                                if scans_issued % 2 == 0 {
                                    format!("COUNT {lo} {hi}")
                                } else {
                                    multi = true;
                                    format!("SCAN {lo} {hi}")
                                }
                            } else {
                                let (op, key) = ops_stream.next();
                                match op {
                                    OpType::Insert => format!("PUT {key} {key}"),
                                    OpType::Delete => format!("DEL {key}"),
                                    OpType::Contains => format!("HAS {key}"),
                                }
                            };
                            multiline.push(multi);
                            wire.push_str(&cmd);
                            wire.push('\n');
                        }
                        // One write per burst: the pipelined client's
                        // whole point (with pipeline=1 this degenerates
                        // to the historical lock-step writeln).
                        out.write_all(wire.as_bytes())?;
                        for &multi in &multiline {
                            line.clear();
                            if reader.read_line(&mut line)? == 0 {
                                return Err(std::io::Error::new(
                                    std::io::ErrorKind::UnexpectedEof,
                                    "server closed mid-swarm",
                                ));
                            }
                            ops += 1;
                            let reply = line.trim();
                            if reply.starts_with("ERR OVERLOAD") {
                                overloads += 1;
                            } else if reply.starts_with("ERR") {
                                errors += 1;
                            } else if multi {
                                // A healthy SCAN reply spans entry lines
                                // up to its `END n` terminator; the whole
                                // body counts as the one op above.
                                while !line.trim().starts_with("END ") {
                                    line.clear();
                                    if reader.read_line(&mut line)? == 0 {
                                        return Err(std::io::Error::new(
                                            std::io::ErrorKind::UnexpectedEof,
                                            "server closed mid-scan",
                                        ));
                                    }
                                }
                            }
                        }
                        issued += burst;
                    }
                    writeln!(out, "QUIT")?;
                    Ok((ops, overloads, errors))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("swarm client panicked"))
            .collect()
    });
    for outcome in outcomes {
        let (ops, overloads, errors) = outcome?;
        result.ops += ops;
        result.overloads += overloads;
        result.errors += errors;
    }
    result.elapsed = start.elapsed();
    Ok(result)
}

/// Repeated measurement with warmup (paper: 5 warmup + 10 measured runs;
/// scaled via the bench CLIs). A fresh structure is built per run and
/// prefilled, so runs are independent.
#[derive(Clone, Copy, Debug)]
pub struct Repeat {
    pub warmup: usize,
    pub runs: usize,
}

impl Default for Repeat {
    fn default() -> Self {
        Self { warmup: 1, runs: 3 }
    }
}

/// Run `make_set()` `repeat.runs` times (after warmups), prefilled to
/// `initial_size`, and aggregate a chosen metric.
pub fn measure<F>(
    make_set: F,
    initial_size: u64,
    cfg: &RunConfig,
    repeat: &Repeat,
    metric: impl Fn(&RunResult) -> f64,
) -> Stats
where
    F: Fn() -> Box<dyn ConcurrentSet>,
{
    let mut samples = Vec::with_capacity(repeat.runs);
    for i in 0..(repeat.warmup + repeat.runs) {
        let set = make_set();
        workload::prefill(set.as_ref(), initial_size, cfg.key_range, cfg.seed ^ 0xF111);
        let res = run(set.as_ref(), cfg);
        if i >= repeat.warmup {
            samples.push(metric(&res));
        }
        crate::ebr::collect();
    }
    Stats::from_samples(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashtable::HashTableSet;
    use crate::size::{LinearizableSize, NoSize};
    use crate::workload::{key_range, UPDATE_HEAVY};

    fn quick_cfg(w: usize, s: usize) -> RunConfig {
        let mut cfg = RunConfig::new(w, s, UPDATE_HEAVY, key_range(512, UPDATE_HEAVY));
        cfg.duration = Duration::from_millis(80);
        cfg
    }

    #[test]
    fn run_produces_ops() {
        let set: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 512);
        workload::prefill(&set, 512, key_range(512, UPDATE_HEAVY), 3);
        let res = run(&set, &quick_cfg(2, 1));
        assert!(res.workload_ops > 0);
        assert!(res.size_ops > 0);
        assert!(res.workload_throughput() > 0.0);
    }

    #[test]
    fn baseline_runs_without_size_threads() {
        let set: HashTableSet<NoSize> = HashTableSet::new(crate::MAX_THREADS, 512);
        let res = run(&set, &quick_cfg(2, 0));
        assert!(res.workload_ops > 0);
        assert_eq!(res.size_ops, 0);
    }

    #[test]
    fn per_type_mode_times_all_types() {
        let set: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 512);
        workload::prefill(&set, 512, key_range(512, UPDATE_HEAVY), 3);
        let mut cfg = quick_cfg(2, 0);
        cfg.per_type_timing = true;
        cfg.duration = Duration::from_millis(200);
        let res = run(&set, &cfg);
        for op in [OpType::Insert, OpType::Delete, OpType::Contains] {
            assert!(res.type_ops[op as usize] > 0, "{op:?} never ran");
            assert!(res.type_throughput(op) > 0.0);
        }
    }

    #[test]
    fn run_drives_handshake_and_optimistic_policies() {
        // The new size methods must survive the exact driver the figure
        // benches use — including a concurrent size thread (the handshake
        // path blocks updates during each size; no deadlock allowed).
        use crate::cli::PolicyKind;
        for policy in [PolicyKind::Handshake, PolicyKind::Optimistic] {
            let set = crate::bench_util::make_set("hashtable", policy, 512).unwrap();
            workload::prefill(set.as_ref(), 512, key_range(512, UPDATE_HEAVY), 3);
            let res = run(set.as_ref(), &quick_cfg(2, 1));
            assert!(res.workload_ops > 0, "{policy:?} starved the workload");
            assert!(res.size_ops > 0, "{policy:?} starved size calls");
        }
    }

    #[test]
    fn run_drives_arbitrated_size_calls() {
        // Size threads must work through every SizeCall path, including
        // the wait-free recent reads with a tight staleness bound.
        for call in [
            SizeCall::Exact,
            SizeCall::Recent(Duration::from_micros(500)),
        ] {
            let set =
                crate::bench_util::make_set("hashtable", crate::cli::PolicyKind::Handshake, 512)
                    .unwrap();
            workload::prefill(set.as_ref(), 512, key_range(512, UPDATE_HEAVY), 3);
            let mut cfg = quick_cfg(2, 2);
            cfg.size_call = call;
            let res = run(set.as_ref(), &cfg);
            assert!(res.workload_ops > 0, "{call:?} starved the workload");
            assert!(res.size_ops > 0, "{call:?} starved size calls");
            let stats = set.size_stats().expect("arbitrated structure");
            assert!(stats.rounds > 0, "{call:?} never collected");
        }
    }

    #[test]
    fn run_drives_refresh_mode_with_a_daemon() {
        // `refresh` size calls must be served overwhelmingly by the
        // daemon's publications: recent hits dominate, and daemon rounds
        // are recorded. The daemon must also be gone when run() returns.
        use crate::cli::PolicyKind;
        let set = crate::bench_util::make_set("hashtable", PolicyKind::Optimistic, 512).unwrap();
        workload::prefill(set.as_ref(), 512, key_range(512, UPDATE_HEAVY), 3);
        let mut cfg = quick_cfg(2, 2);
        cfg.size_call = SizeCall::Refresh(Duration::from_millis(5));
        cfg.duration = Duration::from_millis(150);
        let res = run(set.as_ref(), &cfg);
        assert!(res.workload_ops > 0);
        assert!(res.size_ops > 0);
        let stats = set.size_stats().unwrap();
        assert!(stats.daemon_rounds > 0, "daemon never drove a round");
        assert!(stats.recent_hits > 0, "published reads never hit");
        let rounds = stats.daemon_rounds;
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(
            set.size_stats().unwrap().daemon_rounds,
            rounds,
            "daemon still running after run() returned"
        );
    }

    #[test]
    fn effective_refresh_period_derivation() {
        let mut cfg = quick_cfg(1, 1);
        assert_eq!(cfg.effective_refresh_period(), None);
        cfg.size_call = SizeCall::Refresh(Duration::from_millis(4));
        assert_eq!(
            cfg.effective_refresh_period(),
            Some(Duration::from_millis(2))
        );
        cfg.refresh_period = Some(Duration::from_millis(7));
        assert_eq!(
            cfg.effective_refresh_period(),
            Some(Duration::from_millis(7))
        );
    }

    #[test]
    fn growth_run_records_windows_and_resizes() {
        let cfg = GrowthConfig {
            initial_buckets: 16,
            growth_factor: 8,
            reader_threads: 1,
            size_threads: 1,
            windows: 8,
            rounds: 1,
            seed: 5,
        };
        let res = growth_run::<LinearizableSize>(&cfg);
        assert_eq!(res.initial_buckets, 16);
        assert_eq!(res.windows.len(), 8);
        assert!(res.windows.iter().all(|w| *w > 0.0), "empty window");
        assert!(res.resizes >= 1, "8x growth never resized");
        assert!(res.final_buckets > res.initial_buckets);
        assert!(
            res.migration_quanta >= 16,
            "every migrated bucket counts a quantum"
        );
        let ratio = res.collapse_ratio();
        assert!(ratio > 0.0 && ratio <= 1.0, "ratio {ratio} out of range");
    }

    #[test]
    fn measure_aggregates_runs() {
        let cfg = quick_cfg(1, 0);
        let stats = measure(
            || Box::new(HashTableSet::<NoSize>::new(crate::MAX_THREADS, 256)),
            256,
            &cfg,
            &Repeat { warmup: 0, runs: 2 },
            |r| r.workload_throughput(),
        );
        assert_eq!(stats.n, 2);
        assert!(stats.mean > 0.0);
    }
}
