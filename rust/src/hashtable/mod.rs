//! Hash-table set: Harris-list buckets behind an incrementally-resizable
//! table (paper Section 9: "a table of linked lists whose implementation
//! is based on the linked list").
//!
//! All buckets share one size policy instance, so `size()` spans the whole
//! table — the metadata is per *thread*, not per bucket (paper Section 5).
//!
//! ## Incremental concurrent resize
//!
//! The bucket array lives in a [`Table`] descriptor published through an
//! EBR-protected root pointer. When occupancy crosses
//! [`RESIZE_CHAIN`]× capacity, an updater installs a successor descriptor
//! of twice the capacity in `Table::next`, and from then on every update
//! operation helps migrate a quantum of [`MIGRATION_QUANTUM`] buckets
//! before doing its own work. Per bucket, migration is:
//!
//! 1. **Freeze** ([`list::freeze_chain`]): tag the head word and every
//!    node's `next` with `FREEZE`, making every pre-freeze CAS snapshot
//!    stale. Untracked deletes refuse to mark frozen words, so the set of
//!    deleted nodes is fixed; overwrite stores bail and re-route.
//! 2. **Copy**: walk the frozen chain and splice a copy of each live node
//!    into the successor buckets `i` / `i + old_capacity`. For tracked
//!    policies the one mutation that penetrates a freeze — the delete-info
//!    claim — is arbitrated by *sealing* the same word with
//!    `copy_ptr | SEAL_TAG`: the claim-vs-seal CAS decides atomically
//!    whether the node died here or moved. The copy/link phase is
//!    serialized on a per-table mutex (`mover`), which is what makes the
//!    successor chains single-writer and a panicked quantum recoverable by
//!    the next helper (the whole pass is idempotent: seals are
//!    deduplicated by copy pointer, untracked copies by key).
//! 3. **Publish**: store the [`list::MOVED_HEAD`] sentinel in the old
//!    head — lookups now chase exactly one indirection to the successor —
//!    then retire the originals through [`crate::ebr`]. When the last
//!    bucket moves, the root pointer swings to the successor and the old
//!    descriptor itself is retired.
//!
//! **Counter-ownership rule** (the size-policy invariant): the mover never
//! creates `UpdateInfo` and never bumps a per-thread `(ins, del)` counter.
//! Migration relocates nodes; the exactly-once counter-CAS of
//! `SizeCalculator::update_metadata` always belongs to the logical
//! inserter/deleter — movers only *help* already-claimed operations commit,
//! which the protocol endorses from any thread. `size()` therefore stays
//! wait-free and exact across a resize. Range scans sample the
//! bucket-migration generation counter (`quanta`) around their
//! double-collect and retry if a bucket relocated mid-sweep, since a
//! relocation moves keys without moving any counter.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::SeqCst};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};

use crate::ebr;
use crate::list::{self, Node};
use crate::set_api::{ConcurrentSet, ResizeStats};
use crate::size::{RefresherSlot, SizeArbiter, SizeCore, SizeOpts, SizePolicy};

/// Resize trigger: grow when occupancy exceeds this many nodes per bucket
/// on average (chains stay O(1) while `size()` stays O(threads)).
pub const RESIZE_CHAIN: i64 = 3;
/// Buckets each helping updater migrates per operation while a resize is
/// in flight.
pub const MIGRATION_QUANTUM: u64 = 4;
/// Hard capacity ceiling (2^22 buckets) — a backstop against runaway
/// doubling, not a tuning knob.
const MAX_CAPACITY: usize = 1 << 22;

/// Process-wide count of resizes triggered by any table (the `csize fuzz`
/// coverage gate uses this to excuse an armed-but-silent `ResizeMigrate`
/// site when no workload ever crossed the load-factor threshold).
static RESIZES_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Total resizes triggered process-wide, across every table instance.
pub fn resizes_total() -> u64 {
    RESIZES_TOTAL.load(SeqCst)
}

/// Fibonacci multiplicative hash: spreads sequential keys across buckets.
#[inline]
fn spread(k: u64) -> u64 {
    k.wrapping_mul(0x9E3779B97F4A7C15) >> 17
}

/// One generation of the bucket array. Buckets hold list head words; the
/// descriptor additionally carries the migration state that moves keys to
/// its successor. Policy-independent: nodes are reached through tagged
/// `u64` words.
struct Table {
    buckets: Box<[AtomicU64]>,
    mask: u64,
    /// Successor descriptor (`*mut Table` as u64), 0 while not resizing.
    /// Set once by the CAS winner of the resize trigger.
    next: AtomicU64,
    /// Next bucket index the quantum sweep will claim.
    cursor: AtomicU64,
    /// Buckets of *this* table not yet `MOVED` to the successor. Hits 0
    /// exactly when the migration out of this table completes.
    remaining: AtomicU64,
}

impl Table {
    fn new(capacity: usize) -> Self {
        Table {
            buckets: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            mask: capacity as u64 - 1,
            next: AtomicU64::new(0),
            cursor: AtomicU64::new(0),
            remaining: AtomicU64::new(capacity as u64),
        }
    }

    #[inline]
    fn capacity(&self) -> usize {
        self.buckets.len()
    }
}

pub struct HashTableSet<P: SizePolicy> {
    /// Current root [`Table`] (`*mut Table` as u64), EBR-published: ops
    /// pin before dereferencing, and a superseded descriptor is retired
    /// only after the root swings to its successor.
    root: AtomicU64,
    /// Live keys across both generations (logical inserts − deletes).
    occupancy: AtomicI64,
    /// Resizes this table triggered.
    resizes: AtomicU64,
    /// Bucket-migration generation counter: bumped once per bucket that
    /// turns `MOVED`. Scans sample it around their double-collect.
    quanta: AtomicU64,
    /// Serializes the copy/link phase of migration: successor chains are
    /// single-writer while in flight, so splices are plain stores and a
    /// panicked quantum is recoverable (poisoning is cleared and repaired,
    /// never propagated).
    mover: Mutex<()>,
    /// Policy + arbiter, shared with the optional refresher daemon.
    core: Arc<SizeCore<P>>,
    refresher: RefresherSlot,
}

unsafe impl<P: SizePolicy> Send for HashTableSet<P> {}
unsafe impl<P: SizePolicy> Sync for HashTableSet<P> {}

impl<P: SizePolicy> HashTableSet<P> {
    /// `expected_elements` sizes the initial table: capacity = next power
    /// of two `>= expected_elements` (1–2× occupancy, mirroring the
    /// paper). Under load the table grows past this on its own.
    pub fn new(max_threads: usize, expected_elements: usize) -> Self {
        Self::with_opts(max_threads, expected_elements, SizeOpts::default())
    }

    pub fn with_opts(max_threads: usize, expected_elements: usize, opts: SizeOpts) -> Self {
        Self::with_policy(P::new(max_threads, opts), expected_elements)
    }

    pub fn with_policy(policy: P, expected_elements: usize) -> Self {
        let capacity = expected_elements.max(1).next_power_of_two();
        let table = Box::into_raw(Box::new(Table::new(capacity)));
        Self {
            root: AtomicU64::new(table as u64),
            occupancy: AtomicI64::new(0),
            resizes: AtomicU64::new(0),
            quanta: AtomicU64::new(0),
            mover: Mutex::new(()),
            core: Arc::new(SizeCore::new(policy)),
            refresher: RefresherSlot::new(),
        }
    }

    /// Current root descriptor. Caller must hold an EBR pin.
    #[inline]
    fn root_ptr(&self) -> *mut Table {
        debug_assert!(ebr::is_pinned());
        self.root.load(SeqCst) as *mut Table
    }

    pub fn policy(&self) -> &P {
        &self.core.policy
    }

    /// The combining size arbiter behind `size_exact` / `size_recent`.
    pub fn arbiter(&self) -> &SizeArbiter {
        &self.core.arbiter
    }

    /// Current bucket count (doubles across resizes).
    pub fn capacity(&self) -> usize {
        let _guard = ebr::pin();
        unsafe { &*self.root_ptr() }.capacity()
    }

    /// Resizes this table has triggered.
    pub fn resizes(&self) -> u64 {
        self.resizes.load(SeqCst)
    }

    /// Buckets still awaiting migration (0 when no resize is in flight).
    pub fn migration_pending(&self) -> u64 {
        let _guard = ebr::pin();
        let t = unsafe { &*self.root_ptr() };
        if t.next.load(SeqCst) == 0 {
            0
        } else {
            t.remaining.load(SeqCst)
        }
    }

    /// Bucket migrations completed so far (the scan validation generation).
    pub fn migration_quanta(&self) -> u64 {
        self.quanta.load(SeqCst)
    }

    /// Live-key count maintained at the logical insert/delete (drives the
    /// load-factor trigger; exact at quiescence).
    pub fn occupancy(&self) -> i64 {
        self.occupancy.load(SeqCst)
    }

    /// Occupancy over capacity: the resize trigger fires above
    /// [`RESIZE_CHAIN`].
    pub fn load_factor(&self) -> f64 {
        let _guard = ebr::pin();
        let cap = unsafe { &*self.root_ptr() }.capacity();
        self.occupancy.load(SeqCst) as f64 / cap as f64
    }

    /// Drive any in-flight migration to completion (blocking). Tests,
    /// teardown and quiescent accounting use this; regular operations only
    /// ever help by quanta.
    pub fn finish_migration(&self) {
        let _guard = ebr::pin();
        loop {
            let tp = self.root_ptr();
            let t = unsafe { &*tp };
            let np = t.next.load(SeqCst) as *mut Table;
            if np.is_null() {
                return;
            }
            let lock = self.acquire_mover(tp);
            for bi in 0..t.capacity() {
                self.migrate_bucket(tp, np, bi);
            }
            drop(lock);
            // remaining hit 0 inside the loop, so the root has swung; the
            // next iteration re-reads it (and returns unless the successor
            // immediately started its own resize).
        }
    }

    /// Quiescent full count across all buckets (tests). Finishes any
    /// in-flight migration first so exactly one generation holds the keys.
    pub fn quiescent_count(&self) -> usize {
        self.finish_migration();
        let _guard = ebr::pin();
        let t = unsafe { &*self.root_ptr() };
        t.buckets
            .iter()
            .map(list::quiescent_count_at::<P>)
            .sum()
    }

    /// Take the mover mutex, absorbing poison from a helper that panicked
    /// mid-quantum: clear it and recount this table's `remaining` from the
    /// actual head states (the interrupted bucket stays frozen-not-moved,
    /// which the idempotent [`Self::migrate_bucket`] finishes).
    fn acquire_mover(&self, tp: *mut Table) -> MutexGuard<'_, ()> {
        match self.mover.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                let g = poisoned.into_inner();
                self.mover.clear_poison();
                self.repair_after_panic(tp);
                g
            }
        }
    }

    /// Re-derive migration bookkeeping after a mid-quantum panic (mover
    /// lock held). Head words are the ground truth: `remaining` becomes
    /// the count of not-yet-`MOVED` buckets, and a migration whose final
    /// bookkeeping was lost is completed here.
    fn repair_after_panic(&self, tp: *mut Table) {
        let t = unsafe { &*tp };
        let np = t.next.load(SeqCst) as *mut Table;
        if np.is_null() || self.root.load(SeqCst) != tp as u64 {
            return;
        }
        let pending = t
            .buckets
            .iter()
            .filter(|b| b.load(SeqCst) != list::MOVED_HEAD)
            .count() as u64;
        t.remaining.store(pending, SeqCst);
        if pending == 0 {
            self.root.store(np as u64, SeqCst);
            unsafe { ebr::retire(tp) };
        }
    }

    /// Successful-insert hook: bump occupancy and install a successor
    /// descriptor when the load factor crosses [`RESIZE_CHAIN`]. Only the
    /// `next`-CAS winner publishes (the loser frees its allocation); the
    /// migration itself is performed incrementally by every subsequent
    /// updater.
    fn note_insert(&self) {
        let occ = self.occupancy.fetch_add(1, SeqCst) + 1;
        let _guard = ebr::pin();
        let t = unsafe { &*self.root_ptr() };
        let cap = t.capacity();
        if t.next.load(SeqCst) != 0 || cap >= MAX_CAPACITY || occ <= cap as i64 * RESIZE_CHAIN {
            return;
        }
        let successor = Box::into_raw(Box::new(Table::new(cap * 2)));
        if t.next
            .compare_exchange(0, successor as u64, SeqCst, SeqCst)
            .is_ok()
        {
            self.resizes.fetch_add(1, SeqCst);
            RESIZES_TOTAL.fetch_add(1, SeqCst);
        } else {
            drop(unsafe { Box::from_raw(successor) }); // lost the trigger race
        }
    }

    /// Opportunistic helping: migrate up to [`MIGRATION_QUANTUM`] buckets
    /// if the mover mutex is free (never blocks the calling operation).
    fn help_quanta(&self, tp: *mut Table, np: *mut Table) {
        let t = unsafe { &*tp };
        if t.remaining.load(SeqCst) == 0 {
            return;
        }
        let lock = match self.mover.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(poisoned)) => {
                let g = poisoned.into_inner();
                self.mover.clear_poison();
                self.repair_after_panic(tp);
                g
            }
            Err(TryLockError::WouldBlock) => return, // someone else is moving
        };
        let cap = t.capacity() as u64;
        for _ in 0..MIGRATION_QUANTUM {
            let bi = t.cursor.fetch_add(1, SeqCst);
            if bi >= cap {
                // Sweep exhausted. Any straggler bucket (claimed by a
                // helper that then panicked) is finished here so the
                // migration always terminates.
                if t.remaining.load(SeqCst) > 0 {
                    for bi in 0..t.capacity() {
                        self.migrate_bucket(tp, np, bi);
                    }
                }
                break;
            }
            self.migrate_bucket(tp, np, bi as usize);
        }
        drop(lock);
    }

    /// Blocking help for one bucket an operation depends on: waits for the
    /// mover mutex and finishes the bucket before returning. Cheap no-op
    /// once the bucket is `MOVED`.
    fn complete_bucket(&self, tp: *mut Table, np: *mut Table, bi: usize) {
        let t = unsafe { &*tp };
        if t.buckets[bi].load(SeqCst) == list::MOVED_HEAD {
            return;
        }
        let lock = self.acquire_mover(tp);
        self.migrate_bucket(tp, np, bi);
        drop(lock);
    }

    /// Migrate one bucket (mover lock held; idempotent and resumable).
    /// Freeze → copy live nodes into the successor → publish `MOVED` →
    /// retire originals → complete the table swap on the last bucket.
    fn migrate_bucket(&self, tp: *mut Table, np: *mut Table, bi: usize) {
        let t = unsafe { &*tp };
        let n = unsafe { &*np };
        let head = &t.buckets[bi];
        if head.load(SeqCst) == list::MOVED_HEAD {
            return;
        }
        let frozen = list::freeze_chain::<P>(head);
        // Chaos plane: Delay/Yield stretch the frozen window; Panic kills
        // this helper mid-quantum (the next mover repairs and finishes).
        crate::faults::jitter(crate::faults::FaultSite::ResizeMigrate);

        let policy = &self.core.policy;
        let mut curr = list::addr::<P>(frozen);
        while !curr.is_null() {
            let node = unsafe { &*curr };
            let succ = list::addr::<P>(node.next.load(SeqCst));
            let new_head = &n.buckets[(spread(node.key) & n.mask) as usize];
            if P::TRACKED {
                let raw = P::read_delete_info(&node.delete_info);
                if list::is_seal(raw) {
                    // An interrupted pass already sealed it: make sure its
                    // copy made it into the successor chain.
                    unsafe { list::link_exclusive(new_head, list::seal_ptr::<P>(raw)) };
                } else if raw != 0 {
                    // Logically deleted: commit the metadata (helping — the
                    // deleter owns the counter-CAS, which is idempotent),
                    // copy nothing.
                    policy.commit_delete(raw);
                } else {
                    // Live: linearize any pending insert, then race the
                    // seal against late delete claims on the same word.
                    policy.help_insert(&node.insert_info);
                    let copy = Node::<P>::alloc(node.key, node.value.load(SeqCst), 0);
                    let seal = copy as u64 | list::SEAL_TAG;
                    let winner = P::try_claim_delete(&node.delete_info, seal);
                    if winner == seal {
                        let outcome = unsafe { list::link_exclusive(new_head, copy) };
                        debug_assert_eq!(outcome, list::LinkOutcome::Linked);
                    } else {
                        // A real delete claimed it first: help it commit
                        // and discard the unpublished copy.
                        policy.commit_delete(winner);
                        drop(unsafe { Box::from_raw(copy) });
                    }
                }
            } else if !list::is_marked(node.next.load(SeqCst)) {
                // Untracked: the (now-immutable) mark bit is the deleted
                // state. Copies are deduplicated by key on recovery.
                let copy = Node::<P>::alloc(node.key, node.value.load(SeqCst), 0);
                if unsafe { list::link_exclusive(new_head, copy) } == list::LinkOutcome::DuplicateKey
                {
                    drop(unsafe { Box::from_raw(copy) });
                }
            }
            curr = succ;
        }

        head.store(list::MOVED_HEAD, SeqCst);
        self.quanta.fetch_add(1, SeqCst);

        // Originals are unreachable to post-`MOVED` readers; pre-freeze
        // traversals still inside the chain hold EBR pins.
        let mut curr = list::addr::<P>(frozen);
        while !curr.is_null() {
            let succ = list::addr::<P>(unsafe { &*curr }.next.load(SeqCst));
            unsafe { ebr::retire(curr) };
            curr = succ;
        }

        if t.remaining.fetch_sub(1, SeqCst) == 1 {
            // Last bucket: the successor becomes the root and this
            // descriptor retires through the same epochs as its nodes.
            self.root.store(np as u64, SeqCst);
            unsafe { ebr::retire(tp) };
        }
    }

    /// Route an update to the authoritative bucket for `k`, helping the
    /// in-flight migration by a quantum first. `op` returns `None` when
    /// the chain froze/moved under it, in which case the bucket is
    /// completed (blocking) and the operation retries against the
    /// successor.
    fn route_update<R>(&self, k: u64, op: impl Fn(&AtomicU64) -> Option<R>) -> R {
        let _guard = ebr::pin();
        let h = spread(k);
        loop {
            let tp = self.root_ptr();
            let t = unsafe { &*tp };
            let bi = (h & t.mask) as usize;
            let np = t.next.load(SeqCst) as *mut Table;
            if np.is_null() {
                match op(&t.buckets[bi]) {
                    Some(r) => return r,
                    None => continue, // a resize started mid-op: re-route
                }
            }
            self.help_quanta(tp, np);
            let n = unsafe { &*np };
            let w = t.buckets[bi].load(SeqCst);
            if w != list::MOVED_HEAD {
                if list::is_frozen(w) {
                    self.complete_bucket(tp, np, bi);
                } else {
                    match op(&t.buckets[bi]) {
                        Some(r) => return r,
                        None => self.complete_bucket(tp, np, bi),
                    }
                }
            }
            match op(&n.buckets[(h & n.mask) as usize]) {
                Some(r) => return r,
                // The successor itself began resizing (this migration
                // finished and the next one started): re-read the root.
                None => continue,
            }
        }
    }

    /// Route a read to the authoritative bucket for `k`. Never blocks on
    /// migration: frozen chains answer reads directly; only a fully-moved
    /// bucket redirects to the successor.
    fn route_read<R>(&self, k: u64, op: impl Fn(&AtomicU64) -> Option<R>) -> R {
        let _guard = ebr::pin();
        let h = spread(k);
        loop {
            let tp = self.root_ptr();
            let t = unsafe { &*tp };
            let np = t.next.load(SeqCst) as *mut Table;
            if let Some(r) = op(&t.buckets[(h & t.mask) as usize]) {
                return r;
            }
            // Bucket is MOVED. If `next` reads null the migration completed
            // between the two loads — re-read the root.
            if np.is_null() {
                continue;
            }
            let n = unsafe { &*np };
            if let Some(r) = op(&n.buckets[(h & n.mask) as usize]) {
                return r;
            }
            // Successor bucket moved too (a following resize): retry.
        }
    }

    /// One full-table collect attempt for [`ConcurrentSet::scan`]. `None`
    /// when a bucket relocated under the sweep irrecoverably (the root or
    /// successor advanced); the scan loop retries from the fresh root.
    fn sweep(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let tp = self.root_ptr();
        let t = unsafe { &*tp };
        let cap = t.capacity();
        let policy = &self.core.policy;
        let mut out = Vec::new();
        for bi in 0..cap {
            if t.buckets[bi].load(SeqCst) == list::MOVED_HEAD {
                // One indirection: this bucket's keys split across the
                // successor buckets bi and bi + cap.
                let np = t.next.load(SeqCst) as *mut Table;
                debug_assert!(!np.is_null(), "MOVED bucket without a successor");
                let n = unsafe { &*np };
                list::try_collect_range_at(policy, &n.buckets[bi], lo, hi, &mut out)?;
                list::try_collect_range_at(policy, &n.buckets[bi + cap], lo, hi, &mut out)?;
            } else {
                // Normal or frozen: the old chain is authoritative (seals
                // read as live; see list::try_collect_range_at).
                list::try_collect_range_at(policy, &t.buckets[bi], lo, hi, &mut out)?;
            }
        }
        Some(out)
    }
}

impl<P: SizePolicy> ConcurrentSet for HashTableSet<P> {
    fn insert(&self, k: u64) -> bool {
        self.put(k, 0)
    }
    fn delete(&self, k: u64) -> bool {
        let removed =
            self.route_update(k, |head| list::try_delete_at(&self.core.policy, head, k));
        if removed {
            self.occupancy.fetch_sub(1, SeqCst);
        }
        removed
    }
    fn contains(&self, k: u64) -> bool {
        self.route_read(k, |head| list::try_contains_at(&self.core.policy, head, k))
    }
    fn put(&self, k: u64, v: u64) -> bool {
        let fresh = self
            .route_update(k, |head| list::try_put_at(&self.core.policy, head, k, v, true));
        if fresh {
            self.note_insert();
        }
        fresh
    }
    fn get(&self, k: u64) -> Option<u64> {
        self.route_read(k, |head| list::try_get_at(&self.core.policy, head, k))
    }

    // A range scan has no locality in a hashed table: the collect sweeps
    // every bucket and sorts, with the whole sweep inside one
    // double-collect window so the merged view is still a membership
    // snapshot. Migration moves keys without moving counters, so the sweep
    // additionally brackets itself with the bucket-migration generation
    // (`quanta`) and retries when a bucket relocated mid-collect.
    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _guard = crate::ebr::pin();
        let _op = self.core.policy.enter_read();
        let calc = self.core.policy.calculator();
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let gen_before = self.quanta.load(SeqCst);
            let (swept, validated) =
                crate::size::validated_collect(calc, || self.sweep(lo, hi));
            let gen_after = self.quanta.load(SeqCst);
            let Some(mut pairs) = swept else {
                if attempts >= crate::size::SCAN_RETRIES {
                    // Force a stable view rather than spinning against a
                    // resize storm.
                    self.finish_migration();
                }
                continue;
            };
            let counters_ok = validated || calc.is_none();
            if (counters_ok && gen_before == gen_after) || attempts >= crate::size::SCAN_RETRIES {
                pairs.sort_unstable_by_key(|&(k, _)| k);
                return Some(pairs);
            }
        }
    }

    crate::size::impl_size_surface!(except_stats);

    fn size_stats(&self) -> Option<crate::size::ArbiterStats> {
        let mut stats = self.core.stats(self.refresher.rounds());
        stats.resizes = self.resizes();
        stats.migration_pending = self.migration_pending();
        Some(stats)
    }

    fn resize_stats(&self) -> Option<ResizeStats> {
        let _guard = ebr::pin();
        let capacity = unsafe { &*self.root_ptr() }.capacity();
        let occupancy = self.occupancy.load(SeqCst);
        Some(ResizeStats {
            capacity,
            occupancy,
            resizes: self.resizes(),
            migration_pending: self.migration_pending(),
            load_factor: occupancy as f64 / capacity as f64,
        })
    }

    fn name(&self) -> String {
        format!(
            "HashTable<{}>",
            std::any::type_name::<P>().rsplit("::").next().unwrap()
        )
    }
}

impl<P: SizePolicy> Drop for HashTableSet<P> {
    fn drop(&mut self) {
        // Exclusive access: free both generations. MOVED buckets hold no
        // chain (addr of the sentinel is null — their originals went
        // through EBR when they migrated), so this never double-frees.
        let tp = *self.root.get_mut() as *mut Table;
        let t = unsafe { Box::from_raw(tp) };
        let np = t.next.load(SeqCst) as *mut Table;
        for b in t.buckets.iter() {
            unsafe { list::drop_chain::<P>(b) };
        }
        if !np.is_null() {
            let n = unsafe { Box::from_raw(np) };
            for b in n.buckets.iter() {
                unsafe { list::drop_chain::<P>(b) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NaiveSize, NoSize};
    use std::sync::atomic::Ordering::SeqCst;
    use std::sync::Arc;

    fn table() -> HashTableSet<LinearizableSize> {
        HashTableSet::new(crate::MAX_THREADS, 256)
    }

    #[test]
    fn capacity_is_power_of_two() {
        let t: HashTableSet<NoSize> = HashTableSet::new(4, 100);
        assert_eq!(t.capacity(), 128);
        let t: HashTableSet<NoSize> = HashTableSet::new(4, 128);
        assert_eq!(t.capacity(), 128);
    }

    #[test]
    fn basic_ops() {
        let t = table();
        assert!(t.insert(10));
        assert!(!t.insert(10));
        assert!(t.contains(10));
        assert!(!t.contains(11));
        assert!(t.delete(10));
        assert!(!t.delete(10));
        assert_eq!(t.size(), Some(0));
    }

    #[test]
    fn size_spans_buckets() {
        let t = table();
        for k in 0..1000 {
            assert!(t.insert(k));
        }
        assert_eq!(t.size(), Some(1000));
        assert_eq!(t.quiescent_count(), 1000);
        for k in 0..1000 {
            assert!(t.delete(k));
        }
        assert_eq!(t.size(), Some(0));
    }

    #[test]
    fn scan_sweeps_buckets_in_key_order() {
        let t = table();
        for k in (0..100u64).rev() {
            assert!(t.put(k, k * 10));
        }
        let pairs = t.scan(25, 34).unwrap();
        let want: Vec<_> = (25..=34).map(|k| (k, k * 10)).collect();
        assert_eq!(pairs, want);
        assert_eq!(t.count_range(0, 99), Some(100));
        assert!(!t.put(30, 7), "upsert over an existing key reports 0");
        assert_eq!(t.get(30), Some(7));
        assert_eq!(t.scan(30, 30), Some(vec![(30, 7)]));
        assert!(t.delete(30));
        assert_eq!(t.count_range(25, 34), Some(9));
    }

    #[test]
    fn colliding_keys_coexist() {
        // Keys an exact capacity apart can collide; both must be stored.
        // With incremental resize the table grows under these inserts,
        // which must not lose keys either.
        let t: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 2);
        for k in 0..64 {
            assert!(t.insert(k));
        }
        for k in 0..64 {
            assert!(t.contains(k), "lost key {k}");
        }
        assert_eq!(t.size(), Some(64));
        assert!(t.resizes() >= 1, "64 keys over 2 buckets must resize");
    }

    #[test]
    fn growth_preserves_membership_and_size() {
        let t: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 8);
        let initial_cap = t.capacity();
        for k in 0..400u64 {
            assert!(t.put(k, k + 1));
            assert_eq!(t.size(), Some(k as i64 + 1), "size wrong mid-growth");
        }
        assert!(t.resizes() >= 1, "10x occupancy must trigger a resize");
        assert!(t.capacity() > initial_cap);
        for k in 0..400u64 {
            assert_eq!(t.get(k), Some(k + 1), "lost key {k} across migration");
        }
        t.finish_migration();
        assert_eq!(t.migration_pending(), 0);
        assert_eq!(t.quiescent_count(), 400);
        assert_eq!(t.size(), Some(400));
        assert_eq!(t.occupancy(), 400);
    }

    #[test]
    fn growth_works_for_untracked_policies() {
        let t: HashTableSet<NaiveSize> = HashTableSet::new(crate::MAX_THREADS, 4);
        for k in 0..200u64 {
            assert!(t.put(k, k * 3));
        }
        assert!(t.resizes() >= 1);
        for k in 0..200u64 {
            assert_eq!(t.get(k), Some(k * 3));
        }
        for k in (0..200u64).step_by(2) {
            assert!(t.delete(k));
        }
        assert_eq!(t.quiescent_count(), 100);
        assert_eq!(t.size(), Some(100));
    }

    #[test]
    fn delete_racing_migration_is_exactly_once() {
        // Seeded interleaving: threads delete while inserts force growth;
        // every key is deleted exactly once and occupancy drains to the
        // survivors.
        for seed in 0..8u64 {
            let t = Arc::new(HashTableSet::<LinearizableSize>::new(crate::MAX_THREADS, 4));
            for k in 0..256 {
                assert!(t.insert(k));
            }
            let wins = Arc::new(std::sync::atomic::AtomicUsize::new(0));
            let hs: Vec<_> = (0..4u64)
                .map(|tid| {
                    let t = t.clone();
                    let wins = wins.clone();
                    std::thread::spawn(move || {
                        let mut rng = crate::rng::Xoshiro256::new(seed * 31 + tid);
                        // Grow the table under the deleters' feet.
                        for k in 256..(256 + 128 * (tid + 1)) {
                            t.insert(k);
                        }
                        for k in 0..256 {
                            if rng.gen_bool(0.5) && t.delete(k) {
                                wins.fetch_add(1, SeqCst);
                            }
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            let survivors = (0..256).filter(|&k| t.contains(k)).count();
            assert_eq!(
                wins.load(SeqCst) + survivors,
                256,
                "seed {seed}: deletes double-counted or lost across migration"
            );
            assert_eq!(t.size().unwrap() as usize, t.quiescent_count());
        }
    }

    #[test]
    fn concurrent_churn_size_matches() {
        let t = Arc::new(table());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..4u64)
            .map(|tid| {
                let t = t.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(tid);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(512);
                        if rng.gen_bool(0.5) {
                            t.insert(k);
                        } else {
                            t.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..500 {
            let s = t.size().unwrap();
            assert!((0..=512).contains(&s), "size {s} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(t.size().unwrap() as usize, t.quiescent_count());
    }
}
