//! Hash-table set: a static table of Harris-list buckets (paper Section 9:
//! "a table of linked lists whose implementation is based on the linked
//! list", capacity a power of two between 1× and 2× the expected elements,
//! as Java's `ConcurrentHashMap` sizes itself).
//!
//! All buckets share one size policy instance, so `size()` spans the whole
//! table — the metadata is per *thread*, not per bucket (paper Section 5).

use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use crate::list;
use crate::set_api::ConcurrentSet;
use crate::size::{RefresherSlot, SizeArbiter, SizeCore, SizeOpts, SizePolicy};

/// Fibonacci multiplicative hash: spreads sequential keys across buckets.
#[inline]
fn spread(k: u64) -> u64 {
    k.wrapping_mul(0x9E3779B97F4A7C15) >> 17
}

pub struct HashTableSet<P: SizePolicy> {
    buckets: Box<[AtomicU64]>,
    mask: u64,
    /// Policy + arbiter, shared with the optional refresher daemon.
    core: Arc<SizeCore<P>>,
    refresher: RefresherSlot,
}

unsafe impl<P: SizePolicy> Send for HashTableSet<P> {}
unsafe impl<P: SizePolicy> Sync for HashTableSet<P> {}

impl<P: SizePolicy> HashTableSet<P> {
    /// `expected_elements` sizes the table: capacity = next power of two
    /// `>= expected_elements` (1–2× occupancy, mirroring the paper).
    pub fn new(max_threads: usize, expected_elements: usize) -> Self {
        Self::with_opts(max_threads, expected_elements, SizeOpts::default())
    }

    pub fn with_opts(max_threads: usize, expected_elements: usize, opts: SizeOpts) -> Self {
        Self::with_policy(P::new(max_threads, opts), expected_elements)
    }

    pub fn with_policy(policy: P, expected_elements: usize) -> Self {
        let capacity = expected_elements.max(1).next_power_of_two();
        Self {
            buckets: (0..capacity).map(|_| AtomicU64::new(0)).collect(),
            mask: capacity as u64 - 1,
            core: Arc::new(SizeCore::new(policy)),
            refresher: RefresherSlot::new(),
        }
    }

    #[inline]
    fn bucket(&self, k: u64) -> &AtomicU64 {
        &self.buckets[(spread(k) & self.mask) as usize]
    }

    pub fn policy(&self) -> &P {
        &self.core.policy
    }

    /// The combining size arbiter behind `size_exact` / `size_recent`.
    pub fn arbiter(&self) -> &SizeArbiter {
        &self.core.arbiter
    }

    pub fn capacity(&self) -> usize {
        self.buckets.len()
    }

    /// Quiescent full count across all buckets (tests).
    pub fn quiescent_count(&self) -> usize {
        self.buckets
            .iter()
            .map(list::quiescent_count_at::<P>)
            .sum()
    }
}

impl<P: SizePolicy> ConcurrentSet for HashTableSet<P> {
    fn insert(&self, k: u64) -> bool {
        list::insert_at(&self.core.policy, self.bucket(k), k)
    }
    fn delete(&self, k: u64) -> bool {
        list::delete_at(&self.core.policy, self.bucket(k), k)
    }
    fn contains(&self, k: u64) -> bool {
        list::contains_at(&self.core.policy, self.bucket(k), k)
    }
    fn put(&self, k: u64, v: u64) -> bool {
        list::put_at(&self.core.policy, self.bucket(k), k, v, true)
    }
    fn get(&self, k: u64) -> Option<u64> {
        list::get_at(&self.core.policy, self.bucket(k), k)
    }

    // A range scan has no locality in a hashed table: the collect sweeps
    // every bucket and sorts, with the whole sweep inside one
    // double-collect window so the merged view is still a membership
    // snapshot.
    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _guard = crate::ebr::pin();
        let _op = self.core.policy.enter_read();
        let (mut pairs, _validated) =
            crate::size::validated_collect(self.core.policy.calculator(), || {
                let mut out = Vec::new();
                for bucket in self.buckets.iter() {
                    list::collect_range_at(&self.core.policy, bucket, lo, hi, &mut out);
                }
                out
            });
        pairs.sort_unstable_by_key(|&(k, _)| k);
        Some(pairs)
    }

    crate::size::impl_size_surface!();

    fn name(&self) -> String {
        format!(
            "HashTable<{}>",
            std::any::type_name::<P>().rsplit("::").next().unwrap()
        )
    }
}

impl<P: SizePolicy> Drop for HashTableSet<P> {
    fn drop(&mut self) {
        for b in self.buckets.iter() {
            unsafe { list::drop_chain::<P>(b) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NoSize};
    use std::sync::atomic::Ordering::SeqCst;
    use std::sync::Arc;

    fn table() -> HashTableSet<LinearizableSize> {
        HashTableSet::new(crate::MAX_THREADS, 256)
    }

    #[test]
    fn capacity_is_power_of_two() {
        let t: HashTableSet<NoSize> = HashTableSet::new(4, 100);
        assert_eq!(t.capacity(), 128);
        let t: HashTableSet<NoSize> = HashTableSet::new(4, 128);
        assert_eq!(t.capacity(), 128);
    }

    #[test]
    fn basic_ops() {
        let t = table();
        assert!(t.insert(10));
        assert!(!t.insert(10));
        assert!(t.contains(10));
        assert!(!t.contains(11));
        assert!(t.delete(10));
        assert!(!t.delete(10));
        assert_eq!(t.size(), Some(0));
    }

    #[test]
    fn size_spans_buckets() {
        let t = table();
        for k in 0..1000 {
            assert!(t.insert(k));
        }
        assert_eq!(t.size(), Some(1000));
        assert_eq!(t.quiescent_count(), 1000);
        for k in 0..1000 {
            assert!(t.delete(k));
        }
        assert_eq!(t.size(), Some(0));
    }

    #[test]
    fn scan_sweeps_buckets_in_key_order() {
        let t = table();
        for k in (0..100u64).rev() {
            assert!(t.put(k, k * 10));
        }
        let pairs = t.scan(25, 34).unwrap();
        let want: Vec<_> = (25..=34).map(|k| (k, k * 10)).collect();
        assert_eq!(pairs, want);
        assert_eq!(t.count_range(0, 99), Some(100));
        assert!(!t.put(30, 7), "upsert over an existing key reports 0");
        assert_eq!(t.get(30), Some(7));
        assert_eq!(t.scan(30, 30), Some(vec![(30, 7)]));
        assert!(t.delete(30));
        assert_eq!(t.count_range(25, 34), Some(9));
    }

    #[test]
    fn colliding_keys_coexist() {
        // Keys an exact capacity apart can collide; both must be stored.
        let t: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 2);
        for k in 0..64 {
            assert!(t.insert(k));
        }
        for k in 0..64 {
            assert!(t.contains(k), "lost key {k}");
        }
        assert_eq!(t.size(), Some(64));
    }

    #[test]
    fn concurrent_churn_size_matches() {
        let t = Arc::new(table());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..4u64)
            .map(|tid| {
                let t = t.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(tid);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(512);
                        if rng.gen_bool(0.5) {
                            t.insert(k);
                        } else {
                            t.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..500 {
            let s = t.size().unwrap();
            assert!((0..=512).contains(&s), "size {s} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(t.size().unwrap() as usize, t.quiescent_count());
    }
}
