//! Operation histories and the offline size-linearizability checker.
//!
//! The paper's correctness arguments (Sections 1, 8) revolve around two
//! observable invariants of any *legal* set history:
//!
//! 1. the running size (prefix sum of +1/−1 update deltas in linearization
//!    order) is never negative — the naive counter-after-op scheme violates
//!    this (Figure 2);
//! 2. any `size()` return value equals the running size at its
//!    linearization point; at quiescence it equals the exact element count.
//!
//! This module records update deltas (in commit order, which for a single
//! recording stream equals linearization order) and checks the invariants —
//! both with a pure-Rust oracle and, in the e2e example, through the
//! AOT-compiled Pallas pipeline (`prefix_scan` / `history_stats`), which
//! must agree bit-exactly.
//!
//! For *concurrent* histories — where commit order is unknowable — the
//! [`monitor`] submodule generalizes the checker: timestamped op/size
//! events with an interval-order justification bound per size call.

pub mod monitor;

use std::sync::Mutex;

/// Statistics of a running-size series; mirrors the `history_stats` Pallas
/// kernel output `[min, max, final, negative-count]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistoryStats {
    pub min: i64,
    pub max: i64,
    pub final_size: i64,
    pub negative_count: i64,
}

impl HistoryStats {
    /// A history is legal for a set iff its running size never dips below
    /// zero.
    pub fn is_legal(&self) -> bool {
        self.min >= 0 && self.negative_count == 0
    }

    pub fn as_array(&self) -> [i64; 4] {
        [self.min, self.max, self.final_size, self.negative_count]
    }
}

/// Inclusive prefix sums of `deltas` (the Rust oracle for the Pallas
/// `prefix_scan` kernel).
pub fn running_sizes(deltas: &[i64]) -> Vec<i64> {
    let mut out = Vec::with_capacity(deltas.len());
    let mut acc = 0i64;
    for &d in deltas {
        acc += d;
        out.push(acc);
    }
    out
}

/// Stats over a running-size series (oracle for the `history_stats`
/// kernel; empty series uses the kernel's fold identities).
pub fn stats_of(running: &[i64]) -> HistoryStats {
    if running.is_empty() {
        return HistoryStats {
            min: i64::MAX,
            max: -i64::MAX,
            final_size: 0,
            negative_count: 0,
        };
    }
    HistoryStats {
        min: running.iter().copied().min().unwrap(),
        max: running.iter().copied().max().unwrap(),
        final_size: *running.last().unwrap(),
        negative_count: running.iter().filter(|&&x| x < 0).count() as i64,
    }
}

/// Validate a delta log end to end.
pub fn validate(deltas: &[i64]) -> (Vec<i64>, HistoryStats) {
    let running = running_sizes(deltas);
    let stats = stats_of(&running);
    (running, stats)
}

/// Thread-safe append-only delta log used by examples/tests to capture
/// update commit order.
#[derive(Default)]
pub struct DeltaLog {
    deltas: Mutex<Vec<i64>>,
}

impl DeltaLog {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_insert(&self) {
        self.deltas.lock().unwrap().push(1);
    }

    /// Record an arbitrary delta (e.g., a bulk prefill as one `+n` entry so
    /// the log's running size is absolute rather than relative).
    pub fn record_delta(&self, delta: i64) {
        self.deltas.lock().unwrap().push(delta);
    }

    pub fn record_delete(&self) {
        self.deltas.lock().unwrap().push(-1);
    }

    pub fn snapshot(&self) -> Vec<i64> {
        self.deltas.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.deltas.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite;

    #[test]
    fn running_sizes_telescope() {
        assert_eq!(running_sizes(&[1, 1, -1, 1]), vec![1, 2, 1, 2]);
        assert_eq!(running_sizes(&[]), Vec::<i64>::new());
    }

    #[test]
    fn stats_detect_negative_histories() {
        let (_, s) = validate(&[-1, 1]);
        assert_eq!(s.min, -1);
        assert_eq!(s.negative_count, 1);
        assert!(!s.is_legal());
    }

    #[test]
    fn legal_history_passes() {
        let (_, s) = validate(&[1, 1, -1, -1, 1]);
        assert_eq!(
            s,
            HistoryStats {
                min: 0,
                max: 2,
                final_size: 1,
                negative_count: 0
            }
        );
        assert!(s.is_legal());
    }

    #[test]
    fn delta_log_records_in_order() {
        let log = DeltaLog::new();
        log.record_insert();
        log.record_insert();
        log.record_delete();
        assert_eq!(log.snapshot(), vec![1, 1, -1]);
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn prop_legal_generator_always_legal() {
        proptest_lite::run("legal histories validate", |rng| {
            let mut deltas = Vec::new();
            let mut cur = 0i64;
            for _ in 0..rng.gen_range(500) {
                if cur > 0 && rng.gen_bool(0.5) {
                    deltas.push(-1);
                    cur -= 1;
                } else {
                    deltas.push(1);
                    cur += 1;
                }
            }
            let (running, stats) = validate(&deltas);
            crate::prop_assert!(stats.is_legal(), "legal history flagged: {stats:?}");
            crate::prop_assert!(
                running.last().copied().unwrap_or(0) == cur,
                "final mismatch"
            );
            Ok(())
        });
    }

    #[test]
    fn prop_stats_match_bruteforce() {
        proptest_lite::run("stats == brute force", |rng| {
            let n = rng.gen_range(200) as usize;
            let deltas: Vec<i64> = (0..n).map(|_| rng.gen_range(5) as i64 - 2).collect();
            let (running, stats) = validate(&deltas);
            if !running.is_empty() {
                crate::prop_assert!(stats.min == *running.iter().min().unwrap());
                crate::prop_assert!(stats.max == *running.iter().max().unwrap());
                crate::prop_assert!(stats.final_size == *running.last().unwrap());
            }
            Ok(())
        });
    }
}
