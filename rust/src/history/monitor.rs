//! Online size-linearizability monitor: timestamped op/size histories
//! with an interval-order justification check.
//!
//! The [`super::DeltaLog`] checker handles the degenerate case where one
//! recording stream serializes every update: commit order *is*
//! linearization order, so running prefix sums pin each size exactly.
//! This module generalizes it to fully concurrent histories. Each update
//! and each `size()` call is recorded with its invocation/response
//! timestamps (one monotonic clock for all threads); a size return `v` is
//! **justified** iff some linearization of the recorded history assigns
//! the size call a point `t` inside its window at which the running size
//! is `v`. Exhaustive linearization search is exponential, so the monitor
//! checks the standard interval bound, which is a *necessary* condition —
//! it never flags a legal history (no false positives), though an exotic
//! illegal one could slip through:
//!
//! * every successful update whose response precedes the size call's
//!   invocation must be counted (its linearization point is before any
//!   `t` in the window);
//! * an update whose invocation follows the size call's response cannot
//!   be counted;
//! * updates overlapping the window are free: any subset sum is
//!   reachable because deltas are ±1;
//! * and the set started empty, so no point can have a negative running
//!   size — `v < 0` is never justified (the paper's Figure 2 anomaly).
//!
//! Hence `v` must lie in `[max(definite − overlapping deletes, 0),
//! definite + overlapping inserts]`. With no concurrency the overlap
//! sets are empty and the check collapses to the DeltaLog prefix sums.
//!
//! Bounded-staleness reads (`size_recent`) are checked by widening the
//! window backward by the reported [`crate::size::SizeView::age`]
//! ([`Monitor::commit_size_with_slack`]): the value was exact at some
//! point at most `age` before the read, so justification is against the
//! widened window.
//!
//! # Range scans
//!
//! [`check_scan`] extends the same interval discipline from *counts* to
//! *key sets*. Updates are recorded per key ([`KeyedUpdateEvent`]); a
//! scan return ([`ScanEvent`]) is justified iff some point `t` in its
//! window has exactly the reported keys present. The checkable necessary
//! condition, per key `k` in `[lo, hi]`:
//!
//! * no update of `k` overlaps the scan window → `k`'s membership is
//!   *pinned* over the whole window (the net of updates responding before
//!   the invocation), so the scan must report `k` iff that net is 1;
//! * some update of `k` overlaps → `k` is free: either answer is
//!   justifiable;
//! * a reported key outside `[lo, hi]`, or one the history never
//!   inserted, is never justified.
//!
//! A [`CountEvent`] is bounded by the same per-key analysis summed:
//! `value ∈ [#must-be-present, #may-be-present]` (the floor is 0 by
//! construction — membership bounds cannot go negative). Like the size
//! check this never flags a legal history; and because it is purely
//! interval-based it also accepts the *per-key-justified* fallback scans
//! of untracked policies, so a violation always means a real torn scan.

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One successful update: ±1 delta with its call window (nanoseconds
/// since the monitor's origin).
#[derive(Clone, Copy, Debug)]
pub struct UpdateEvent {
    pub inv: u64,
    pub resp: u64,
    pub delta: i64,
}

/// One size observation with its (possibly slack-widened) call window.
#[derive(Clone, Copy, Debug)]
pub struct SizeEvent {
    pub inv: u64,
    pub resp: u64,
    pub value: i64,
}

/// One successful update *with its key* — the raw material for
/// [`check_scan`]'s per-key membership analysis.
#[derive(Clone, Copy, Debug)]
pub struct KeyedUpdateEvent {
    pub key: u64,
    pub inv: u64,
    pub resp: u64,
    pub delta: i64,
}

/// One range-scan observation: the window, the queried range, and the
/// key set the scan reported (values are per-key atomic reads outside
/// the membership contract, so the checker ignores them).
#[derive(Clone, Debug)]
pub struct ScanEvent {
    pub inv: u64,
    pub resp: u64,
    pub lo: u64,
    pub hi: u64,
    pub keys: Vec<u64>,
}

/// One range-count observation.
#[derive(Clone, Copy, Debug)]
pub struct CountEvent {
    pub inv: u64,
    pub resp: u64,
    pub lo: u64,
    pub hi: u64,
    pub value: i64,
}

/// A scan or count observation no linearization justifies.
#[derive(Clone, Copy, Debug)]
pub struct ScanViolation {
    /// The offending observation's window.
    pub inv: u64,
    pub resp: u64,
    /// The offending key for a membership violation; `None` for a count
    /// out of bounds.
    pub key: Option<u64>,
    /// Whether the scan reported the key (membership violations only).
    pub reported: bool,
    /// The observed value against the justified `[low, high]`: per-key
    /// membership (0/1) for scans, the returned count for counts.
    pub value: i64,
    pub low: i64,
    pub high: i64,
}

/// Outcome of [`Monitor::verify_scans`] / [`check_scan`].
#[derive(Debug, Default)]
pub struct ScanReport {
    pub updates: usize,
    pub scans_checked: usize,
    pub counts_checked: usize,
    pub violations: Vec<ScanViolation>,
}

impl ScanReport {
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// A size return no linearization of the recorded history justifies.
#[derive(Clone, Copy, Debug)]
pub struct Violation {
    pub event: SizeEvent,
    /// The justified range the value fell outside of.
    pub low: i64,
    pub high: i64,
}

/// Outcome of [`Monitor::verify`] / [`check`].
#[derive(Debug, Default)]
pub struct Report {
    pub updates: usize,
    pub sizes_checked: usize,
    pub violations: Vec<Violation>,
    /// Net delta of all recorded updates (the exact quiescent size when
    /// the monitor saw every update).
    pub final_net: i64,
}

impl Report {
    pub fn is_ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// In-flight call handle: captures the invocation timestamp.
#[derive(Clone, Copy, Debug)]
pub struct Timer {
    inv: u64,
}

/// Thread-safe history recorder (see module docs).
pub struct Monitor {
    origin: Instant,
    updates: Mutex<Vec<UpdateEvent>>,
    sizes: Mutex<Vec<SizeEvent>>,
    keyed: Mutex<Vec<KeyedUpdateEvent>>,
    scans: Mutex<Vec<ScanEvent>>,
    counts: Mutex<Vec<CountEvent>>,
}

impl Default for Monitor {
    fn default() -> Self {
        Self::new()
    }
}

impl Monitor {
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
            updates: Mutex::new(Vec::new()),
            sizes: Mutex::new(Vec::new()),
            keyed: Mutex::new(Vec::new()),
            scans: Mutex::new(Vec::new()),
            counts: Mutex::new(Vec::new()),
        }
    }

    #[inline]
    fn now(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    /// Stamp the invocation of an operation about to run.
    #[inline]
    pub fn begin(&self) -> Timer {
        Timer { inv: self.now() }
    }

    /// Record a completed *successful* update (`delta` ±1). Failed
    /// updates and `contains` don't move the size — don't record them.
    pub fn commit_update(&self, timer: Timer, delta: i64) {
        let resp = self.now();
        self.updates.lock().unwrap().push(UpdateEvent {
            inv: timer.inv,
            resp,
            delta,
        });
    }

    /// Record a completed linearizable size observation.
    pub fn commit_size(&self, timer: Timer, value: i64) {
        self.commit_size_with_slack(timer, value, Duration::ZERO);
    }

    /// Record a size observation whose value may date back `slack`
    /// before the invocation (a `size_recent` hit reports its `age`).
    pub fn commit_size_with_slack(&self, timer: Timer, value: i64, slack: Duration) {
        let resp = self.now();
        let inv = timer.inv.saturating_sub(slack.as_nanos() as u64);
        self.sizes.lock().unwrap().push(SizeEvent { inv, resp, value });
    }

    /// Check every recorded size observation against the recorded
    /// updates (call after all recording threads joined).
    pub fn verify(&self) -> Report {
        let updates = self.updates.lock().unwrap();
        let sizes = self.sizes.lock().unwrap();
        check(&updates, &sizes)
    }

    /// Snapshot the recorded history — the raw material for repro
    /// dumping and [`minimize`].
    pub fn events(&self) -> (Vec<UpdateEvent>, Vec<SizeEvent>) {
        (self.updates.lock().unwrap().clone(), self.sizes.lock().unwrap().clone())
    }

    /// Record a completed successful update with its key. The event
    /// feeds *both* streams — the unkeyed one (so [`Self::verify`] still
    /// checks sizes against it) and the keyed one for
    /// [`Self::verify_scans`].
    pub fn commit_keyed_update(&self, timer: Timer, key: u64, delta: i64) {
        let resp = self.now();
        self.updates.lock().unwrap().push(UpdateEvent {
            inv: timer.inv,
            resp,
            delta,
        });
        self.keyed.lock().unwrap().push(KeyedUpdateEvent {
            key,
            inv: timer.inv,
            resp,
            delta,
        });
    }

    /// Record a completed range scan's reported key set.
    pub fn commit_scan(&self, timer: Timer, lo: u64, hi: u64, keys: Vec<u64>) {
        let resp = self.now();
        self.scans.lock().unwrap().push(ScanEvent {
            inv: timer.inv,
            resp,
            lo,
            hi,
            keys,
        });
    }

    /// Record a completed range count.
    pub fn commit_count(&self, timer: Timer, lo: u64, hi: u64, value: i64) {
        let resp = self.now();
        self.counts.lock().unwrap().push(CountEvent {
            inv: timer.inv,
            resp,
            lo,
            hi,
            value,
        });
    }

    /// Check every recorded scan and count against the keyed updates
    /// (call after all recording threads joined). Sound only if *every*
    /// successful update went through [`Self::commit_keyed_update`] — a
    /// key updated outside the keyed stream looks never-inserted.
    pub fn verify_scans(&self) -> ScanReport {
        let keyed = self.keyed.lock().unwrap();
        let scans = self.scans.lock().unwrap();
        let counts = self.counts.lock().unwrap();
        check_scan(&keyed, &scans, &counts)
    }

    /// Snapshot the scan-side history (repro dumping, [`minimize_scan`]).
    pub fn scan_events(&self) -> (Vec<KeyedUpdateEvent>, Vec<ScanEvent>, Vec<CountEvent>) {
        (
            self.keyed.lock().unwrap().clone(),
            self.scans.lock().unwrap().clone(),
            self.counts.lock().unwrap().clone(),
        )
    }
}

/// Per-sign event times, sorted for binary search.
struct SignIndex {
    /// Response times of +1 (resp. −1) updates, sorted.
    resp: Vec<u64>,
    /// Invocation times, sorted.
    inv: Vec<u64>,
}

impl SignIndex {
    fn build(updates: &[UpdateEvent], sign: i64) -> Self {
        let mut resp: Vec<u64> = updates
            .iter()
            .filter(|u| u.delta.signum() == sign)
            .map(|u| u.resp)
            .collect();
        let mut inv: Vec<u64> = updates
            .iter()
            .filter(|u| u.delta.signum() == sign)
            .map(|u| u.inv)
            .collect();
        resp.sort_unstable();
        inv.sort_unstable();
        Self { resp, inv }
    }

    /// Updates of this sign that definitely precede `t` (resp < t).
    fn done_before(&self, t: u64) -> usize {
        self.resp.partition_point(|&r| r < t)
    }

    /// Updates of this sign invoked at or before `t` (inv <= t).
    fn started_by(&self, t: u64) -> usize {
        self.inv.partition_point(|&i| i <= t)
    }
}

/// The pure checking core behind [`Monitor::verify`] (separated so tests
/// can feed synthetic histories).
pub fn check(updates: &[UpdateEvent], sizes: &[SizeEvent]) -> Report {
    debug_assert!(
        updates.iter().all(|u| u.delta == 1 || u.delta == -1),
        "monitor updates must be unit deltas"
    );
    let plus = SignIndex::build(updates, 1);
    let minus = SignIndex::build(updates, -1);
    let mut report = Report {
        updates: updates.len(),
        sizes_checked: sizes.len(),
        final_net: plus.resp.len() as i64 - minus.resp.len() as i64,
        violations: Vec::new(),
    };
    for &s in sizes {
        let definite_plus = plus.done_before(s.inv);
        let definite_minus = minus.done_before(s.inv);
        let definite = definite_plus as i64 - definite_minus as i64;
        // Overlapping = started by the response, not finished before the
        // invocation. Equal timestamps count as overlap: the coarser the
        // clock, the looser (never the stricter) the bound.
        let overlap_plus = plus.started_by(s.resp) - definite_plus;
        let overlap_minus = minus.started_by(s.resp) - definite_minus;
        let low = (definite - overlap_minus as i64).max(0);
        let high = definite + overlap_plus as i64;
        if s.value < low || s.value > high {
            report.violations.push(Violation {
                event: s,
                low,
                high,
            });
        }
    }
    report
}

/// [`check`] generalized to a window that starts mid-history: `anchor` is
/// a linearizable size observation taken when recording began, and every
/// recorded update strictly follows it (the recorder only starts once the
/// anchor completes). Size observations are then justified against
/// `anchor.value` plus the recorded deltas. `slack` widens both bounds by
/// the number of operations that may have been in flight — started before
/// recording, landing inside the window unrecorded (in the live server:
/// the handler pool size). Sizes overlapping or preceding the anchor are
/// skipped, not checked (`sizes_checked` counts only the checked ones).
/// The empty-set floor still applies: no clock slack makes a negative
/// size justifiable.
pub fn check_anchored(
    anchor: &SizeEvent,
    slack: i64,
    updates: &[UpdateEvent],
    sizes: &[SizeEvent],
) -> Report {
    debug_assert!(
        updates.iter().all(|u| u.delta == 1 || u.delta == -1),
        "monitor updates must be unit deltas"
    );
    debug_assert!(slack >= 0, "slack is a count of in-flight ops");
    let plus = SignIndex::build(updates, 1);
    let minus = SignIndex::build(updates, -1);
    let mut report = Report {
        updates: updates.len(),
        sizes_checked: 0,
        final_net: anchor.value + plus.resp.len() as i64 - minus.resp.len() as i64,
        violations: Vec::new(),
    };
    for &s in sizes {
        if s.inv < anchor.resp {
            continue;
        }
        report.sizes_checked += 1;
        let definite_plus = plus.done_before(s.inv);
        let definite_minus = minus.done_before(s.inv);
        let definite = anchor.value + definite_plus as i64 - definite_minus as i64;
        let overlap_plus = plus.started_by(s.resp) - definite_plus;
        let overlap_minus = minus.started_by(s.resp) - definite_minus;
        let low = (definite - overlap_minus as i64 - slack).max(0);
        let high = definite + overlap_plus as i64 + slack;
        if s.value < low || s.value > high {
            report.violations.push(Violation {
                event: s,
                low,
                high,
            });
        }
    }
    report
}

/// [`check`] lifted to a sharded store: `shard_updates[i]` holds the
/// updates that ran on shard `i`, and every size observation is a
/// *global* (aggregated) reading. A global size is justified iff it lies
/// in the **sum of the per-shard justification intervals** over its
/// window: each shard contributes `[max(definite_i − overlapping
/// deletes_i, 0), definite_i + overlapping inserts_i]`, and the value
/// must fall in `[Σ low_i, Σ high_i]`. Note this is *tighter* than
/// pooling all updates into one history — the empty-set floor applies
/// per shard (no shard can be negative), so a global reading that could
/// only be explained by one shard going negative is flagged.
pub fn check_aggregated(shard_updates: &[Vec<UpdateEvent>], sizes: &[SizeEvent]) -> Report {
    debug_assert!(
        shard_updates
            .iter()
            .flatten()
            .all(|u| u.delta == 1 || u.delta == -1),
        "monitor updates must be unit deltas"
    );
    let indexes: Vec<(SignIndex, SignIndex)> = shard_updates
        .iter()
        .map(|u| (SignIndex::build(u, 1), SignIndex::build(u, -1)))
        .collect();
    let mut report = Report {
        updates: shard_updates.iter().map(Vec::len).sum(),
        sizes_checked: sizes.len(),
        final_net: indexes
            .iter()
            .map(|(p, m)| p.resp.len() as i64 - m.resp.len() as i64)
            .sum(),
        violations: Vec::new(),
    };
    for &s in sizes {
        let (mut low, mut high) = (0i64, 0i64);
        for (plus, minus) in &indexes {
            let definite_plus = plus.done_before(s.inv);
            let definite_minus = minus.done_before(s.inv);
            let definite = definite_plus as i64 - definite_minus as i64;
            let overlap_plus = plus.started_by(s.resp) - definite_plus;
            let overlap_minus = minus.started_by(s.resp) - definite_minus;
            low += (definite - overlap_minus as i64).max(0);
            high += definite + overlap_plus as i64;
        }
        if s.value < low || s.value > high {
            report.violations.push(Violation {
                event: s,
                low,
                high,
            });
        }
    }
    report
}

/// Per-key membership bounds over a call window: `(must, may)` — the key
/// must be reported / may be reported by a scan with that window. With no
/// overlapping update the membership is pinned at the definite net; any
/// overlap frees the key (either answer justifiable at some point `t`).
fn key_bounds(history: &[KeyedUpdateEvent], baseline: i64, inv: u64, resp: u64) -> (bool, bool) {
    let mut net = baseline;
    let mut overlap = false;
    for u in history {
        if u.resp < inv {
            net += u.delta;
        } else if u.inv <= resp {
            overlap = true;
        }
    }
    let present = net > 0;
    (present && !overlap, present || overlap)
}

/// The pure scan/count checking core behind [`Monitor::verify_scans`]
/// (module docs, "Range scans"). Assumes the history is complete: every
/// successful update of every scanned key was recorded.
pub fn check_scan(
    updates: &[KeyedUpdateEvent],
    scans: &[ScanEvent],
    counts: &[CountEvent],
) -> ScanReport {
    let mut by_key: HashMap<u64, Vec<KeyedUpdateEvent>> = HashMap::new();
    for &u in updates {
        by_key.entry(u.key).or_default().push(u);
    }
    check_scan_indexed(&by_key, |_| 0, None, updates.len(), scans, counts)
}

/// [`check_scan`] generalized to a window that starts mid-history:
/// `anchor` is a full scan taken when recording began (its key set is the
/// membership baseline over `[anchor.lo, anchor.hi]`), and every recorded
/// update strictly follows it. Scans and counts that overlap the anchor,
/// or whose range is not contained in the anchor's, are skipped rather
/// than checked — their baseline is unknown.
pub fn check_scan_anchored(
    anchor: &ScanEvent,
    updates: &[KeyedUpdateEvent],
    scans: &[ScanEvent],
    counts: &[CountEvent],
) -> ScanReport {
    let mut by_key: HashMap<u64, Vec<KeyedUpdateEvent>> = HashMap::new();
    for &u in updates {
        by_key.entry(u.key).or_default().push(u);
    }
    // Baseline keys with no later updates still need per-key entries, or
    // the sweep below would never visit them.
    for &k in &anchor.keys {
        by_key.entry(k).or_default();
    }
    let base: HashSet<u64> = anchor.keys.iter().copied().collect();
    check_scan_indexed(
        &by_key,
        |k| i64::from(base.contains(&k)),
        Some(anchor),
        updates.len(),
        scans,
        counts,
    )
}

/// [`check_scan`] lifted to a sharded store: `shard_updates[i]` holds the
/// keyed updates that ran on shard `i`, and every scan/count is a global
/// (aggregated) observation. Keys *partition* across shards, so each
/// key's full history lives in exactly one shard stream and the pooled
/// per-key bounds equal the per-shard ones — unlike sizes (where the
/// per-shard floor tightens the summed bound), flattening loses nothing.
pub fn check_scan_aggregated(
    shard_updates: &[Vec<KeyedUpdateEvent>],
    scans: &[ScanEvent],
    counts: &[CountEvent],
) -> ScanReport {
    let pooled: Vec<KeyedUpdateEvent> = shard_updates.iter().flatten().copied().collect();
    check_scan(&pooled, scans, counts)
}

/// Shared sweep behind the `check_scan*` entry points: `baseline` gives a
/// key's membership before the first recorded update, `anchor` (when
/// present) restricts which observations are comparable.
fn check_scan_indexed(
    by_key: &HashMap<u64, Vec<KeyedUpdateEvent>>,
    baseline: impl Fn(u64) -> i64,
    anchor: Option<&ScanEvent>,
    updates: usize,
    scans: &[ScanEvent],
    counts: &[CountEvent],
) -> ScanReport {
    let mut report = ScanReport {
        updates,
        scans_checked: 0,
        counts_checked: 0,
        violations: Vec::new(),
    };
    let comparable = |inv: u64, lo: u64, hi: u64| match anchor {
        None => true,
        Some(a) => inv >= a.resp && lo >= a.lo && hi <= a.hi,
    };
    for s in scans {
        if !comparable(s.inv, s.lo, s.hi) {
            continue;
        }
        report.scans_checked += 1;
        let reported: HashSet<u64> = s.keys.iter().copied().collect();
        for &k in &s.keys {
            let in_range = s.lo <= k && k <= s.hi;
            let may = by_key
                .get(&k)
                .is_some_and(|h| key_bounds(h, baseline(k), s.inv, s.resp).1);
            if !in_range || !may {
                report.violations.push(ScanViolation {
                    inv: s.inv,
                    resp: s.resp,
                    key: Some(k),
                    reported: true,
                    value: 1,
                    low: 0,
                    high: 0,
                });
            }
        }
        for (&k, h) in by_key {
            if k < s.lo || k > s.hi || reported.contains(&k) {
                continue;
            }
            let (must, _) = key_bounds(h, baseline(k), s.inv, s.resp);
            if must {
                report.violations.push(ScanViolation {
                    inv: s.inv,
                    resp: s.resp,
                    key: Some(k),
                    reported: false,
                    value: 0,
                    low: 1,
                    high: 1,
                });
            }
        }
    }
    for c in counts {
        if !comparable(c.inv, c.lo, c.hi) {
            continue;
        }
        report.counts_checked += 1;
        let (mut low, mut high) = (0i64, 0i64);
        for (&k, h) in by_key {
            if k < c.lo || k > c.hi {
                continue;
            }
            let (must, may) = key_bounds(h, baseline(k), c.inv, c.resp);
            low += i64::from(must);
            high += i64::from(may);
        }
        if c.value < low || c.value > high {
            report.violations.push(ScanViolation {
                inv: c.inv,
                resp: c.resp,
                key: None,
                reported: false,
                value: c.value,
                low,
                high,
            });
        }
    }
    report
}

/// [`Monitor`] for a sharded store: one shared clock, per-shard update
/// streams, global size observations, verified by [`check_aggregated`].
/// (Separate per-shard `Monitor`s would not compose — each carries its
/// own `Instant` origin, making timestamps incomparable.)
pub struct ShardedMonitor {
    origin: Instant,
    shards: Box<[Mutex<Vec<UpdateEvent>>]>,
    sizes: Mutex<Vec<SizeEvent>>,
    keyed: Box<[Mutex<Vec<KeyedUpdateEvent>>]>,
    scans: Mutex<Vec<ScanEvent>>,
    counts: Mutex<Vec<CountEvent>>,
}

impl ShardedMonitor {
    pub fn new(shards: usize) -> Self {
        assert!(shards > 0, "a sharded monitor needs at least one shard");
        Self {
            origin: Instant::now(),
            shards: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
            sizes: Mutex::new(Vec::new()),
            keyed: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
            scans: Mutex::new(Vec::new()),
            counts: Mutex::new(Vec::new()),
        }
    }

    #[inline]
    fn now(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    /// Stamp the invocation of an operation about to run.
    #[inline]
    pub fn begin(&self) -> Timer {
        Timer { inv: self.now() }
    }

    /// Record a completed successful update (`delta` ±1) on `shard`.
    pub fn commit_update(&self, shard: usize, timer: Timer, delta: i64) {
        let resp = self.now();
        self.shards[shard].lock().unwrap().push(UpdateEvent {
            inv: timer.inv,
            resp,
            delta,
        });
    }

    /// Record a completed aggregated (global) size observation.
    pub fn commit_size(&self, timer: Timer, value: i64) {
        self.commit_size_with_slack(timer, value, Duration::ZERO);
    }

    /// [`Self::commit_size`] widened backward by `slack` (an aggregated
    /// `global_recent` reading reports its composed `age`).
    pub fn commit_size_with_slack(&self, timer: Timer, value: i64, slack: Duration) {
        let resp = self.now();
        let inv = timer.inv.saturating_sub(slack.as_nanos() as u64);
        self.sizes.lock().unwrap().push(SizeEvent { inv, resp, value });
    }

    /// Check every recorded global size against the per-shard updates
    /// (call after all recording threads joined).
    pub fn verify(&self) -> Report {
        let shards: Vec<Vec<UpdateEvent>> = self
            .shards
            .iter()
            .map(|m| m.lock().unwrap().clone())
            .collect();
        let sizes = self.sizes.lock().unwrap();
        check_aggregated(&shards, &sizes)
    }

    /// Record a completed successful keyed update on `shard` (feeds both
    /// that shard's unkeyed stream for [`Self::verify`] and its keyed
    /// stream for [`Self::verify_scans`]).
    pub fn commit_keyed_update(&self, shard: usize, timer: Timer, key: u64, delta: i64) {
        let resp = self.now();
        self.shards[shard].lock().unwrap().push(UpdateEvent {
            inv: timer.inv,
            resp,
            delta,
        });
        self.keyed[shard].lock().unwrap().push(KeyedUpdateEvent {
            key,
            inv: timer.inv,
            resp,
            delta,
        });
    }

    /// Record a completed aggregated (global) range scan's key set.
    pub fn commit_scan(&self, timer: Timer, lo: u64, hi: u64, keys: Vec<u64>) {
        let resp = self.now();
        self.scans.lock().unwrap().push(ScanEvent {
            inv: timer.inv,
            resp,
            lo,
            hi,
            keys,
        });
    }

    /// Record a completed aggregated range count.
    pub fn commit_count(&self, timer: Timer, lo: u64, hi: u64, value: i64) {
        let resp = self.now();
        self.counts.lock().unwrap().push(CountEvent {
            inv: timer.inv,
            resp,
            lo,
            hi,
            value,
        });
    }

    /// Check every recorded global scan/count against the per-shard keyed
    /// updates via [`check_scan_aggregated`].
    pub fn verify_scans(&self) -> ScanReport {
        let shards: Vec<Vec<KeyedUpdateEvent>> = self
            .keyed
            .iter()
            .map(|m| m.lock().unwrap().clone())
            .collect();
        let scans = self.scans.lock().unwrap();
        let counts = self.counts.lock().unwrap();
        check_scan_aggregated(&shards, &scans, &counts)
    }
}

/// Greedy one-pass shrink: drop every event whose removal keeps the
/// violation alive. Shared by [`minimize`] / [`minimize_anchored`] /
/// [`minimize_scan`] (generic over the event type — keyed and unkeyed
/// histories shrink the same way).
fn shrink<T: Clone>(updates: &[T], still_fails: impl Fn(&[T]) -> bool) -> Vec<T> {
    let mut kept = updates.to_vec();
    let mut i = 0;
    while i < kept.len() {
        let removed = kept.remove(i);
        if still_fails(&kept) {
            continue; // the violation survives without it: drop for good
        }
        kept.insert(i, removed);
        i += 1;
    }
    kept
}

/// Minimize the update history behind a violating size observation: the
/// returned subset still fails [`check`] against `size`, and removing any
/// single remaining update would stop it failing. Turns a thousands-long
/// fuzz history into a repro a human can read.
pub fn minimize(updates: &[UpdateEvent], size: &SizeEvent) -> Vec<UpdateEvent> {
    debug_assert!(!check(updates, std::slice::from_ref(size)).is_ok());
    shrink(updates, |kept| !check(kept, std::slice::from_ref(size)).is_ok())
}

/// [`minimize`] for a violating scan observation: the returned keyed
/// subset still fails [`check_scan`] against `scan` alone. (Dropping a
/// key's whole history can itself fail the check — a reported key with no
/// recorded insert is a violation — so the core is a repro, not a proof
/// skeleton; the dump prints it alongside the scan either way.)
pub fn minimize_scan(updates: &[KeyedUpdateEvent], scan: &ScanEvent) -> Vec<KeyedUpdateEvent> {
    shrink(updates, |kept| {
        !check_scan(kept, std::slice::from_ref(scan), &[]).is_ok()
    })
}

/// [`minimize`] for anchored windows (see [`check_anchored`]).
pub fn minimize_anchored(
    anchor: &SizeEvent,
    slack: i64,
    updates: &[UpdateEvent],
    size: &SizeEvent,
) -> Vec<UpdateEvent> {
    shrink(updates, |kept| {
        !check_anchored(anchor, slack, kept, std::slice::from_ref(size)).is_ok()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn up(inv: u64, resp: u64, delta: i64) -> UpdateEvent {
        UpdateEvent { inv, resp, delta }
    }

    fn sz(inv: u64, resp: u64, value: i64) -> SizeEvent {
        SizeEvent { inv, resp, value }
    }

    #[test]
    fn sequential_history_pins_exact_sizes() {
        // Updates strictly before the size call: its value is forced.
        let updates = [up(0, 1, 1), up(2, 3, 1), up(4, 5, -1)];
        assert!(check(&updates, &[sz(10, 11, 1)]).is_ok());
        for wrong in [0, 2, -1] {
            let r = check(&updates, &[sz(10, 11, wrong)]);
            assert_eq!(r.violations.len(), 1, "value {wrong} must be rejected");
            assert_eq!((r.violations[0].low, r.violations[0].high), (1, 1));
        }
    }

    #[test]
    fn overlapping_updates_widen_the_range() {
        // One insert done, one insert and one delete in flight.
        let updates = [up(0, 1, 1), up(5, 20, 1), up(6, 21, -1)];
        for fine in [0, 1, 2] {
            assert!(check(&updates, &[sz(10, 11, fine)]).is_ok(), "size {fine}");
        }
        for wrong in [-1, 3] {
            assert!(!check(&updates, &[sz(10, 11, wrong)]).is_ok(), "{wrong}");
        }
    }

    #[test]
    fn negative_sizes_are_never_justified() {
        // Figure 2 shape: a delete's effect observed before its insert's.
        let updates = [up(0, 30, 1), up(5, 25, -1)];
        let r = check(&updates, &[sz(10, 12, -1)]);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].low, 0, "floor must clamp at empty-set");
    }

    #[test]
    fn updates_after_the_window_cannot_count() {
        let updates = [up(20, 21, 1)];
        assert!(check(&updates, &[sz(5, 6, 0)]).is_ok());
        assert!(!check(&updates, &[sz(5, 6, 1)]).is_ok());
    }

    #[test]
    fn empty_history_accepts_only_zero() {
        assert!(check(&[], &[sz(0, 1, 0)]).is_ok());
        assert!(!check(&[], &[sz(0, 1, 1)]).is_ok());
    }

    #[test]
    fn monitor_records_and_verifies_end_to_end() {
        let m = Monitor::new();
        let t = m.begin();
        m.commit_update(t, 1);
        let t = m.begin();
        m.commit_update(t, 1);
        let t = m.begin();
        m.commit_size(t, 2);
        let t = m.begin();
        m.commit_update(t, -1);
        let t = m.begin();
        m.commit_size(t, 1);
        let report = m.verify();
        assert!(report.is_ok(), "{:?}", report.violations);
        assert_eq!(report.updates, 3);
        assert_eq!(report.sizes_checked, 2);
        assert_eq!(report.final_net, 1);
    }

    #[test]
    fn anchored_check_offsets_by_baseline() {
        // Anchor: size 10 observed over [0, 5]; two inserts and a delete
        // recorded after it.
        let anchor = sz(0, 5, 10);
        let updates = [up(6, 7, 1), up(8, 9, 1), up(10, 11, -1)];
        assert!(check_anchored(&anchor, 0, &updates, &[sz(20, 21, 11)]).is_ok());
        let r = check_anchored(&anchor, 0, &updates, &[sz(20, 21, 10)]);
        assert_eq!(r.violations.len(), 1);
        assert_eq!((r.violations[0].low, r.violations[0].high), (11, 11));
        assert_eq!(r.final_net, 11);
    }

    #[test]
    fn anchored_check_skips_pre_anchor_sizes_and_applies_slack() {
        let anchor = sz(0, 5, 100);
        let updates = [up(6, 7, 1)];
        // A size overlapping the anchor is not comparable: skipped.
        let r = check_anchored(&anchor, 0, &updates, &[sz(3, 4, 7)]);
        assert_eq!(r.sizes_checked, 0);
        assert!(r.is_ok());
        // Slack of 2 (in-flight unrecorded ops) widens both bounds.
        for fine in [99, 103] {
            assert!(check_anchored(&anchor, 2, &updates, &[sz(10, 11, fine)]).is_ok());
        }
        for wrong in [98, 104] {
            assert!(!check_anchored(&anchor, 2, &updates, &[sz(10, 11, wrong)]).is_ok());
        }
    }

    #[test]
    fn minimize_keeps_a_minimal_failing_core() {
        // 5 inserts done before the size call; value 99 is impossible no
        // matter what — the empty update set already fails (value > 0
        // with nothing recorded), so minimize should strip everything.
        let updates: Vec<UpdateEvent> = (0..5).map(|i| up(2 * i, 2 * i + 1, 1)).collect();
        let bad = sz(100, 101, 99);
        assert_eq!(minimize(&updates, &bad).len(), 0);
        // A negative size is refuted by the floor alone as well, but a
        // too-large size of 3 against 2 completed inserts needs... 3 > 2
        // fails with both kept; dropping one insert still fails (3 > 1);
        // dropping both still fails (3 > 0): minimal core is empty.
        // A too-SMALL size keeps its witnesses: value 0 against two
        // completed inserts fails only while at least one insert remains.
        let two = [up(0, 1, 1), up(2, 3, 1)];
        let small = sz(10, 11, 0);
        let core = minimize(&two, &small);
        assert_eq!(core.len(), 1, "one definite insert suffices to refute 0");
    }

    #[test]
    fn aggregated_check_sums_per_shard_intervals() {
        // Shard 0: one definite insert. Shard 1: one definite insert and
        // one overlapping insert. Global window [10, 11]:
        //   shard 0 contributes [1, 1], shard 1 contributes [1, 2].
        let shards = vec![vec![up(0, 1, 1)], vec![up(2, 3, 1), up(5, 20, 1)]];
        for fine in [2, 3] {
            assert!(
                check_aggregated(&shards, &[sz(10, 11, fine)]).is_ok(),
                "{fine}"
            );
        }
        for wrong in [1, 4] {
            let r = check_aggregated(&shards, &[sz(10, 11, wrong)]);
            assert_eq!(r.violations.len(), 1, "value {wrong}");
            assert_eq!((r.violations[0].low, r.violations[0].high), (2, 3));
        }
        let r = check_aggregated(&shards, &[sz(10, 11, 2)]);
        assert_eq!(r.updates, 3);
        assert_eq!(r.final_net, 3);
    }

    #[test]
    fn aggregated_floor_applies_per_shard() {
        // Shard 0 has 2 definite inserts; shard 1 has an overlapping
        // delete whose insert never happened on that shard. Pooled into
        // one history the bound would be [2-1, 2] = [1, 2]; per shard,
        // shard 1's interval is [max(0-1, 0), 0] = [0, 0] — the floor
        // clamps per shard, so the global bound is [2, 2].
        let shards = vec![vec![up(0, 1, 1), up(2, 3, 1)], vec![up(5, 20, -1)]];
        assert!(check_aggregated(&shards, &[sz(10, 11, 2)]).is_ok());
        let r = check_aggregated(&shards, &[sz(10, 11, 1)]);
        assert_eq!(r.violations.len(), 1, "per-shard floor must reject 1");
        assert_eq!((r.violations[0].low, r.violations[0].high), (2, 2));
        // The pooled (single-history) check would have accepted it:
        let pooled: Vec<UpdateEvent> = shards.iter().flatten().copied().collect();
        assert!(
            check(&pooled, &[sz(10, 11, 1)]).is_ok(),
            "pooled bound is looser"
        );
    }

    #[test]
    fn aggregated_single_shard_collapses_to_check() {
        let updates = vec![up(0, 1, 1), up(5, 20, 1), up(6, 21, -1)];
        for v in [-1, 0, 1, 2, 3] {
            assert_eq!(
                check_aggregated(&[updates.clone()], &[sz(10, 11, v)]).is_ok(),
                check(&updates, &[sz(10, 11, v)]).is_ok(),
                "value {v}"
            );
        }
    }

    #[test]
    fn sharded_monitor_records_on_one_clock() {
        let m = ShardedMonitor::new(2);
        let t = m.begin();
        m.commit_update(0, t, 1);
        let t = m.begin();
        m.commit_update(1, t, 1);
        let t = m.begin();
        m.commit_size(t, 2);
        let t = m.begin();
        m.commit_update(0, t, -1);
        let t = m.begin();
        m.commit_size(t, 1);
        let report = m.verify();
        assert!(report.is_ok(), "{:?}", report.violations);
        assert_eq!(report.updates, 3);
        assert_eq!(report.sizes_checked, 2);
        assert_eq!(report.final_net, 1);
        // An impossible reading is caught.
        let t = m.begin();
        m.commit_size(t, 5);
        assert!(!m.verify().is_ok());
    }

    fn kup(key: u64, inv: u64, resp: u64, delta: i64) -> KeyedUpdateEvent {
        KeyedUpdateEvent { key, inv, resp, delta }
    }

    fn scan(inv: u64, resp: u64, lo: u64, hi: u64, keys: &[u64]) -> ScanEvent {
        ScanEvent { inv, resp, lo, hi, keys: keys.to_vec() }
    }

    fn cnt(inv: u64, resp: u64, lo: u64, hi: u64, value: i64) -> CountEvent {
        CountEvent { inv, resp, lo, hi, value }
    }

    #[test]
    fn scan_must_report_pinned_members_and_nothing_else() {
        // Key 5 definitely in (insert done), key 7 definitely out
        // (insert+delete both done), key 9 never touched.
        let ups = [kup(5, 0, 1, 1), kup(7, 2, 3, 1), kup(7, 4, 5, -1)];
        assert!(check_scan(&ups, &[scan(10, 11, 0, 20, &[5])], &[]).is_ok());
        // Dropping the pinned key is a torn scan.
        let r = check_scan(&ups, &[scan(10, 11, 0, 20, &[])], &[]);
        assert_eq!(r.violations.len(), 1);
        let v = r.violations[0];
        assert_eq!((v.key, v.reported, v.low, v.high), (Some(5), false, 1, 1));
        // Reporting a definitely-deleted key, a never-inserted key, or an
        // out-of-range key is each a violation.
        for bad in [7u64, 9] {
            let r = check_scan(&ups, &[scan(10, 11, 0, 20, &[5, bad])], &[]);
            assert_eq!(r.violations.len(), 1, "key {bad}");
            assert_eq!(r.violations[0].key, Some(bad));
            assert!(r.violations[0].reported);
        }
        let r = check_scan(&ups, &[scan(10, 11, 6, 20, &[5])], &[]);
        assert_eq!(r.violations.len(), 1, "key 5 is outside [6, 20]");
    }

    #[test]
    fn overlapping_updates_free_a_keys_membership() {
        // Key 5's delete overlaps the scan window: both answers fine.
        let ups = [kup(5, 0, 1, 1), kup(5, 8, 20, -1)];
        assert!(check_scan(&ups, &[scan(10, 11, 0, 9, &[5])], &[]).is_ok());
        assert!(check_scan(&ups, &[scan(10, 11, 0, 9, &[])], &[]).is_ok());
        // An overlapping *insert* of a fresh key likewise frees it.
        let ups = [kup(6, 8, 20, 1)];
        assert!(check_scan(&ups, &[scan(10, 11, 0, 9, &[6])], &[]).is_ok());
        assert!(check_scan(&ups, &[scan(10, 11, 0, 9, &[])], &[]).is_ok());
    }

    #[test]
    fn count_bounds_sum_per_key_membership() {
        // Pinned present: 1, 2. Freed by overlap: 3. Pinned absent: 4.
        let ups = [
            kup(1, 0, 1, 1),
            kup(2, 0, 1, 1),
            kup(3, 8, 20, 1),
            kup(4, 2, 3, 1),
            kup(4, 4, 5, -1),
        ];
        for fine in [2, 3] {
            assert!(check_scan(&ups, &[], &[cnt(10, 11, 0, 9, fine)]).is_ok(), "{fine}");
        }
        for wrong in [-1, 1, 4] {
            let r = check_scan(&ups, &[], &[cnt(10, 11, 0, 9, wrong)]);
            assert_eq!(r.violations.len(), 1, "count {wrong}");
            assert_eq!((r.violations[0].low, r.violations[0].high), (2, 3));
        }
        // Range restriction: only key 1 in [0, 1].
        assert!(check_scan(&ups, &[], &[cnt(10, 11, 0, 1, 1)]).is_ok());
        assert!(!check_scan(&ups, &[], &[cnt(10, 11, 0, 1, 0)]).is_ok());
    }

    #[test]
    fn anchored_scan_seeds_baseline_and_skips_incomparable() {
        // Anchor over [0, 100] reported {3, 4}; afterwards 4 is deleted
        // and 8 inserted.
        let anchor = scan(0, 5, 0, 100, &[3, 4]);
        let ups = [kup(4, 6, 7, -1), kup(8, 8, 9, 1)];
        let r = check_scan_anchored(&anchor, &ups, &[scan(20, 21, 0, 100, &[3, 8])], &[]);
        assert!(r.is_ok(), "{:?}", r.violations);
        assert_eq!(r.scans_checked, 1);
        // Dropping baseline key 3 (never updated after the anchor) is
        // exactly the violation the baseline seeding must catch.
        let r = check_scan_anchored(&anchor, &ups, &[scan(20, 21, 0, 100, &[8])], &[]);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].key, Some(3));
        // A scan overlapping the anchor, or ranging outside it, is
        // skipped, not checked.
        let r = check_scan_anchored(
            &anchor,
            &ups,
            &[scan(2, 3, 0, 100, &[]), scan(20, 21, 0, 200, &[])],
            &[cnt(20, 21, 0, 200, 7)],
        );
        assert_eq!((r.scans_checked, r.counts_checked), (0, 0));
        assert!(r.is_ok());
        // Counts inside the anchor range check against the seeded bound.
        let r = check_scan_anchored(&anchor, &ups, &[], &[cnt(20, 21, 0, 100, 2)]);
        assert!(r.is_ok(), "{:?}", r.violations);
        assert!(!check_scan_anchored(&anchor, &ups, &[], &[cnt(20, 21, 0, 100, 4)]).is_ok());
    }

    #[test]
    fn aggregated_scan_check_equals_pooled() {
        // Keys partition across shards, so the sharded check must agree
        // with the pooled single-history one on every observation.
        let shards = vec![
            vec![kup(2, 0, 1, 1), kup(2, 8, 20, -1)],
            vec![kup(3, 0, 1, 1), kup(5, 2, 3, 1), kup(5, 4, 5, -1)],
        ];
        let pooled: Vec<KeyedUpdateEvent> = shards.iter().flatten().copied().collect();
        let observations = [
            scan(10, 11, 0, 9, &[2, 3]),
            scan(10, 11, 0, 9, &[3]),
            scan(10, 11, 0, 9, &[5]),
            scan(10, 11, 0, 9, &[]),
        ];
        for s in &observations {
            assert_eq!(
                check_scan_aggregated(&shards, std::slice::from_ref(s), &[]).is_ok(),
                check_scan(&pooled, std::slice::from_ref(s), &[]).is_ok(),
                "scan {:?}",
                s.keys
            );
        }
        let r = check_scan_aggregated(&shards, &[], &[cnt(10, 11, 0, 9, 2)]);
        assert!(r.is_ok(), "{:?}", r.violations);
        assert_eq!(r.updates, 5);
    }

    #[test]
    fn minimize_scan_shrinks_to_a_failing_core() {
        // Many irrelevant keys plus one pinned-present key the scan
        // dropped: the core should keep (at most) the insert of key 50.
        let mut ups: Vec<KeyedUpdateEvent> =
            (0..20).map(|i| kup(100 + i, 2 * i, 2 * i + 1, 1)).collect();
        ups.push(kup(50, 0, 1, 1));
        let torn = scan(100, 101, 0, 99, &[]);
        assert!(!check_scan(&ups, std::slice::from_ref(&torn), &[]).is_ok());
        let core = minimize_scan(&ups, &torn);
        assert_eq!(core.len(), 1);
        assert_eq!(core[0].key, 50);
        assert!(!check_scan(&core, std::slice::from_ref(&torn), &[]).is_ok());
    }

    #[test]
    fn monitor_scan_recording_end_to_end() {
        let m = Monitor::new();
        let t = m.begin();
        m.commit_keyed_update(t, 7, 1);
        let t = m.begin();
        m.commit_keyed_update(t, 8, 1);
        let t = m.begin();
        m.commit_scan(t, 0, 100, vec![7, 8]);
        let t = m.begin();
        m.commit_keyed_update(t, 7, -1);
        let t = m.begin();
        m.commit_count(t, 0, 100, 1);
        let r = m.verify_scans();
        assert!(r.is_ok(), "{:?}", r.violations);
        assert_eq!((r.scans_checked, r.counts_checked, r.updates), (1, 1, 3));
        // Keyed updates feed the unkeyed stream too: verify() still works.
        let t = m.begin();
        m.commit_size(t, 1);
        assert!(m.verify().is_ok());
        // A fabricated scan is caught.
        let t = m.begin();
        m.commit_scan(t, 0, 100, vec![7]);
        assert!(!m.verify_scans().is_ok(), "key 7 is deleted by now");
    }

    #[test]
    fn sharded_monitor_scan_recording_end_to_end() {
        let m = ShardedMonitor::new(2);
        let t = m.begin();
        m.commit_keyed_update(0, t, 4, 1);
        let t = m.begin();
        m.commit_keyed_update(1, t, 5, 1);
        let t = m.begin();
        m.commit_scan(t, 0, 10, vec![4, 5]);
        let t = m.begin();
        m.commit_count(t, 0, 10, 2);
        let r = m.verify_scans();
        assert!(r.is_ok(), "{:?}", r.violations);
        // Keyed updates land in the unkeyed per-shard streams too.
        let t = m.begin();
        m.commit_size(t, 2);
        assert!(m.verify().is_ok());
        // A global scan missing a pinned key is caught.
        let t = m.begin();
        m.commit_scan(t, 0, 10, vec![4]);
        assert!(!m.verify_scans().is_ok());
    }

    #[test]
    fn slack_widens_justification_backward() {
        let m = Monitor::new();
        let t = m.begin();
        m.commit_update(t, 1);
        std::thread::sleep(Duration::from_millis(2));
        // A stale read of 0 predating the insert: justified only with
        // slack covering the insert's window.
        let t = m.begin();
        m.commit_size_with_slack(t, 0, Duration::from_secs(1));
        assert!(m.verify().is_ok());
        let t = m.begin();
        m.commit_size(t, 0); // no slack: the insert is definite by now
        assert!(!m.verify().is_ok());
    }
}
