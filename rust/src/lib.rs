//! # concurrent-size
//!
//! A production-oriented Rust reproduction of **“Concurrent Size”**
//! (Gal Sela & Erez Petrank, OOPSLA 2022, DOI 10.1145/3563300): a methodology
//! for adding a **wait-free, linearizable `size()`** operation to concurrent
//! sets and dictionaries with O(#threads) cost — no full-structure snapshot,
//! no global lock.
//!
//! ## What lives where
//!
//! * [`size`] — the size-methods subsystem. The paper's core mechanism:
//!   per-thread insertion/deletion counters ([`size::SizeCalculator`]), the
//!   Jayanti-style wait-free counter snapshot ([`size::CountersSnapshot`]),
//!   and the [`size::SizePolicy`] family — **six** policies that
//!   instantiate each data structure across the size design space:
//!   - `NoSize` — baseline, no `size()` (the overhead yardstick);
//!   - `LinearizableSize` — the paper's wait-free linearizable size;
//!     strongest progress guarantee, metadata work on every update;
//!   - `NaiveSize` — Java-style counter-after-op; cheap but **not**
//!     linearizable (Figures 1–2 anomalies);
//!   - `LockSize` — global reader-writer lock; correct, simplest, worst
//!     scalability under mixed traffic;
//!   - `HandshakeSize` — flag-raise/ack handshake (the
//!     synchronization-methods study, arXiv 2506.16350): near-zero update
//!     overhead, so it wins update-heavy mixes with rare/periodic sizes;
//!     `size()` is blocking and serialized;
//!   - `OptimisticSize` — version-stamped double-collect with bounded
//!     retries falling back to the wait-free path (same study): the
//!     paper's update costs with cheaper size calls when collects
//!     succeed; wins when sizes and moderate update traffic interleave.
//!
//!   `cargo bench --bench ablation_policies` sweeps all six on one
//!   structure; every policy plugs into all four structures generically.
//!
//!   On top of the policies sits the **size arbiter**
//!   ([`size::SizeArbiter`]): every structure embeds one, and the
//!   [`set_api::ConcurrentSet`] freshness API routes through it —
//!   `size_exact()` is linearizable with *combining* (concurrent callers
//!   share one underlying collect: one handshake serves a whole batch),
//!   and `size_recent(max_staleness)` is a wait-free published read under
//!   a bounded-staleness contract ([`size::SizeView`] carries the value,
//!   an age upper bound, and provenance). The size-heavy scenario of
//!   `ablation_policies` quantifies both against raw per-caller `size()`
//!   and records the sweep to `BENCH_ablation.json`.
//!
//!   The **size scale layer** sits alongside: [`size::ShardedCounters`]
//!   (`sharded.rs`) is a striped cache-padded mirror of the metadata —
//!   synced at the protocol's exactly-once counter-CAS point — whose
//!   batched reconciliation collect serves O(shards) bounded-lag
//!   estimates (`ConcurrentSet::size_estimate`, `--size-shards`, the
//!   `kv_server` `SIZE?` probe); [`size::SizeRefresher`] (`refresher.rs`)
//!   is an owned background daemon per structure that periodically
//!   drives the arbiter's round (`ConcurrentSet::set_refresh_period`,
//!   `--size-call refresh`, `kv_server --refresh-ms`) so `size_recent`
//!   becomes a truly passive read, with join-on-drop shutdown; and
//!   [`size::OptimisticSize`] auto-tunes its retry budget from observed
//!   fallback rates (surfaced in [`size::ArbiterStats`]). The
//!   `ablation_policies` `scale` scenario sweeps the shards ×
//!   refresh-period grid. Concurrent histories are checked by the online
//!   [`history::monitor`] (`rust/tests/linearizability.rs` runs it over
//!   all six policies × four structures).
//! * [`list`], [`hashtable`], [`skiplist`], [`bst`] — the evaluated data
//!   structures, each generic over the size policy (paper Section 9).
//! * [`snapshot`], [`vcas`] — the snapshot-based competitors
//!   (Petrank–Timnat snap-collector; Wei et al. versioned-CAS BST).
//! * [`ebr`] — from-scratch epoch-based memory reclamation (the GC the Java
//!   original leaned on).
//! * [`workload`], [`harness`], [`metrics`] — YCSB-style workload generator
//!   and the multi-threaded throughput engine that regenerates the paper's
//!   Figures 7–13.
//! * [`runtime`], [`analytics`] — PJRT CPU runtime loading the AOT-compiled
//!   JAX/Pallas analytics artifacts (`artifacts/*.hlo.txt`), and the epoch
//!   analytics pipeline built on them. The XLA backend sits behind the
//!   `pjrt` cargo feature; default (offline) builds get a stub whose
//!   loaders fail gracefully and the pipeline consumers skip.
//! * [`history`] — operation logging + the offline size-linearizability
//!   checker (rust oracle, cross-checked against the Pallas pipeline).
//! * [`server`] — the async TCP front-end over any [`set_api::ConcurrentSet`]:
//!   a std-only nonblocking **reactor** (one thread multiplexing thousands
//!   of connections through per-connection read/write buffers and
//!   partial-line state machines) feeding a handler pool bounded by
//!   [`thread_id::capacity`], with **size-driven admission control** —
//!   incoming `PUT`s are checked against high/low watermarks on the
//!   `size_estimate` probe (hysteresis; `ERR OVERLOAD` sheds) — and a
//!   `STATS` endpoint merging server gauges with [`size::ArbiterStats`].
//!   `examples/kv_server.rs` is a thin CLI shim over it; `make
//!   server-smoke` boots it in CI. The server **self-heals**: pool
//!   requests carry per-request deadlines (`ERR TIMEOUT`, stale replies
//!   dropped by request id), handler panics are contained by
//!   `catch_unwind` (`ERR PANIC`) with pool replenishment, idle and
//!   slowloris connections are reaped on a protocol-progress clock, and
//!   a sampled in-server monitor (`--monitor-sample`) checks live
//!   windows of traffic against a `size_exact` anchor, dumping minimized
//!   repros of any unjustified size to `artifacts/`.
//! * [`shardstore`] — the **sharded store subsystem**: the key space
//!   partitioned over S independent hash-table shards (deterministic
//!   [`shardstore::route`] hash routing; each shard owns its own
//!   `SizeCore`, counter mirror and refresher) behind one
//!   [`set_api::ConcurrentSet`] face, with a cluster-wide
//!   [`shardstore::SizeAggregator`] — the arbiter's combining protocol
//!   applied one level up. `global_exact()` is a two-phase fan-out
//!   collect justified by overlapping per-shard intervals,
//!   `global_recent(d)` composes published views under
//!   `age = max(per-shard ages) <= d`, and the server's admission
//!   control grows a second tier: per-shard watermarks shed only the
//!   hot shard's `PUT`s (`ERR OVERLOAD shard=<i>`) under zipfian skew
//!   (`--key-dist zipf:<theta>`), while `kv_server --store-shards`
//!   mounts the whole thing.
//! * [`faults`] — the deterministic **chaos plane** (cargo feature
//!   `faults`; compiled to zero-cost no-ops otherwise): seeded injection
//!   sites through the size protocol and the server fire delays, yields,
//!   panics, short writes and forced fallbacks on a schedule that
//!   replays exactly from its seed. `csize fuzz` and `make fuzz-smoke`
//!   drive it; `kv_server --fault-seed` arms it on a live server.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // no_run: doctest binaries miss the libxla rpath; compile-checked only.
//! use concurrent_size::set_api::ConcurrentSet;
//! use concurrent_size::size::LinearizableSize;
//! use concurrent_size::skiplist::SkipListSet;
//!
//! let set: SkipListSet<LinearizableSize> = SkipListSet::new(64);
//! assert!(set.insert(41));
//! assert!(set.insert(42));
//! assert!(set.delete(41));
//! assert_eq!(set.size(), Some(1)); // linearizable, wait-free, O(#threads)
//! ```

pub mod analytics;
pub mod bench_util;
pub mod bst;
pub mod cli;
pub mod ebr;
pub mod faults;
pub mod harness;
pub mod hashtable;
pub mod history;
pub mod list;
pub mod metrics;
pub mod pad;
pub mod proptest_lite;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod set_api;
pub mod shardstore;
pub mod size;
pub mod skiplist;
pub mod snapshot;
pub mod thread_id;
pub mod vcas;
pub mod workload;

/// Maximum number of registered application threads (paper: per-thread
/// counter arrays are sized once at construction). Mirrors `AOT_T` in
/// `python/compile/aot.py`.
pub const MAX_THREADS: usize = 64;
