//! Harris lock-free linked-list set, generic over the size policy.
//!
//! This is the paper's running example (Fig. 3 applied to Harris 2001) and
//! also the bucket engine for [`crate::hashtable`]. The engine operates on
//! an external `head: AtomicU64` so a table of buckets reuses it verbatim.
//!
//! ## Deletion state machine
//!
//! * **Tracked** ([`crate::size::LinearizableSize`]): the *marking step* is
//!   installing packed `UpdateInfo` into the node's `delete_info` slot
//!   (CAS 0 → info) — the analogue of `ConcurrentSkipListMap` repointing the
//!   value field at the `UpdateInfo` (paper Section 4). The winner is the
//!   logical deleter; the metadata is updated (`commit_delete`) **before**
//!   the physical steps, which are Harris's: set the next-pointer mark bit,
//!   then unlink. Any operation that encounters a node with installed
//!   delete-info must commit its metadata before unlinking or ignoring it.
//! * **Untracked**: classic Harris — the next-pointer mark CAS is the
//!   logical delete and decides the winner.
//!
//! Unlinked nodes are retired through [`crate::ebr`].

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Arc;

use crate::ebr;
use crate::set_api::{ConcurrentSet, MAX_KEY};
use crate::size::{RefresherSlot, SizeArbiter, SizeCore, SizeOpts, SizePolicy};
use crate::thread_id;

const MARK: u64 = 1;
/// Low bit 1 on a node's `next` (and on a bucket head): the chain is being
/// migrated by [`crate::hashtable`]'s incremental resize. A frozen word
/// makes every pre-freeze CAS snapshot stale, so in-flight structure
/// mutations fail and re-route to the successor table. Untracked deletes
/// refuse to mark a frozen word, so after [`freeze_chain`] the set of
/// marked (deleted) nodes is fixed and the mover's copy pass reads it
/// authoritatively.
pub(crate) const FREEZE: u64 = 2;
/// All pointer-tag bits ([`Node`] allocations are 8-byte aligned).
const LOW_BITS: u64 = MARK | FREEZE;
/// Bucket-head sentinel: every live key of this bucket now lives in the
/// successor table (`FREEZE` so every stale CAS still fails, `MARK` to
/// distinguish "migrated" from a merely frozen empty bucket). `addr` of
/// it is null, so a stale traversal degrades to an empty walk, and the
/// `try_*` entry points bail out to the table router before that.
pub(crate) const MOVED_HEAD: u64 = FREEZE | MARK;
/// Tag bit distinguishing a migration *seal* stored in a tracked node's
/// `delete_info` slot from packed `UpdateInfo` (`tid << 48 | counter` with
/// `tid < MAX_THREADS`, so bit 63 is never set by a real operation). A
/// sealed word carries the copy node's address: claim-vs-seal races on the
/// original resolve on this one word.
pub(crate) const SEAL_TAG: u64 = 1 << 63;

#[inline]
pub(crate) fn is_marked(word: u64) -> bool {
    word & MARK == MARK
}

#[inline]
pub(crate) fn is_frozen(word: u64) -> bool {
    word & FREEZE == FREEZE
}

#[inline]
pub(crate) fn is_seal(word: u64) -> bool {
    word & SEAL_TAG == SEAL_TAG
}

#[inline]
pub(crate) fn seal_ptr<P: SizePolicy>(word: u64) -> *mut Node<P> {
    (word & !SEAL_TAG) as *mut Node<P>
}

#[inline]
pub(crate) fn addr<P: SizePolicy>(word: u64) -> *mut Node<P> {
    (word & !LOW_BITS) as *mut Node<P>
}

/// List node. Info slots are zero-sized for untracked policies, so the
/// baseline node layout matches the untransformed algorithm.
pub(crate) struct Node<P: SizePolicy> {
    pub(crate) key: u64,
    /// Dictionary payload; an upsert over an existing key overwrites it
    /// in place (per-key atomic, not part of the membership protocol).
    pub(crate) value: AtomicU64,
    /// Successor pointer; low bit = Harris mark (physical-deletion lock).
    pub(crate) next: AtomicU64,
    /// Published insert `UpdateInfo` (paper: `insertInfo` field).
    pub(crate) insert_info: P::InfoSlot,
    /// Published delete `UpdateInfo`; non-zero = logically deleted
    /// (paper: the repurposed value/`deleteInfo` field).
    pub(crate) delete_info: P::InfoSlot,
}

impl<P: SizePolicy> Node<P> {
    pub(crate) fn alloc(key: u64, value: u64, next: u64) -> *mut Self {
        Box::into_raw(Box::new(Node {
            key,
            value: AtomicU64::new(value),
            next: AtomicU64::new(next),
            insert_info: P::InfoSlot::default(),
            delete_info: P::InfoSlot::default(),
        }))
    }
}

/// Whether `node` is logically deleted, returning its delete-info when the
/// policy tracks one.
#[inline]
fn deletion_state<P: SizePolicy>(node: &Node<P>) -> (bool, u64) {
    if P::TRACKED {
        let dinfo = P::read_delete_info(&node.delete_info);
        if dinfo != 0 {
            // A migration seal is not a delete: the node was copied to the
            // successor table. Sealed nodes live only in frozen chains,
            // which these traversals bail out of first — treat the slot as
            // "nothing to commit" defensively (0 is commit-guarded below).
            if is_seal(dinfo) {
                return (true, 0);
            }
            return (true, dinfo);
        }
        // delete_info is installed before the mark, so a marked node always
        // has a non-zero slot; re-reading covers the race window.
        if is_marked(node.next.load(SeqCst)) {
            return (true, P::read_delete_info(&node.delete_info));
        }
        (false, 0)
    } else {
        (is_marked(node.next.load(SeqCst)), 0)
    }
}

/// Set the Harris mark bit on `node.next` (idempotent). Bails out without
/// marking when the word is frozen — the bucket is migrating and physical
/// deletion must not race the mover; the caller checks `is_frozen` on the
/// returned word.
#[inline]
fn mark_next<P: SizePolicy>(node: &Node<P>) -> u64 {
    let mut w = node.next.load(SeqCst);
    while !is_marked(w) {
        if is_frozen(w) {
            return w;
        }
        match node.next.compare_exchange(w, w | MARK, SeqCst, SeqCst) {
            Ok(_) => return w | MARK,
            Err(cur) => w = cur,
        }
    }
    w
}

/// Find `(pred, curr)` with `curr` the first node whose key is `>= k`,
/// physically unlinking every logically-deleted node encountered —
/// after committing its delete metadata (Fig. 3 footnote: *"call
/// updateMetadata(node's deleteInfo, DELETE) before unlinking"*).
///
/// `pred == null` means the predecessor is `head` itself. Caller must hold
/// an EBR pin. Returns `None` when a frozen word is encountered — the
/// bucket is being migrated and the caller must re-route through the
/// table descriptor.
unsafe fn search<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    k: u64,
) -> Option<(*mut Node<P>, *mut Node<P>)> {
    'retry: loop {
        let mut pred: *mut Node<P> = std::ptr::null_mut();
        loop {
            let pred_next: &AtomicU64 = if pred.is_null() {
                head
            } else {
                unsafe { &(*pred).next }
            };
            let curr_w = pred_next.load(SeqCst);
            if is_frozen(curr_w) {
                return None;
            }
            if is_marked(curr_w) {
                // pred was deleted under us; restart from the head.
                continue 'retry;
            }
            let curr = addr::<P>(curr_w);
            if curr.is_null() {
                return Some((pred, curr));
            }
            let curr_ref = unsafe { &*curr };
            let (deleted, dinfo) = deletion_state(curr_ref);
            if deleted {
                // New linearization order: metadata before unlink.
                if P::TRACKED && dinfo != 0 {
                    policy.commit_delete(dinfo);
                }
                let marked_next = mark_next(curr_ref);
                if is_frozen(marked_next) {
                    return None;
                }
                match pred_next.compare_exchange(curr_w, marked_next & !MARK, SeqCst, SeqCst) {
                    Ok(_) => {
                        unsafe { ebr::retire(curr) };
                        continue; // re-read the same pred_next
                    }
                    Err(_) => continue 'retry,
                }
            }
            if curr_ref.key >= k {
                return Some((pred, curr));
            }
            pred = curr;
        }
    }
}

/// Insert into the list rooted at `head` (Fig. 3 lines 15–26).
pub(crate) fn insert_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> bool {
    put_at(policy, head, k, 0, false)
}

/// Dictionary upsert into the list rooted at `head`: [`insert_at`] with a
/// value payload. A fresh insert publishes `v` with the node and returns
/// `true`; when `k` is already present, `overwrite` decides whether the
/// existing node's value is replaced (the store is the overwrite's
/// linearization point) — either way membership is unchanged and the
/// return is `false`, preserving the set-semantics reply.
pub(crate) fn put_at<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    k: u64,
    v: u64,
    overwrite: bool,
) -> bool {
    try_put_at(policy, head, k, v, overwrite).expect("standalone list chains never freeze")
}

/// [`put_at`] that bails out with `None` when the chain freezes under it
/// (the bucket is migrating): the caller re-routes through the table
/// descriptor. No partial effect escapes a `None` — an unpublished node
/// is reclaimed, and the one non-CAS mutation (the overwrite store) is
/// fenced by a frozen check on both sides: if the post-store check sees
/// the freeze, the mover may have copied the old value, so the caller
/// must retry the overwrite against the successor chain (re-storing the
/// same value there is idempotent).
pub(crate) fn try_put_at<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    k: u64,
    v: u64,
    overwrite: bool,
) -> Option<bool> {
    debug_assert!(k <= MAX_KEY);
    let _guard = ebr::pin();
    let _op = policy.enter();
    let tid = thread_id::current();

    let packed = policy.begin_insert(tid); // line 22 (createUpdateInfo)
    let mut new_node: *mut Node<P> = std::ptr::null_mut();

    let reclaim = |node: *mut Node<P>| {
        if !node.is_null() {
            drop(unsafe { Box::from_raw(node) }); // never published
        }
    };

    loop {
        let Some((pred, curr)) = (unsafe { search(policy, head, k) }) else {
            reclaim(new_node);
            return None;
        };
        if !curr.is_null() {
            let curr_ref = unsafe { &*curr };
            if curr_ref.key == k {
                // Present in an unmarked node: help its insert, fail
                // (lines 16–18).
                policy.help_insert(&curr_ref.insert_info);
                if overwrite {
                    if is_frozen(curr_ref.next.load(SeqCst)) {
                        reclaim(new_node);
                        return None; // mover may already have copied it
                    }
                    curr_ref.value.store(v, SeqCst);
                    if is_frozen(curr_ref.next.load(SeqCst)) {
                        reclaim(new_node);
                        return None; // store raced the copy: redo on successor
                    }
                }
                reclaim(new_node);
                return Some(false);
            }
        }
        if new_node.is_null() {
            new_node = Node::<P>::alloc(k, v, curr as u64);
            P::stash_insert_info(unsafe { &(*new_node).insert_info }, packed); // line 23
        } else {
            unsafe { &(*new_node).next }.store(curr as u64, SeqCst);
        }
        let pred_next: &AtomicU64 = if pred.is_null() {
            head
        } else {
            unsafe { &(*pred).next }
        };
        if pred_next
            .compare_exchange(curr as u64, new_node as u64, SeqCst, SeqCst)
            .is_ok()
        {
            // Original linearization passed; reach the new one (line 25).
            policy.commit_insert(unsafe { &(*new_node).insert_info }, packed);
            return Some(true);
        }
        // CAS failed (concurrent update, or the chain froze — search
        // distinguishes): retry with the allocated node.
    }
}

/// Delete from the list rooted at `head` (Fig. 3 lines 27–38).
pub(crate) fn delete_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> bool {
    try_delete_at(policy, head, k).expect("standalone list chains never freeze")
}

/// [`delete_at`] that bails out with `None` when the chain freezes under
/// it. Tracked policies have one freeze-penetrating step — the delete-info
/// claim lands on a word the mover does not freeze — so the mover *seals*
/// that same word ([`SEAL_TAG`]): whichever CAS wins decides atomically
/// whether the node was deleted here or moved. A claim that loses to a
/// seal returns `None` and the caller re-deletes the copy in the
/// successor chain.
pub(crate) fn try_delete_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> Option<bool> {
    let _guard = ebr::pin();
    let _op = policy.enter();
    let tid = thread_id::current();

    let (pred, curr) = unsafe { search(policy, head, k) }?;
    if curr.is_null() || unsafe { &*curr }.key != k {
        return Some(false); // line 29
    }
    let curr_ref = unsafe { &*curr };

    if P::TRACKED {
        // Line 33: the node we found is unmarked — ensure its insert is
        // linearized before we depend on it.
        policy.help_insert(&curr_ref.insert_info);
        let packed = policy.begin_delete(tid); // line 34
        // Line 35: the marking step = installing delete-info.
        let winner = P::try_claim_delete(&curr_ref.delete_info, packed);
        if is_seal(winner) {
            return None; // the mover moved it first: delete the copy
        }
        // Line 36: metadata before any unlink.
        policy.commit_delete(winner);
        // Physical deletion (best effort; search() will finish it, or the
        // mover retires the whole frozen chain).
        let marked_next = mark_next(curr_ref);
        if !is_frozen(marked_next) {
            let pred_next: &AtomicU64 = if pred.is_null() {
                head
            } else {
                unsafe { &(*pred).next }
            };
            if pred_next
                .compare_exchange(curr as u64, marked_next & !MARK, SeqCst, SeqCst)
                .is_ok()
            {
                unsafe { ebr::retire(curr) };
            }
        }
        Some(winner == packed) // lost the claim race => concurrent
                               // delete succeeded instead (lines 30-32)
    } else {
        // Classic Harris: the next-pointer mark decides the winner. The
        // mark CAS refuses frozen words, which is what lets the mover read
        // the mark bit as the authoritative deleted/live state.
        let mut w = curr_ref.next.load(SeqCst);
        loop {
            if is_frozen(w) {
                return None;
            }
            if is_marked(w) {
                // Marked by a concurrent delete: the key is gone.
                return Some(false);
            }
            match curr_ref.next.compare_exchange(w, w | MARK, SeqCst, SeqCst) {
                Ok(_) => {
                    policy.commit_delete(0); // naive/lock counter bump
                    let pred_next: &AtomicU64 = if pred.is_null() {
                        head
                    } else {
                        unsafe { &(*pred).next }
                    };
                    if pred_next
                        .compare_exchange(curr as u64, w, SeqCst, SeqCst)
                        .is_ok()
                    {
                        unsafe { ebr::retire(curr) };
                    }
                    return Some(true);
                }
                Err(cur) => w = cur,
            }
        }
    }
}

/// Membership test (Fig. 3 lines 6–13): a read-only traversal that helps
/// pending operations on the found node reach their metadata linearization
/// point before reporting.
pub(crate) fn contains_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> bool {
    try_contains_at(policy, head, k).expect("standalone list chains never freeze")
}

/// Dictionary read: [`contains_at`] returning the stored value.
pub(crate) fn get_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> Option<u64> {
    try_get_at(policy, head, k).expect("standalone list chains never freeze")
}

/// [`contains_at`] over a possibly-migrating bucket. A *frozen* chain is
/// still authoritative for reads — freezing stops mutation, it does not
/// move anything — so the walk ignores `FREEZE` bits, and a migration
/// *seal* reads as live: the node was live when sealed, its value is
/// frozen, and every mutation of its copy starts after the bucket turns
/// [`MOVED_HEAD`], i.e. after this reader began, so ordering the read
/// before them is linearizable. The only `None` is a [`MOVED_HEAD`]
/// bucket, which carries no data — the caller re-routes to the successor
/// table. Reads never block on migration.
pub(crate) fn try_contains_at<P: SizePolicy>(policy: &P, head: &AtomicU64, k: u64) -> Option<bool> {
    try_get_at(policy, head, k).map(|v| v.is_some())
}

/// [`get_at`] over a possibly-migrating bucket; see [`try_contains_at`]
/// for the `None` contract.
#[allow(clippy::option_option)]
pub(crate) fn try_get_at<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    k: u64,
) -> Option<Option<u64>> {
    let _guard = ebr::pin();
    let _op = policy.enter_read();

    let head_w = head.load(SeqCst);
    if head_w == MOVED_HEAD {
        return None;
    }
    let mut curr = addr::<P>(head_w);
    while !curr.is_null() {
        let curr_ref = unsafe { &*curr };
        if curr_ref.key >= k {
            break;
        }
        curr = addr::<P>(curr_ref.next.load(SeqCst));
    }
    if curr.is_null() {
        return Some(None);
    }
    let curr_ref = unsafe { &*curr };
    if curr_ref.key != k {
        return Some(None);
    }
    if P::TRACKED && is_seal(P::read_delete_info(&curr_ref.delete_info)) {
        // Sealed = moved while live; the frozen original is a valid
        // linearization of the key (see try_contains_at).
        policy.help_insert(&curr_ref.insert_info);
        return Some(Some(curr_ref.value.load(SeqCst)));
    }
    let (deleted, dinfo) = deletion_state(curr_ref);
    if deleted {
        if P::TRACKED && dinfo != 0 {
            policy.commit_delete(dinfo); // lines 12–13
        }
        return Some(None);
    }
    policy.help_insert(&curr_ref.insert_info); // lines 9–10
    Some(Some(curr_ref.value.load(SeqCst)))
}

/// Range collect: push every live `(key, value)` with `lo <= key <= hi`
/// onto `out`, in key order. The traversal *helps*: a pending insert it
/// reports is committed first, and an observed logical delete is
/// committed before the node is skipped — so any tracked update the
/// traversal could have half-seen bumps a counter, and the double-collect
/// in [`crate::size::validated_collect`] detects it and retries. Caller
/// must hold an EBR pin and a policy read guard.
pub(crate) fn collect_range_at<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    lo: u64,
    hi: u64,
    out: &mut Vec<(u64, u64)>,
) {
    try_collect_range_at(policy, head, lo, hi, out).expect("standalone list chains never freeze")
}

/// [`collect_range_at`] over a possibly-migrating bucket. Frozen chains
/// are collected as normal (a migration seal reads as live, exactly as in
/// [`try_contains_at`]); the hashtable's sweep pairs this with a
/// migration-generation check so a bucket that relocates mid-scan forces
/// a retry. `None` (bucket is [`MOVED_HEAD`]) leaves `out` untouched.
pub(crate) fn try_collect_range_at<P: SizePolicy>(
    policy: &P,
    head: &AtomicU64,
    lo: u64,
    hi: u64,
    out: &mut Vec<(u64, u64)>,
) -> Option<()> {
    let head_w = head.load(SeqCst);
    if head_w == MOVED_HEAD {
        return None;
    }
    let mut curr = addr::<P>(head_w);
    while !curr.is_null() {
        let curr_ref = unsafe { &*curr };
        if curr_ref.key > hi {
            return Some(());
        }
        let next = addr::<P>(curr_ref.next.load(SeqCst));
        if curr_ref.key >= lo {
            let raw = if P::TRACKED {
                P::read_delete_info(&curr_ref.delete_info)
            } else {
                0
            };
            if is_seal(raw) {
                // Moved while live: report the frozen original.
                policy.help_insert(&curr_ref.insert_info);
                out.push((curr_ref.key, curr_ref.value.load(SeqCst)));
            } else {
                let (deleted, dinfo) = deletion_state(curr_ref);
                if deleted {
                    if P::TRACKED && dinfo != 0 {
                        policy.commit_delete(dinfo);
                    }
                } else {
                    policy.help_insert(&curr_ref.insert_info);
                    out.push((curr_ref.key, curr_ref.value.load(SeqCst)));
                }
            }
        }
        curr = next;
    }
    Some(())
}

/// Non-linearizable full count: walks the list ignoring in-flight state.
/// For tests at quiescence only.
pub(crate) fn quiescent_count_at<P: SizePolicy>(head: &AtomicU64) -> usize {
    let _guard = ebr::pin();
    let mut n = 0;
    let mut curr = addr::<P>(head.load(SeqCst));
    while !curr.is_null() {
        let curr_ref = unsafe { &*curr };
        let (deleted, _) = deletion_state(curr_ref);
        if !deleted {
            n += 1;
        }
        curr = addr::<P>(curr_ref.next.load(SeqCst));
    }
    n
}

/// Free every node reachable from `head` (exclusive access).
pub(crate) unsafe fn drop_chain<P: SizePolicy>(head: &AtomicU64) {
    let mut curr = addr::<P>(head.load(SeqCst));
    while !curr.is_null() {
        let next = addr::<P>(unsafe { &*curr }.next.load(SeqCst));
        drop(unsafe { Box::from_raw(curr) });
        curr = next;
    }
    head.store(0, SeqCst);
}

// --- incremental-resize migration primitives -------------------------------
//
// Used only by `crate::hashtable`. The mover never creates `UpdateInfo` and
// never touches a per-thread `(ins, del)` counter: migration relocates
// nodes, it performs no logical operation, so the exactly-once counter-CAS
// stays with the real inserter/deleter (the size-policy invariant).

/// Freeze a bucket chain: set [`FREEZE`] on the head word and on every
/// node's `next`. After this returns, every pre-freeze CAS snapshot is
/// stale (structure mutations fail and re-route), untracked deletes can no
/// longer mark, and overwrite stores bail — the chain is immutable except
/// for tracked delete-info claims, which the copy pass arbitrates with
/// [`SEAL_TAG`]. Idempotent, so a helper recovering a panicked migration
/// re-runs it safely. Returns the frozen head word.
pub(crate) fn freeze_chain<P: SizePolicy>(head: &AtomicU64) -> u64 {
    let mut w = head.load(SeqCst);
    while !is_frozen(w) {
        match head.compare_exchange(w, w | FREEZE, SeqCst, SeqCst) {
            Ok(_) => w |= FREEZE,
            Err(cur) => w = cur,
        }
    }
    let mut curr = addr::<P>(w);
    while !curr.is_null() {
        let next = unsafe { &(*curr).next };
        let mut nw = next.load(SeqCst);
        while !is_frozen(nw) {
            match next.compare_exchange(nw, nw | FREEZE, SeqCst, SeqCst) {
                Ok(_) => nw |= FREEZE,
                Err(cur) => nw = cur,
            }
        }
        curr = addr::<P>(nw);
    }
    w
}

/// Outcome of [`link_exclusive`].
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum LinkOutcome {
    /// Spliced into the chain at its sorted position.
    Linked,
    /// This exact node is already in the chain (recovery re-walk after a
    /// mid-quantum panic; tracked copies are deduplicated by pointer).
    AlreadyLinked,
    /// A different node with the same key is already in the chain (an
    /// earlier, interrupted pass copied this key; untracked copies are
    /// deduplicated by key) — the caller frees the redundant allocation.
    DuplicateKey,
}

/// Sorted-position splice into a chain the caller owns exclusively: the
/// successor-table buckets of an in-flight migration are written only by
/// the (mutex-serialized) mover, so plain stores suffice and duplicate
/// detection is exact.
///
/// # Safety
/// `node` must be a valid unpublished allocation (or one already linked
/// here by an interrupted pass), and no other thread may be mutating the
/// chain rooted at `head`.
pub(crate) unsafe fn link_exclusive<P: SizePolicy>(
    head: &AtomicU64,
    node: *mut Node<P>,
) -> LinkOutcome {
    let key = unsafe { &*node }.key;
    let mut pred: *mut Node<P> = std::ptr::null_mut();
    let mut curr = addr::<P>(head.load(SeqCst));
    loop {
        if !curr.is_null() {
            if curr == node {
                return LinkOutcome::AlreadyLinked;
            }
            let curr_ref = unsafe { &*curr };
            if curr_ref.key < key {
                pred = curr;
                curr = addr::<P>(curr_ref.next.load(SeqCst));
                continue;
            }
            if curr_ref.key == key {
                return LinkOutcome::DuplicateKey;
            }
        }
        unsafe { &(*node).next }.store(curr as u64, SeqCst);
        let pred_next: &AtomicU64 = if pred.is_null() {
            head
        } else {
            unsafe { &(*pred).next }
        };
        pred_next.store(node as u64, SeqCst);
        return LinkOutcome::Linked;
    }
}

// ---------------------------------------------------------------------------

/// A sorted lock-free linked-list set (paper's transformation target in
/// Fig. 3; also the base structure of the hash table's buckets).
pub struct LinkedListSet<P: SizePolicy> {
    head: AtomicU64,
    /// Policy + arbiter, shared with the optional refresher daemon.
    core: Arc<SizeCore<P>>,
    refresher: RefresherSlot,
}

unsafe impl<P: SizePolicy> Send for LinkedListSet<P> {}
unsafe impl<P: SizePolicy> Sync for LinkedListSet<P> {}

impl<P: SizePolicy> LinkedListSet<P> {
    pub fn new(max_threads: usize) -> Self {
        Self::with_opts(max_threads, SizeOpts::default())
    }

    pub fn with_opts(max_threads: usize, opts: SizeOpts) -> Self {
        Self::with_policy(P::new(max_threads, opts))
    }

    /// Build around an externally-configured policy (demos use this to set
    /// `NaiveSize` anomaly windows).
    pub fn with_policy(policy: P) -> Self {
        Self {
            head: AtomicU64::new(0),
            core: Arc::new(SizeCore::new(policy)),
            refresher: RefresherSlot::new(),
        }
    }

    pub fn policy(&self) -> &P {
        &self.core.policy
    }

    /// The combining size arbiter behind `size_exact` / `size_recent`.
    pub fn arbiter(&self) -> &SizeArbiter {
        &self.core.arbiter
    }

    /// Quiescent full count (tests).
    pub fn quiescent_count(&self) -> usize {
        quiescent_count_at::<P>(&self.head)
    }
}

impl<P: SizePolicy> ConcurrentSet for LinkedListSet<P> {
    fn insert(&self, k: u64) -> bool {
        insert_at(&self.core.policy, &self.head, k)
    }
    fn delete(&self, k: u64) -> bool {
        delete_at(&self.core.policy, &self.head, k)
    }
    fn contains(&self, k: u64) -> bool {
        contains_at(&self.core.policy, &self.head, k)
    }
    fn put(&self, k: u64, v: u64) -> bool {
        put_at(&self.core.policy, &self.head, k, v, true)
    }
    fn get(&self, k: u64) -> Option<u64> {
        get_at(&self.core.policy, &self.head, k)
    }

    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter_read();
        let (pairs, _validated) =
            crate::size::validated_collect(self.core.policy.calculator(), || {
                let mut out = Vec::new();
                collect_range_at(&self.core.policy, &self.head, lo, hi, &mut out);
                out
            });
        Some(pairs)
    }

    crate::size::impl_size_surface!();

    fn name(&self) -> String {
        format!(
            "LinkedList<{}>",
            std::any::type_name::<P>().rsplit("::").next().unwrap()
        )
    }
}

impl<P: SizePolicy> Drop for LinkedListSet<P> {
    fn drop(&mut self) {
        unsafe { drop_chain::<P>(&self.head) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NaiveSize, NoSize};
    use std::sync::Arc;

    fn lin_list() -> LinkedListSet<LinearizableSize> {
        LinkedListSet::new(crate::MAX_THREADS)
    }

    #[test]
    fn insert_delete_contains_basic() {
        let l = lin_list();
        assert!(!l.contains(5));
        assert!(l.insert(5));
        assert!(!l.insert(5));
        assert!(l.contains(5));
        assert!(l.delete(5));
        assert!(!l.delete(5));
        assert!(!l.contains(5));
    }

    #[test]
    fn size_is_exact_sequentially() {
        let l = lin_list();
        assert_eq!(l.size(), Some(0));
        for k in 0..100 {
            assert!(l.insert(k));
        }
        assert_eq!(l.size(), Some(100));
        for k in 0..50 {
            assert!(l.delete(k * 2));
        }
        assert_eq!(l.size(), Some(50));
        assert_eq!(l.quiescent_count(), 50);
    }

    #[test]
    fn dictionary_put_get_scan_sequentially() {
        let l = lin_list();
        assert_eq!(l.get(5), None);
        assert!(l.put(5, 50));
        assert_eq!(l.get(5), Some(50));
        assert!(!l.put(5, 51), "upsert over an existing key reports 0");
        assert_eq!(l.get(5), Some(51));
        assert!(l.insert(7));
        assert_eq!(l.get(7), Some(0), "set insert stores the default value");
        assert!(!l.insert(7));
        assert_eq!(l.get(7), Some(0), "plain insert must not overwrite");
        assert!(l.put(3, 30));
        assert_eq!(l.scan(0, 10), Some(vec![(3, 30), (5, 51), (7, 0)]));
        assert_eq!(l.scan(4, 7), Some(vec![(5, 51), (7, 0)]));
        assert_eq!(l.scan(6, 6), Some(vec![]));
        assert_eq!(l.count_range(0, 10), Some(3));
        assert_eq!(l.count_range(4, 5), Some(1));
        assert!(l.delete(5));
        assert_eq!(l.get(5), None);
        assert_eq!(l.scan(0, 10), Some(vec![(3, 30), (7, 0)]));
    }

    #[test]
    fn reinsertion_after_delete() {
        let l = lin_list();
        assert!(l.insert(7));
        assert!(l.delete(7));
        assert!(l.insert(7));
        assert!(l.contains(7));
        assert_eq!(l.size(), Some(1));
    }

    #[test]
    fn ordering_is_maintained() {
        let l = lin_list();
        for k in [5u64, 1, 9, 3, 7] {
            l.insert(k);
        }
        for k in [1u64, 3, 5, 7, 9] {
            assert!(l.contains(k));
        }
        assert!(!l.contains(2));
        assert_eq!(l.size(), Some(5));
    }

    #[test]
    fn baseline_nosize_works_without_size() {
        let l: LinkedListSet<NoSize> = LinkedListSet::new(crate::MAX_THREADS);
        assert!(l.insert(1));
        assert!(l.contains(1));
        assert_eq!(l.size(), None);
        assert!(l.delete(1));
        assert_eq!(l.quiescent_count(), 0);
    }

    #[test]
    fn concurrent_inserts_distinct_ranges() {
        let l = Arc::new(lin_list());
        let hs: Vec<_> = (0..4u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for k in (t * 1000)..(t * 1000 + 250) {
                        assert!(l.insert(k));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(l.size(), Some(1000));
        assert_eq!(l.quiescent_count(), 1000);
    }

    #[test]
    fn concurrent_same_key_single_winner() {
        for _ in 0..50 {
            let l = Arc::new(lin_list());
            let hs: Vec<_> = (0..4)
                .map(|_| {
                    let l = l.clone();
                    std::thread::spawn(move || l.insert(42) as usize)
                })
                .collect();
            let wins: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(wins, 1, "exactly one insert(42) must win");
            assert_eq!(l.size(), Some(1));
        }
    }

    #[test]
    fn concurrent_delete_single_winner() {
        for _ in 0..50 {
            let l = Arc::new(lin_list());
            l.insert(42);
            let hs: Vec<_> = (0..4)
                .map(|_| {
                    let l = l.clone();
                    std::thread::spawn(move || l.delete(42) as usize)
                })
                .collect();
            let wins: usize = hs.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(wins, 1, "exactly one delete(42) must win");
            assert_eq!(l.size(), Some(0));
        }
    }

    #[test]
    fn size_never_negative_under_churn() {
        let l = Arc::new(lin_list());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..3u64)
            .map(|t| {
                let l = l.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(SeqCst) {
                        let k = t * 10 + (i % 5);
                        l.insert(k);
                        l.delete(k);
                        i += 1;
                    }
                })
            })
            .collect();
        for _ in 0..1000 {
            let s = l.size().unwrap();
            assert!(s >= 0, "linearizable size went negative: {s}");
            assert!(s <= 15, "size exceeded live-key bound: {s}");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(l.size().unwrap() as usize, l.quiescent_count());
    }

    #[test]
    fn naive_policy_counts_at_quiescence() {
        let l: LinkedListSet<NaiveSize> = LinkedListSet::new(crate::MAX_THREADS);
        for k in 0..10 {
            l.insert(k);
        }
        l.delete(3);
        assert_eq!(l.size(), Some(9));
    }

    #[test]
    fn mixed_stress_size_matches_quiescent_count() {
        let l = Arc::new(lin_list());
        let hs: Vec<_> = (0..4u64)
            .map(|t| {
                let l = l.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(t + 99);
                    for _ in 0..3000 {
                        let k = rng.gen_range(64);
                        match rng.gen_range(3) {
                            0 => {
                                l.insert(k);
                            }
                            1 => {
                                l.delete(k);
                            }
                            _ => {
                                l.contains(k);
                            }
                        }
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(l.size().unwrap() as usize, l.quiescent_count());
    }
}
