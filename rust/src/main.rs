//! `csize` — the Concurrent Size coordinator CLI.
//!
//! Subcommands:
//! * `demo`     — quick functional tour of every structure/policy combo.
//! * `bench`    — one ad-hoc throughput run (`--structure`, `--policy`,
//!   `--threads`, `--size-threads`, `--secs`, `--initial`, `--mix`,
//!   `--size-call raw|exact|recent|refresh`, `--staleness-ms`,
//!   `--refresh-ms` for an explicit daemon period, `--size-shards
//!   auto|N` for the sharded counter mirror).
//! * `analyze`  — run a workload with epoch sampling and push the samples
//!   through the AOT-compiled Pallas pipeline (PJRT).
//! * `verify`   — anomaly hunt: show the naive policy violating
//!   linearizability (paper Figs. 1–2) and the transformed one holding.
//! * `fuzz`     — seeded fault-schedule fuzzing: drive every
//!   size-providing policy under the chaos fault plane (`--fault-seed`,
//!   `--seeds`, `--ops`, `--structure NAME|all`), check each recorded
//!   history for size-linearizability **and scan/count justification**
//!   (every policy must pass the scan check — the interval bound accepts
//!   even the un-validated fallback scans, so a violation always means a
//!   torn scan), and dump minimized repros for any violation to
//!   `--dump-dir` (default `artifacts/`). Two teeth tests prove the
//!   checkers can fail: the naive policy's forced Figure 2 anomaly, and
//!   a deliberately corrupted scan record. Ends with a fault-site
//!   coverage table (fires per armed site, including a short server
//!   drive for the server-only sites); any armed site that never fired
//!   fails the run. Build with `--features faults` for actual fault
//!   injection.
//! * `resize-stress` — the growth-phase CI gate: the in-process growth
//!   harness (64 buckets through 10x trigger capacity under mixed
//!   read/size load, per-window throughput with a 50%-collapse gate)
//!   plus a monitored server under a PUT-heavy swarm that forces several
//!   doublings, asserting zero monitor violations, `resizes >= 1` and a
//!   drained migration (`--initial-buckets`, `--clients`, `--ops`,
//!   `--monitor-sample`, `--fault-seed`; arm with `--features faults`).
//!
//! Figure reproductions live in `cargo bench` targets (see DESIGN.md §4).

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util;
use concurrent_size::cli::{Args, PolicyKind, SizeCallKind};
use concurrent_size::faults::{self, FaultPlane};
use concurrent_size::harness::{run, RunConfig, SizeCall};
use concurrent_size::history::monitor::{
    minimize, minimize_scan, KeyedUpdateEvent, Monitor, ScanEvent, ScanViolation, UpdateEvent,
    Violation,
};
use concurrent_size::list::LinkedListSet;
use concurrent_size::metrics::fmt_rate;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NaiveSize, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::snapshot::SnapshotSkipList;
use concurrent_size::vcas::VcasSet;
use concurrent_size::workload::{self, key_range, Mix, READ_HEAVY, UPDATE_HEAVY};
use concurrent_size::{analytics, MAX_THREADS, runtime};

fn make_set(
    structure: &str,
    policy: &str,
    initial: usize,
    opts: concurrent_size::size::SizeOpts,
) -> Box<dyn ConcurrentSet> {
    // Snapshot-based competitors carry their own size mechanism and ignore
    // the policy; everything else goes through the shared six-policy
    // factory (`bench_util::make_set_opts`).
    match structure {
        "snapshot-skiplist" => return Box::new(SnapshotSkipList::new(MAX_THREADS)),
        "vcas" => return Box::new(VcasSet::new(MAX_THREADS, initial)),
        _ => {}
    }
    let Some(kind) = PolicyKind::parse(policy) else {
        eprintln!(
            "unknown policy {policy:?} (use baseline|linearizable|naive|lock|handshake|optimistic)"
        );
        std::process::exit(2);
    };
    match bench_util::make_set_opts(structure, kind, initial, opts) {
        Some(set) => set,
        None => {
            eprintln!(
                "unknown structure {structure:?} (use {}|snapshot-skiplist|vcas)",
                bench_util::STRUCTURES.join("|")
            );
            std::process::exit(2);
        }
    }
}

fn parse_mix(s: &str) -> Mix {
    match s {
        "update-heavy" | "update" => UPDATE_HEAVY,
        "read-heavy" | "read" => READ_HEAVY,
        other => {
            eprintln!("unknown mix {other:?} (use update-heavy|read-heavy)");
            std::process::exit(2);
        }
    }
}

fn cmd_demo() {
    println!("== concurrent-size demo ==");
    for structure in [
        "hashtable",
        "skiplist",
        "bst",
        "list",
        "snapshot-skiplist",
        "vcas",
    ] {
        let set = make_set(structure, "size", 1024, Default::default());
        for k in 1..=100u64 {
            set.insert(k);
        }
        for k in 1..=50u64 {
            set.delete(k * 2);
        }
        println!(
            "{:<24} contains(1)={:<5} size={:?}",
            set.name(),
            set.contains(1),
            set.size()
        );
    }
    println!("\n-- size policies (hash table) --");
    for kind in PolicyKind::ALL {
        let set = make_set("hashtable", kind.label(), 1024, Default::default());
        for k in 1..=100u64 {
            set.insert(k);
        }
        for k in 1..=50u64 {
            set.delete(k * 2);
        }
        let exact = set.size_exact().map(|v| v.value);
        let recent = set
            .size_recent(Duration::from_millis(50))
            .map(|v| (v.value, v.age));
        println!(
            "{:<12} size={:<10} exact={exact:<8?} recent={recent:?} linearizable={}",
            kind.label(),
            format!("{:?}", set.size()),
            if kind.provides_size() {
                if kind.linearizable() { "yes" } else { "NO" }
            } else {
                "n/a"
            }
        );
    }
}

fn cmd_bench(args: &Args) {
    let structure = args.get("structure").unwrap_or("skiplist").to_string();
    let policy = args.get("policy").unwrap_or("size").to_string();
    let initial = args.get_usize("initial", 100_000);
    let mix = parse_mix(args.get("mix").unwrap_or("update-heavy"));
    let w = args.get_usize("threads", 4);
    let s = args.get_usize("size-threads", 1);
    let secs = args.get_f64("secs", 2.0);
    let call_spelling = args.get("size-call").unwrap_or("raw");
    let Some(call_kind) = SizeCallKind::parse(call_spelling) else {
        eprintln!("unknown --size-call {call_spelling:?} (use raw|exact|recent|refresh)");
        std::process::exit(2);
    };
    let size_call = SizeCall::from_kind(
        call_kind,
        Duration::from_millis(args.get_u64("staleness-ms", 1)),
    );
    let refresh_ms = args.get_f64("refresh-ms", 0.0);
    let opts = concurrent_size::size::SizeOpts::default().with_shards(args.size_shards(0));

    let set = make_set(&structure, &policy, initial, opts);
    let range = key_range(initial as u64, mix);
    println!(
        "prefilling {} with {initial} keys (range [1,{range}])...",
        set.name()
    );
    workload::prefill(set.as_ref(), initial as u64, range, 42);

    // No size threads on structures whose policy provides no size().
    let size_threads = if set.size().is_some() { s } else { 0 };
    let mut cfg = RunConfig::new(w, size_threads, mix, range);
    cfg.duration = Duration::from_secs_f64(secs);
    cfg.size_call = size_call;
    if refresh_ms > 0.0 {
        cfg.refresh_period = Some(Duration::from_secs_f64(refresh_ms / 1e3));
    }
    let res = run(set.as_ref(), &cfg);
    println!(
        "{:<24} mix={} w={w} s={} call={} -> workload {} ops/s, size {} ops/s",
        set.name(),
        mix.label(),
        cfg.size_threads,
        size_call.label(),
        fmt_rate(res.workload_throughput()),
        fmt_rate(res.size_throughput()),
    );
    if let Some(stats) = set.size_stats() {
        if stats.rounds + stats.recent_hits > 0 {
            println!(
                "arbiter: {} rounds ({} daemon-driven), {} adopted, {} recent hits, \
                 {} refreshes",
                stats.rounds,
                stats.daemon_rounds,
                stats.adoptions,
                stats.recent_hits,
                stats.recent_refreshes
            );
        }
        if stats.retry_budget > 0 {
            println!(
                "optimistic tuning: budget {} after {} fallbacks",
                stats.retry_budget, stats.fallbacks
            );
        }
    }
    if let Some(estimate) = set.size_estimate() {
        println!("sharded estimate at quiescence: {estimate}");
    }
}

fn cmd_analyze(args: &Args) {
    let initial = args.get_usize("initial", 10_000);
    let epochs = args.get_usize("epochs", 64).min(runtime::AOT_E);
    let secs = args.get_f64("secs", 2.0);
    let mix = parse_mix(args.get("mix").unwrap_or("update-heavy"));

    println!("loading PJRT artifacts...");
    let artifacts = match runtime::Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("analyze unavailable: {e}");
            std::process::exit(1);
        }
    };

    let set: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));
    let range = key_range(initial as u64, mix);
    workload::prefill(set.as_ref(), initial as u64, range, 42);

    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..3u64)
        .map(|t| {
            let set = set.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut stream = workload::OpStream::new(t, mix, range);
                let mut ops = 0u64;
                while !stop.load(SeqCst) {
                    let (op, k) = stream.next();
                    workload::apply(set.as_ref(), op, k);
                    ops += 1;
                }
                ops
            })
        })
        .collect();

    let mut rec = analytics::EpochRecorder::new();
    let calc = set.policy().calculator().unwrap();
    let epoch_dt = Duration::from_secs_f64(secs / epochs as f64);
    for _ in 0..epochs.saturating_sub(1) {
        std::thread::sleep(epoch_dt);
        rec.record(calc);
    }
    stop.store(true, SeqCst);
    let total_ops: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
    rec.record(calc); // final, quiescent epoch

    let report = analytics::analyze(&artifacts, &rec).expect("pipeline failure");
    println!(
        "epochs={} ops={} final size (pallas)={} (linearizable)={} skew_max={} final_exact={}",
        rec.len(),
        total_ops,
        report.pallas_sizes.last().unwrap(),
        report.linearizable_sizes.last().unwrap(),
        report.max_skew(),
        report.final_exact(),
    );
    assert!(report.final_exact(), "quiescent epoch must be exact");
}

fn cmd_verify(args: &Args) {
    use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies};
    use concurrent_size::size::SizeOpts;
    let trials = args.get_usize("trials", 2_000);
    let rounds = args.get_usize("rounds", 500);

    let mut naive_policy = NaiveSize::new(MAX_THREADS, SizeOpts::default());
    naive_policy.set_insert_window(Duration::from_micros(80));
    let naive: SkipListSet<NaiveSize> = SkipListSet::with_policy(naive_policy);
    let lin: SkipListSet<LinearizableSize> = SkipListSet::new(MAX_THREADS);

    println!("-- Figure 1 anomaly (contains=true then size=0), {trials} trials --");
    println!("  naive        : {}", fig1_anomalies(&naive, trials));
    let lin1 = fig1_anomalies(&lin, trials);
    println!("  linearizable : {lin1}");

    println!("-- Figure 2 anomaly (negative size), {rounds} rounds --");
    println!("  naive        : {}", fig2_anomalies(&naive, rounds));
    let lin2 = fig2_anomalies(&lin, rounds);
    println!("  linearizable : {lin2}");

    assert_eq!(
        lin1 + lin2,
        0,
        "the transformed structure must never misreport"
    );
    println!("verify OK: methodology exhibits no anomalies");
}

/// Drive one structure/policy combination with seeded updater and sizer
/// threads (the `rust/tests/linearizability.rs` schedule) and hand back
/// the recorded history plus the quiescent size.
fn fuzz_drive(
    structure: &str,
    policy: PolicyKind,
    seed: u64,
    ops: usize,
) -> (Monitor, Option<i64>) {
    const UPDATERS: u64 = 3;
    const SIZERS: u64 = 2;
    const KEY_SPACE: u64 = 48;
    let set: Arc<dyn ConcurrentSet> =
        Arc::from(bench_util::make_set(structure, policy, 128).expect("structure exists"));
    let monitor = Monitor::new();
    std::thread::scope(|scope| {
        for t in 0..UPDATERS {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ ((t + 1) * 0x9E37));
                for _ in 0..ops {
                    let k = rng.gen_range_incl(1, KEY_SPACE);
                    match rng.gen_range(3) {
                        0 => {
                            let timer = monitor.begin();
                            if set.insert(k) {
                                monitor.commit_keyed_update(timer, k, 1);
                            }
                        }
                        1 => {
                            let timer = monitor.begin();
                            if set.delete(k) {
                                monitor.commit_keyed_update(timer, k, -1);
                            }
                        }
                        _ => {
                            set.contains(k); // moves no size: not recorded
                        }
                    }
                }
            });
        }
        for t in 0..SIZERS {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ ((t + 77) * 0xC0FF));
                for _ in 0..ops / 4 {
                    match rng.gen_range(5) {
                        0 => {
                            let timer = monitor.begin();
                            let v = set.size().expect("policy provides size");
                            monitor.commit_size(timer, v);
                        }
                        1 => {
                            let timer = monitor.begin();
                            let v = set.size_exact().expect("policy provides size");
                            monitor.commit_size(timer, v.value);
                        }
                        2 => {
                            // Stale reads are justified within a window
                            // widened by their reported age.
                            let timer = monitor.begin();
                            let bound = Duration::from_micros(rng.gen_range_incl(1, 800));
                            let v = set.size_recent(bound).expect("policy provides size");
                            monitor.commit_size_with_slack(timer, v.value, v.age);
                        }
                        3 => {
                            let lo = rng.gen_range_incl(1, KEY_SPACE);
                            let hi = (lo + rng.gen_range(16)).min(KEY_SPACE);
                            let timer = monitor.begin();
                            let pairs = set.scan(lo, hi).expect("structures provide scans");
                            monitor.commit_scan(
                                timer,
                                lo,
                                hi,
                                pairs.into_iter().map(|(k, _)| k).collect(),
                            );
                        }
                        _ => {
                            let lo = rng.gen_range_incl(1, KEY_SPACE);
                            let hi = (lo + rng.gen_range(16)).min(KEY_SPACE);
                            let timer = monitor.begin();
                            let n = set.count_range(lo, hi).expect("structures provide counts");
                            monitor.commit_count(timer, lo, hi, n);
                        }
                    }
                    if rng.gen_bool(0.25) {
                        std::thread::yield_now();
                    }
                }
            });
        }
    });
    let quiescent = set.size();
    (monitor, quiescent)
}

/// Write a repro file with a minimized update core for each violation
/// (first 3) and return the file path.
fn dump_repro(
    dir: &str,
    tag: &str,
    seed: u64,
    updates: &[UpdateEvent],
    violations: &[Violation],
) -> String {
    use std::fmt::Write as _;
    let mut body = String::new();
    let _ = writeln!(body, "# csize fuzz repro: {tag} (fault seed {seed:#x})");
    let _ = writeln!(body, "# updates recorded: {}", updates.len());
    for v in violations.iter().take(3) {
        let _ = writeln!(
            body,
            "violation: value={} window=[{}, {}] justified=[{}, {}]",
            v.event.value, v.event.inv, v.event.resp, v.low, v.high
        );
        let core = minimize(updates, &v.event);
        let _ = writeln!(body, "  minimized repro ({} updates):", core.len());
        for u in &core {
            let _ = writeln!(
                body,
                "  update delta={:+} window=[{}, {}]",
                u.delta,
                u.inv,
                u.resp
            );
        }
    }
    if violations.len() > 3 {
        let _ = writeln!(
            body,
            "# ... {} more violations elided",
            violations.len() - 3
        );
    }
    let _ = std::fs::create_dir_all(dir);
    let path = format!("{dir}/fuzz-{tag}-{seed:#x}.txt");
    if let Err(e) = std::fs::write(&path, &body) {
        eprintln!("fuzz: could not write repro {path}: {e}");
    }
    path
}

/// Write a repro file for scan/count violations: the offending window
/// and bounds, plus a minimized keyed-update core for scan membership
/// violations (first 3), and return the file path.
fn dump_scan_repro(
    dir: &str,
    tag: &str,
    seed: u64,
    updates: &[KeyedUpdateEvent],
    scans: &[ScanEvent],
    violations: &[ScanViolation],
) -> String {
    use std::fmt::Write as _;
    let mut body = String::new();
    let _ = writeln!(body, "# csize fuzz scan repro: {tag} (fault seed {seed:#x})");
    let _ = writeln!(body, "# keyed updates recorded: {}", updates.len());
    for v in violations.iter().take(3) {
        match v.key {
            Some(key) => {
                let _ = writeln!(
                    body,
                    "scan violation: key={key} reported={} window=[{}, {}] \
                     membership in [{}, {}]",
                    v.reported, v.inv, v.resp, v.low, v.high
                );
            }
            None => {
                let _ = writeln!(
                    body,
                    "count violation: value={} window=[{}, {}] justified=[{}, {}]",
                    v.value, v.inv, v.resp, v.low, v.high
                );
            }
        }
        if let Some(scan) = scans.iter().find(|s| s.inv == v.inv && s.resp == v.resp) {
            let core = minimize_scan(updates, scan);
            let _ = writeln!(
                body,
                "  scan [{}, {}] reported {:?}; minimized repro ({} updates):",
                scan.lo,
                scan.hi,
                scan.keys,
                core.len()
            );
            for u in &core {
                let _ = writeln!(
                    body,
                    "  update key={} delta={:+} window=[{}, {}]",
                    u.key, u.delta, u.inv, u.resp
                );
            }
        }
    }
    if violations.len() > 3 {
        let _ = writeln!(
            body,
            "# ... {} more violations elided",
            violations.len() - 3
        );
    }
    let _ = std::fs::create_dir_all(dir);
    let path = format!("{dir}/fuzz-scan-{tag}-{seed:#x}.txt");
    if let Err(e) = std::fs::write(&path, &body) {
        eprintln!("fuzz: could not write repro {path}: {e}");
    }
    path
}

/// Reproduce the paper's Figure 2 anomaly on a widened-window
/// [`NaiveSize`] under the chaos plane; return the repro path once the
/// monitor flags the negative size (`None` = never reproduced).
fn fuzz_naive_teeth(seed: u64, dump_dir: &str) -> Option<String> {
    let _guard = faults::install(FaultPlane::chaos(seed));
    let mut policy = NaiveSize::new(MAX_THREADS, concurrent_size::size::SizeOpts::default());
    policy.set_insert_window(Duration::from_micros(800));
    let set = Arc::new(LinkedListSet::<NaiveSize>::with_policy(policy));
    let monitor = Monitor::new();
    let negative_seen = AtomicBool::new(false);
    for k in 1..=600u64 {
        std::thread::scope(|scope| {
            let inserter = set.clone();
            scope.spawn(move || {
                inserter.insert(k); // increments only after the window
            });
            scope.spawn(|| {
                let timer = monitor.begin();
                while !set.delete(k) {
                    std::hint::spin_loop();
                }
                monitor.commit_update(timer, -1);
            });
            scope.spawn(|| {
                for _ in 0..32 {
                    let timer = monitor.begin();
                    let v = set.size().unwrap();
                    monitor.commit_size(timer, v);
                    if v < 0 {
                        negative_seen.store(true, SeqCst);
                        break;
                    }
                }
            });
        });
        // The insert is recorded only once it completed (window and
        // all), mirroring what an online monitor can actually know.
        let timer = monitor.begin();
        monitor.commit_update(timer, 1);
        if negative_seen.load(SeqCst) {
            break;
        }
    }
    let report = monitor.verify();
    if report.is_ok() {
        return None;
    }
    let (updates, _) = monitor.events();
    Some(dump_repro(dump_dir, "naive-fig2", seed, &updates, &report.violations))
}

/// Prove the scan checker has teeth: build a quiescent keyed history,
/// take a real validated scan, then corrupt the record the way a torn
/// scan would look (drop a definitely-present key) and require
/// `verify_scans` to flag it. Returns the repro path (`None` = the
/// corrupted scan sailed through, which fails the run).
fn fuzz_scan_teeth(seed: u64, dump_dir: &str) -> Option<String> {
    let set = bench_util::make_set("hashtable", PolicyKind::Linearizable, 128)
        .expect("hashtable exists");
    let monitor = Monitor::new();
    for k in 1..=32u64 {
        let timer = monitor.begin();
        assert!(set.insert(k), "fresh key {k}");
        monitor.commit_keyed_update(timer, k, 1);
    }
    let timer = monitor.begin();
    let mut keys: Vec<u64> = set
        .scan(1, 32)
        .expect("hashtable provides scans")
        .into_iter()
        .map(|(k, _)| k)
        .collect();
    assert_eq!(keys.len(), 32, "quiescent scan sees every key");
    // The corruption: a key whose insert finished before the scan began
    // is pinned present, so omitting it is unjustifiable.
    keys.remove(0);
    monitor.commit_scan(timer, 1, 32, keys);
    let report = monitor.verify_scans();
    if report.is_ok() {
        return None;
    }
    let (updates, scans, _) = monitor.scan_events();
    Some(dump_scan_repro(
        dump_dir,
        "scan-teeth",
        seed,
        &updates,
        &scans,
        &report.violations,
    ))
}

/// Exercise the fault sites the structure sweep cannot reach — handler
/// dispatch, connection writes, accept handoffs, reply coalescing, and
/// the refresher daemon — by driving a real two-reactor server (and a
/// 1ms refresher) under the chaos plane, so the coverage gate can hold
/// *every* armed site to "fired at least once".
fn fuzz_cover_server_sites(seed: u64) {
    use concurrent_size::server::{BlockingClient, Server, ServerConfig};
    let _guard = faults::install(FaultPlane::chaos(seed));
    let store: Arc<dyn ConcurrentSet> = Arc::from(
        bench_util::make_set("hashtable", PolicyKind::Linearizable, 256).expect("hashtable"),
    );
    store.set_refresh_period(Some(Duration::from_millis(1)));
    let config = ServerConfig {
        handlers: 2,
        reactors: 2,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store.clone(), config).expect("bind fuzz server");
    // A dozen accepts give the 1-in-3 accept-handoff site plenty of
    // chances while the acceptor spreads sockets over both shards, and
    // each client pipelines a burst so replies coalesce into shared
    // writes (the reply-coalesce short-write site caps those flushes).
    let mut burst: Vec<BlockingClient> = (0..12)
        .map(|_| BlockingClient::connect(server.local_addr()))
        .collect();
    for (i, client) in burst.iter_mut().enumerate() {
        for k in 0..8u64 {
            client.send(format!("PUT {}", 1000 + i as u64 * 100 + k));
        }
        for _ in 0..8 {
            client.recv().expect("fuzz burst reply");
        }
    }
    drop(burst);
    let mut client = BlockingClient::connect(server.local_addr());
    for k in 1..=200u64 {
        client.cmd(format!("PUT {k} {k}"));
        if k % 3 == 0 {
            client.cmd(format!("DEL {k}"));
        }
        if k % 7 == 0 {
            client.cmd("SIZE");
        }
        if k % 11 == 0 {
            // Multi-line replies through the same coalesced write path.
            client.scan(1, k).expect("fuzz scan reply");
            client.cmd(format!("COUNT 1 {k}"));
        }
    }
    // Let the refresher tick through a few dozen armed wakes.
    std::thread::sleep(Duration::from_millis(40));
    store.set_refresh_period(None);
}

/// `resize-stress` — the growth-phase CI gate. Two phases under the
/// chaos fault plane (when compiled with `--features faults`, the
/// `ResizeMigrate` site jitters migration quanta):
///
/// 1. In-process [`growth_run`]: a 64-bucket table grows through 10× its
///    trigger capacity under mixed read/size load; fails on any lost key,
///    a never-triggered resize, or a throughput window collapsing below
///    50% of the steady-state median.
/// 2. A live server with the sampled linearizability monitor
///    (`--monitor-sample`) over a small hashtable store, driven by a
///    PUT-heavy pipelined swarm that forces several doublings; fails on
///    any monitor violation (repros land under `artifacts/`), a
///    never-triggered resize, or a migration that does not drain
///    (`migration_pending != 0` after the tail is helped through).
fn cmd_resize_stress(args: &Args) {
    use concurrent_size::harness::{client_swarm, growth_run, GrowthConfig, SwarmConfig};
    use concurrent_size::hashtable::HashTableSet;
    use concurrent_size::server::{proto, Server, ServerConfig};

    let seed = args.get_u64("fault-seed", 0xE512E);
    let initial_buckets = args.get_usize("initial-buckets", 64);
    let clients = args.get_usize("clients", 8);
    let ops_per_client = args.get_u64("ops", 4_000);
    let monitor_sample = args.get_u64("monitor-sample", 16);
    let mut failures = 0usize;

    let _guard = faults::install(FaultPlane::chaos(seed));
    if faults::COMPILED {
        println!("resize-stress: chaos plane armed (seed {seed:#x})");
    } else {
        println!("resize-stress: faults not compiled in (build with --features faults)");
    }

    // Phase 1: direct growth harness.
    let growth = growth_run::<LinearizableSize>(&GrowthConfig {
        initial_buckets,
        seed,
        ..GrowthConfig::default()
    });
    let ratio = growth.collapse_ratio();
    println!(
        "resize-stress growth: buckets {} -> {} resizes={} quanta={} inserted={} \
         collapse_ratio={ratio:.3} ({:?})",
        growth.initial_buckets,
        growth.final_buckets,
        growth.resizes,
        growth.migration_quanta,
        growth.inserted,
        growth.elapsed,
    );
    if growth.resizes == 0 {
        eprintln!("resize-stress: growth phase never resized");
        failures += 1;
    }
    if ratio < 0.5 {
        eprintln!(
            "resize-stress: throughput window collapsed to {ratio:.3} of steady-state \
             (windows: {:?})",
            growth.windows
        );
        failures += 1;
    }

    // Phase 2: server + sampled monitor + growth-forcing swarm.
    let store = Arc::new(HashTableSet::<LinearizableSize>::new(MAX_THREADS, initial_buckets));
    let dyn_store: Arc<dyn ConcurrentSet> = store.clone();
    let config = ServerConfig {
        monitor_sample,
        ..ServerConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", dyn_store, config).expect("bind resize-stress server");
    // PUT-heavy over a key range far past the trigger capacity: the
    // handler threads double the table several times mid-traffic.
    let mix = Mix {
        insert_pct: 70,
        delete_pct: 10,
    };
    let key_span = initial_buckets as u64
        * concurrent_size::hashtable::RESIZE_CHAIN as u64
        * 40;
    let swarm = client_swarm(
        server.local_addr(),
        SwarmConfig::new(clients, ops_per_client, mix, key_span, seed).pipelined(4),
    )
    .expect("resize-stress swarm");
    println!(
        "resize-stress swarm: ops={} overloads={} errors={} ({:?})",
        swarm.ops, swarm.overloads, swarm.errors, swarm.elapsed
    );
    if swarm.errors > 0 {
        eprintln!("resize-stress: {} protocol errors from the server", swarm.errors);
        failures += 1;
    }

    // Help the in-flight tail through, then read the same STATS line CI
    // greps (store stats flow through the server's own size_stats path).
    store.finish_migration();
    let sstats = server.stats();
    let size_stats = store.size_stats().expect("hashtable size stats");
    println!(
        "resize-stress STATS: {}",
        proto::stats_reply(&sstats, &size_stats)
    );
    if sstats.monitor_violations > 0 {
        eprintln!(
            "resize-stress: {} monitor violation(s) — repros under artifacts/",
            sstats.monitor_violations
        );
        failures += 1;
    }
    if size_stats.resizes == 0 {
        eprintln!("resize-stress: server store never resized");
        failures += 1;
    }
    if size_stats.migration_pending != 0 {
        eprintln!(
            "resize-stress: migration never drained (pending={})",
            size_stats.migration_pending
        );
        failures += 1;
    }
    drop(server);

    if failures > 0 {
        eprintln!("resize-stress: {failures} failure(s)");
        std::process::exit(1);
    }
    println!(
        "resize-stress OK: {} resizes across both phases, zero violations, migration drained",
        growth.resizes + size_stats.resizes
    );
}

fn cmd_fuzz(args: &Args) {
    let seeds = args.get_usize("seeds", 2);
    let base_seed = args.get_u64("fault-seed", 0xC1A05);
    let ops = args.get_usize("ops", 1_200);
    let structure_arg = args.get("structure").unwrap_or("hashtable").to_string();
    let dump_dir = args.get("dump-dir").unwrap_or("artifacts").to_string();
    let structures: Vec<&str> = if structure_arg == "all" {
        bench_util::STRUCTURES.to_vec()
    } else if bench_util::STRUCTURES.contains(&structure_arg.as_str()) {
        vec![structure_arg.as_str()]
    } else {
        eprintln!(
            "unknown --structure {structure_arg:?} (use {}|all)",
            bench_util::STRUCTURES.join("|")
        );
        std::process::exit(2);
    };
    if !faults::COMPILED {
        eprintln!(
            "note: fault injection not compiled in; running the schedule without chaos \
             (rebuild with --features faults)"
        );
    }

    let fires_at_start = faults::fire_counts();
    let mut failures = 0usize;
    for round in 0..seeds {
        let seed = base_seed.wrapping_add(round as u64 * 0x9E37_79B9);
        for &structure in &structures {
            for policy in PolicyKind::ALL {
                let label = policy.label();
                if !policy.provides_size() {
                    println!("fuzz {structure}/{label}: no size to check; skipped");
                    continue;
                }
                let (monitor, quiescent) = {
                    let _guard = faults::install(FaultPlane::chaos(seed));
                    fuzz_drive(structure, policy, seed, ops)
                };
                let report = monitor.verify();
                let scan_report = monitor.verify_scans();
                if let Some(size) = quiescent {
                    if size != report.final_net {
                        eprintln!(
                            "fuzz {structure}/{label} seed={seed:#x}: quiescent size {size} \
                             != monitor net {}",
                            report.final_net
                        );
                        failures += 1;
                    }
                }
                // Scan/count justification must hold for EVERY policy:
                // the per-key interval bound accepts even the
                // un-validated fallback scans of untracked policies, so
                // any violation here means a torn scan, not an expected
                // weak-policy anomaly.
                if !scan_report.is_ok() {
                    let (keyed, scans, _) = monitor.scan_events();
                    let tag = format!("{structure}-{label}");
                    let path = dump_scan_repro(
                        &dump_dir,
                        &tag,
                        seed,
                        &keyed,
                        &scans,
                        &scan_report.violations,
                    );
                    eprintln!(
                        "fuzz {structure}/{label} seed={seed:#x}: {} UNJUSTIFIED scan/count \
                         returns (repro: {path})",
                        scan_report.violations.len()
                    );
                    failures += 1;
                }
                if report.is_ok() {
                    println!(
                        "fuzz {structure}/{label} seed={seed:#x}: clean ({} updates, {} sizes, \
                         {} scans, {} counts)",
                        report.updates,
                        report.sizes_checked,
                        scan_report.scans_checked,
                        scan_report.counts_checked
                    );
                    continue;
                }
                let (updates, _) = monitor.events();
                let tag = format!("{structure}-{label}");
                let path = dump_repro(&dump_dir, &tag, seed, &updates, &report.violations);
                if policy.linearizable() {
                    eprintln!(
                        "fuzz {structure}/{label} seed={seed:#x}: {} UNJUSTIFIED size \
                         returns (repro: {path})",
                        report.violations.len()
                    );
                    failures += 1;
                } else {
                    println!(
                        "fuzz {structure}/{label} seed={seed:#x}: caught {} expected \
                         non-linearizable anomalies (repro: {path})",
                        report.violations.len()
                    );
                }
            }
        }
    }

    // Prove the checker has teeth: force the naive policy's Figure 2
    // anomaly (negative size) and require the monitor to flag it.
    println!("fuzz: forcing the naive Figure 2 anomaly (checker teeth)...");
    match fuzz_naive_teeth(base_seed, &dump_dir) {
        Some(path) => {
            println!("fuzz naive-teeth: negative size caught and dumped (repro: {path})");
        }
        None => {
            eprintln!("fuzz naive-teeth: FAILED to catch the forced naive anomaly");
            failures += 1;
        }
    }

    // Same for the scan checker: corrupt a recorded scan the way a torn
    // collect would and require verify_scans to reject it.
    println!("fuzz: corrupting a recorded scan (scan-checker teeth)...");
    match fuzz_scan_teeth(base_seed, &dump_dir) {
        Some(path) => {
            println!("fuzz scan-teeth: torn scan caught and dumped (repro: {path})");
        }
        None => {
            eprintln!("fuzz scan-teeth: FAILED to flag the corrupted scan");
            failures += 1;
        }
    }

    // Coverage gate: every site the chaos profile arms must have fired
    // at least once across the run, or the schedule silently stopped
    // reaching part of the protocol. The server drive covers the five
    // sites (handler dispatch, conn writes, accept handoffs, reply
    // coalescing, refresher ticks) the direct structure sweep cannot
    // hit.
    if faults::COMPILED {
        fuzz_cover_server_sites(base_seed);
        let fired = faults::fire_counts();
        let armed = FaultPlane::chaos(base_seed).armed_sites();
        let mut uncovered = 0usize;
        println!("fuzz: fault-site coverage (fires this run):");
        for site in armed {
            let fires = fired[site as usize] - fires_at_start[site as usize];
            // The migration site only executes when a table actually
            // crossed its load-factor threshold; a fuzz config too small
            // to resize is not a coverage hole.
            if site == faults::FaultSite::ResizeMigrate
                && fires == 0
                && concurrent_size::hashtable::resizes_total() == 0
            {
                println!(
                    "  {:<20} 0  (no resize triggered this run; exempt)",
                    site.label()
                );
                continue;
            }
            let mark = if fires == 0 { "  <-- NEVER FIRED" } else { "" };
            println!("  {:<20} {fires}{mark}", site.label());
            if fires == 0 {
                uncovered += 1;
            }
        }
        if uncovered > 0 {
            eprintln!("fuzz: {uncovered} armed site(s) never fired");
            failures += uncovered;
        }
    } else {
        println!("fuzz: fault-site coverage n/a (faults not compiled in)");
    }

    if failures > 0 {
        eprintln!("fuzz: {failures} failure(s)");
        std::process::exit(1);
    }
    println!(
        "fuzz OK: every linearizable policy justified every size return and \
         every policy justified every scan/count"
    );
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("demo") | None => cmd_demo(),
        Some("bench") => cmd_bench(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("verify") => cmd_verify(&args),
        Some("fuzz") => cmd_fuzz(&args),
        Some("resize-stress") => cmd_resize_stress(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}; try demo|bench|analyze|verify|fuzz|resize-stress");
            std::process::exit(2);
        }
    }
}
