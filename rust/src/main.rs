//! `csize` — the Concurrent Size coordinator CLI.
//!
//! Subcommands:
//! * `demo`     — quick functional tour of every structure/policy combo.
//! * `bench`    — one ad-hoc throughput run (`--structure`, `--policy`,
//!   `--threads`, `--size-threads`, `--secs`, `--initial`, `--mix`,
//!   `--size-call raw|exact|recent|refresh`, `--staleness-ms`,
//!   `--refresh-ms` for an explicit daemon period, `--size-shards
//!   auto|N` for the sharded counter mirror).
//! * `analyze`  — run a workload with epoch sampling and push the samples
//!   through the AOT-compiled Pallas pipeline (PJRT).
//! * `verify`   — anomaly hunt: show the naive policy violating
//!   linearizability (paper Figs. 1–2) and the transformed one holding.
//!
//! Figure reproductions live in `cargo bench` targets (see DESIGN.md §4).

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util;
use concurrent_size::cli::{Args, PolicyKind, SizeCallKind};
use concurrent_size::harness::{run, RunConfig, SizeCall};
use concurrent_size::metrics::fmt_rate;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{LinearizableSize, NaiveSize, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::snapshot::SnapshotSkipList;
use concurrent_size::vcas::VcasSet;
use concurrent_size::workload::{self, key_range, Mix, READ_HEAVY, UPDATE_HEAVY};
use concurrent_size::{analytics, MAX_THREADS, runtime};

fn make_set(
    structure: &str,
    policy: &str,
    initial: usize,
    opts: concurrent_size::size::SizeOpts,
) -> Box<dyn ConcurrentSet> {
    // Snapshot-based competitors carry their own size mechanism and ignore
    // the policy; everything else goes through the shared six-policy
    // factory (`bench_util::make_set_opts`).
    match structure {
        "snapshot-skiplist" => return Box::new(SnapshotSkipList::new(MAX_THREADS)),
        "vcas" => return Box::new(VcasSet::new(MAX_THREADS, initial)),
        _ => {}
    }
    let Some(kind) = PolicyKind::parse(policy) else {
        eprintln!(
            "unknown policy {policy:?} (use baseline|linearizable|naive|lock|handshake|optimistic)"
        );
        std::process::exit(2);
    };
    match bench_util::make_set_opts(structure, kind, initial, opts) {
        Some(set) => set,
        None => {
            eprintln!(
                "unknown structure {structure:?} (use {}|snapshot-skiplist|vcas)",
                bench_util::STRUCTURES.join("|")
            );
            std::process::exit(2);
        }
    }
}

fn parse_mix(s: &str) -> Mix {
    match s {
        "update-heavy" | "update" => UPDATE_HEAVY,
        "read-heavy" | "read" => READ_HEAVY,
        other => {
            eprintln!("unknown mix {other:?} (use update-heavy|read-heavy)");
            std::process::exit(2);
        }
    }
}

fn cmd_demo() {
    println!("== concurrent-size demo ==");
    for structure in [
        "hashtable",
        "skiplist",
        "bst",
        "list",
        "snapshot-skiplist",
        "vcas",
    ] {
        let set = make_set(structure, "size", 1024, Default::default());
        for k in 1..=100u64 {
            set.insert(k);
        }
        for k in 1..=50u64 {
            set.delete(k * 2);
        }
        println!(
            "{:<24} contains(1)={:<5} size={:?}",
            set.name(),
            set.contains(1),
            set.size()
        );
    }
    println!("\n-- size policies (hash table) --");
    for kind in PolicyKind::ALL {
        let set = make_set("hashtable", kind.label(), 1024, Default::default());
        for k in 1..=100u64 {
            set.insert(k);
        }
        for k in 1..=50u64 {
            set.delete(k * 2);
        }
        let exact = set.size_exact().map(|v| v.value);
        let recent = set
            .size_recent(Duration::from_millis(50))
            .map(|v| (v.value, v.age));
        println!(
            "{:<12} size={:<10} exact={exact:<8?} recent={recent:?} linearizable={}",
            kind.label(),
            format!("{:?}", set.size()),
            if kind.provides_size() {
                if kind.linearizable() { "yes" } else { "NO" }
            } else {
                "n/a"
            }
        );
    }
}

fn cmd_bench(args: &Args) {
    let structure = args.get("structure").unwrap_or("skiplist").to_string();
    let policy = args.get("policy").unwrap_or("size").to_string();
    let initial = args.get_usize("initial", 100_000);
    let mix = parse_mix(args.get("mix").unwrap_or("update-heavy"));
    let w = args.get_usize("threads", 4);
    let s = args.get_usize("size-threads", 1);
    let secs = args.get_f64("secs", 2.0);
    let call_spelling = args.get("size-call").unwrap_or("raw");
    let Some(call_kind) = SizeCallKind::parse(call_spelling) else {
        eprintln!("unknown --size-call {call_spelling:?} (use raw|exact|recent|refresh)");
        std::process::exit(2);
    };
    let size_call = SizeCall::from_kind(
        call_kind,
        Duration::from_millis(args.get_u64("staleness-ms", 1)),
    );
    let refresh_ms = args.get_f64("refresh-ms", 0.0);
    let opts = concurrent_size::size::SizeOpts::default().with_shards(args.size_shards(0));

    let set = make_set(&structure, &policy, initial, opts);
    let range = key_range(initial as u64, mix);
    println!(
        "prefilling {} with {initial} keys (range [1,{range}])...",
        set.name()
    );
    workload::prefill(set.as_ref(), initial as u64, range, 42);

    // No size threads on structures whose policy provides no size().
    let size_threads = if set.size().is_some() { s } else { 0 };
    let mut cfg = RunConfig::new(w, size_threads, mix, range);
    cfg.duration = Duration::from_secs_f64(secs);
    cfg.size_call = size_call;
    if refresh_ms > 0.0 {
        cfg.refresh_period = Some(Duration::from_secs_f64(refresh_ms / 1e3));
    }
    let res = run(set.as_ref(), &cfg);
    println!(
        "{:<24} mix={} w={w} s={} call={} -> workload {} ops/s, size {} ops/s",
        set.name(),
        mix.label(),
        cfg.size_threads,
        size_call.label(),
        fmt_rate(res.workload_throughput()),
        fmt_rate(res.size_throughput()),
    );
    if let Some(stats) = set.size_stats() {
        if stats.rounds + stats.recent_hits > 0 {
            println!(
                "arbiter: {} rounds ({} daemon-driven), {} adopted, {} recent hits, \
                 {} refreshes",
                stats.rounds,
                stats.daemon_rounds,
                stats.adoptions,
                stats.recent_hits,
                stats.recent_refreshes
            );
        }
        if stats.retry_budget > 0 {
            println!(
                "optimistic tuning: budget {} after {} fallbacks",
                stats.retry_budget, stats.fallbacks
            );
        }
    }
    if let Some(estimate) = set.size_estimate() {
        println!("sharded estimate at quiescence: {estimate}");
    }
}

fn cmd_analyze(args: &Args) {
    let initial = args.get_usize("initial", 10_000);
    let epochs = args.get_usize("epochs", 64).min(runtime::AOT_E);
    let secs = args.get_f64("secs", 2.0);
    let mix = parse_mix(args.get("mix").unwrap_or("update-heavy"));

    println!("loading PJRT artifacts...");
    let artifacts = match runtime::Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("analyze unavailable: {e}");
            std::process::exit(1);
        }
    };

    let set: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));
    let range = key_range(initial as u64, mix);
    workload::prefill(set.as_ref(), initial as u64, range, 42);

    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..3u64)
        .map(|t| {
            let set = set.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut stream = workload::OpStream::new(t, mix, range);
                let mut ops = 0u64;
                while !stop.load(SeqCst) {
                    let (op, k) = stream.next();
                    workload::apply(set.as_ref(), op, k);
                    ops += 1;
                }
                ops
            })
        })
        .collect();

    let mut rec = analytics::EpochRecorder::new();
    let calc = set.policy().calculator().unwrap();
    let epoch_dt = Duration::from_secs_f64(secs / epochs as f64);
    for _ in 0..epochs.saturating_sub(1) {
        std::thread::sleep(epoch_dt);
        rec.record(calc);
    }
    stop.store(true, SeqCst);
    let total_ops: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
    rec.record(calc); // final, quiescent epoch

    let report = analytics::analyze(&artifacts, &rec).expect("pipeline failure");
    println!(
        "epochs={} ops={} final size (pallas)={} (linearizable)={} skew_max={} final_exact={}",
        rec.len(),
        total_ops,
        report.pallas_sizes.last().unwrap(),
        report.linearizable_sizes.last().unwrap(),
        report.max_skew(),
        report.final_exact(),
    );
    assert!(report.final_exact(), "quiescent epoch must be exact");
}

fn cmd_verify(args: &Args) {
    use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies};
    use concurrent_size::size::SizeOpts;
    let trials = args.get_usize("trials", 2_000);
    let rounds = args.get_usize("rounds", 500);

    let mut naive_policy = NaiveSize::new(MAX_THREADS, SizeOpts::default());
    naive_policy.set_insert_window(Duration::from_micros(80));
    let naive: SkipListSet<NaiveSize> = SkipListSet::with_policy(naive_policy);
    let lin: SkipListSet<LinearizableSize> = SkipListSet::new(MAX_THREADS);

    println!("-- Figure 1 anomaly (contains=true then size=0), {trials} trials --");
    println!("  naive        : {}", fig1_anomalies(&naive, trials));
    let lin1 = fig1_anomalies(&lin, trials);
    println!("  linearizable : {lin1}");

    println!("-- Figure 2 anomaly (negative size), {rounds} rounds --");
    println!("  naive        : {}", fig2_anomalies(&naive, rounds));
    let lin2 = fig2_anomalies(&lin, rounds);
    println!("  linearizable : {lin2}");

    assert_eq!(lin1 + lin2, 0, "the transformed structure must never misreport");
    println!("verify OK: methodology exhibits no anomalies");
}

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("demo") | None => cmd_demo(),
        Some("bench") => cmd_bench(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("verify") => cmd_verify(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other:?}; try demo|bench|analyze|verify");
            std::process::exit(2);
        }
    }
}
