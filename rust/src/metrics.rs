//! Summary statistics and table formatting for the benchmark harness.
//!
//! The paper reports each data point as the average of repeated runs and
//! quotes the coefficient of variation (Section 9: "up to 11%"); this module
//! provides exactly those aggregates plus simple fixed-width table output
//! used by the figure benches.

/// Aggregates over repeated measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Stats::from_samples on empty slice");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Coefficient of variation (stddev / mean); 0 for a zero mean.
    pub fn cov(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.stddev / self.mean
        }
    }
}

/// Percentile with linear interpolation (`p` in `[0, 100]`).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Human-readable ops/sec (e.g., `12.3M`, `455.1K`).
pub fn fmt_rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e6 {
        format!("{:.2}M", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.1}K", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.1}")
    }
}

/// Escape a string for inclusion in a JSON document (no serde offline;
/// the machine-readable bench reports hand-assemble their JSON).
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a float as a JSON value (`null` for non-finite numbers, which
/// raw JSON cannot represent).
pub fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "null".into()
    }
}

/// Fixed-width table printer used by the figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column-count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.stddev - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn stats_single_sample_has_zero_stddev() {
        let s = Stats::from_samples(&[5.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.cov(), 0.0);
    }

    #[test]
    fn cov_matches_definition() {
        let s = Stats::from_samples(&[10.0, 12.0, 8.0]);
        assert!((s.cov() - s.stddev / s.mean).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn fmt_rate_scales() {
        assert_eq!(fmt_rate(12_345_678.0), "12.35M");
        assert_eq!(fmt_rate(4_200.0), "4.2K");
        assert_eq!(fmt_rate(9.0), "9.0");
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\ny");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn json_f64_rejects_non_finite() {
        assert_eq!(json_f64(1.5), "1.500");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["w", "ops/s"]);
        t.row(&["1".into(), "12.3M".into()]);
        t.row(&["64".into(), "1.1M".into()]);
        let r = t.render();
        assert!(r.contains("w  ops/s") || r.contains(" w  ops/s"));
        assert_eq!(r.lines().count(), 4);
    }
}
