//! Cache-line padding, replacing `crossbeam_utils::CachePadded` so the
//! crate builds with zero external dependencies (the offline image has no
//! crates.io registry).
//!
//! Alignment is 128 bytes: the size of two x86-64 cache lines (the spatial
//! prefetcher pulls pairs) and of one aarch64 cache line on big cores —
//! the same constant crossbeam uses on these targets. Each padded value
//! therefore owns its line(s), which is what keeps the paper's per-thread
//! counter arrays free of false sharing (paper Section 6.1).

use std::ops::{Deref, DerefMut};

/// Pads and aligns `T` to 128 bytes.
#[derive(Debug, Default)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    #[inline]
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    #[inline]
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> Deref for CachePadded<T> {
    type Target = T;
    #[inline]
    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> DerefMut for CachePadded<T> {
    #[inline]
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> Self {
        Self::new(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_128() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 128);
        assert_eq!(std::mem::size_of::<CachePadded<u8>>(), 128);
    }

    #[test]
    fn deref_roundtrip() {
        let mut p = CachePadded::new(7u64);
        assert_eq!(*p, 7);
        *p = 9;
        assert_eq!(p.into_inner(), 9);
    }

    #[test]
    fn adjacent_padded_values_share_no_line() {
        let pair = [CachePadded::new(0u8), CachePadded::new(0u8)];
        let a = &pair[0] as *const _ as usize;
        let b = &pair[1] as *const _ as usize;
        assert!(b - a >= 128);
    }
}
