//! Minimal randomized property-testing helper.
//!
//! `proptest` is not available in the offline build, so this module carries
//! the 20% we need: run a property over many seeded random cases, report
//! the failing seed for reproduction, and honor `CSIZE_PROP_SEED` /
//! `CSIZE_PROP_CASES` env overrides.

use crate::rng::Xoshiro256;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        let seed = std::env::var("CSIZE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC512E);
        let cases = std::env::var("CSIZE_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Self { cases, seed }
    }
}

/// Run `prop` over `config.cases` random cases; panics with the case seed on
/// the first failure (re-run with `CSIZE_PROP_SEED=<seed> CSIZE_PROP_CASES=1`).
pub fn run_with(
    name: &str,
    config: Config,
    mut prop: impl FnMut(&mut Xoshiro256) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{} \
                 (CSIZE_PROP_SEED={case_seed} to reproduce): {msg}",
                config.cases
            );
        }
    }
}

/// [`run_with`] under the default/env configuration.
pub fn run(name: &str, prop: impl FnMut(&mut Xoshiro256) -> Result<(), String>) {
    run_with(name, Config::default(), prop);
}

/// Assert helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_with(
            "trivial",
            Config { cases: 10, seed: 1 },
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        run_with("failing", Config { cases: 5, seed: 2 }, |rng| {
            let x = rng.gen_range(10);
            prop_assert!(x < 5, "x={x} too big");
            Ok(())
        });
    }

    #[test]
    fn prop_assert_formats_message() {
        let res: Result<(), String> = (|| {
            prop_assert!(1 + 1 == 3, "math broke: {}", 42);
            Ok(())
        })();
        assert_eq!(res.unwrap_err(), "math broke: 42");
    }
}
