//! Deterministic PRNGs for workloads and property tests.
//!
//! `rand` is unavailable in the offline build, so we carry SplitMix64 (for
//! seeding) and xoshiro256++ (for streams) — the standard pair for
//! reproducible benchmark workloads.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-period stream generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift rejection).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[0.0, 1.0)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn gen_range_incl(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.gen_range(hi - lo + 1)
    }

    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_is_in_bounds() {
        let mut r = Xoshiro256::new(3);
        for bound in [1u64, 2, 3, 10, 1 << 40] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_range_incl_covers_endpoints() {
        let mut r = Xoshiro256::new(4);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match r.gen_range_incl(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                x => assert!((5..=8).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Xoshiro256::new(5);
        for _ in 0..1000 {
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn roughly_uniform_buckets() {
        let mut r = Xoshiro256::new(6);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.gen_range(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
