//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas analytics
//! artifacts from Rust.
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py` and
//! DESIGN.md): `HloModuleProto::from_text_file` → `client.compile` →
//! `execute`. Executables are compiled once at load and reused; Python is
//! never on any execution path.
//!
//! Shape contract (mirrors `python/compile/aot.py`):
//! * `size_reduce.hlo.txt`   : s64[[`AOT_E`], [`AOT_T`], 2] → (s64[[`AOT_E`]],)
//! * `prefix_scan.hlo.txt`   : s64[[`AOT_L`]] → (s64[[`AOT_L`]],)
//! * `history_stats.hlo.txt` : s64[[`AOT_L`]], s64[] → (s64[[`AOT_L`]], s64[4])
//!
//! ## Offline builds
//!
//! The XLA backend needs the vendored `xla` crate and `libxla`, which the
//! offline image does not carry, so it sits behind the `pjrt` cargo
//! feature. The default build substitutes a stub whose loaders return
//! [`Err`]; every artifact consumer (integration tests, `csize analyze`,
//! `examples/size_analytics`) treats that as "skip the PJRT cross-check".
//! The Rust oracles in [`crate::history`] keep the same semantics covered.

use std::fmt;
use std::path::Path;

use crate::history::HistoryStats;

/// Epochs per analytics batch (AOT_E in aot.py).
pub const AOT_E: usize = 256;
/// Thread slots (AOT_T in aot.py; == [`crate::MAX_THREADS`]).
pub const AOT_T: usize = 64;
/// History log capacity (AOT_L in aot.py).
pub const AOT_L: usize = 65536;

/// Runtime error: a message chain, `anyhow`-shaped but dependency-free.
#[derive(Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        Self(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Early-return shorthand (scoped to this module and its backends).
macro_rules! bail {
    ($($fmt:tt)+) => {
        return Err($crate::runtime::RuntimeError::new(format!($($fmt)+)))
    };
}

/// Locate the `artifacts/` directory by walking up from the current dir.
fn find_artifacts_dir() -> Result<std::path::PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("size_reduce.hlo.txt").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!("artifacts/ not found; run `make artifacts` first");
        }
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    //! The real XLA-backed implementation (requires the vendored `xla`
    //! crate; see the module docs).
    use super::*;

    /// The three compiled analytics executables.
    pub struct Artifacts {
        size_reduce: xla::PjRtLoadedExecutable,
        prefix_scan: xla::PjRtLoadedExecutable,
        history_stats: xla::PjRtLoadedExecutable,
    }

    impl Artifacts {
        /// Compile all artifacts from `dir` on the PJRT CPU client.
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::new(format!("creating PJRT CPU client: {e}")))?;
            let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(name);
                let text_path = path
                    .to_str()
                    .ok_or_else(|| RuntimeError::new("non-utf8 artifact path"))?;
                let proto = xla::HloModuleProto::from_text_file(text_path)
                    .map_err(|e| RuntimeError::new(format!("parsing {}: {e}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| RuntimeError::new(format!("compiling {}: {e}", path.display())))
            };
            Ok(Self {
                size_reduce: compile("size_reduce.hlo.txt")?,
                prefix_scan: compile("prefix_scan.hlo.txt")?,
                history_stats: compile("history_stats.hlo.txt")?,
            })
        }

        /// Locate the artifacts directory, then [`Self::load`] it.
        pub fn load_default() -> Result<Self> {
            Self::load(find_artifacts_dir()?)
        }

        /// Per-epoch sizes from per-thread counter samples.
        ///
        /// `epochs[e][t] = [insertions, deletions]`; at most [`AOT_E`]
        /// epochs of at most [`AOT_T`] threads (zero-padded to AOT shape).
        pub fn epoch_sizes(&self, epochs: &[Vec<[u64; 2]>]) -> Result<Vec<i64>> {
            if epochs.len() > AOT_E {
                bail!("too many epochs: {} > {AOT_E}", epochs.len());
            }
            let mut flat = vec![0i64; AOT_E * AOT_T * 2];
            for (e, sample) in epochs.iter().enumerate() {
                if sample.len() > AOT_T {
                    bail!("too many threads: {} > {AOT_T}", sample.len());
                }
                for (t, pair) in sample.iter().enumerate() {
                    flat[(e * AOT_T + t) * 2] = pair[0] as i64;
                    flat[(e * AOT_T + t) * 2 + 1] = pair[1] as i64;
                }
            }
            let input = xla::Literal::vec1(&flat)
                .reshape(&[AOT_E as i64, AOT_T as i64, 2])
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            let out = self
                .size_reduce
                .execute::<xla::Literal>(&[input])
                .map_err(|e| RuntimeError::new(e.to_string()))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(e.to_string()))?
                .to_tuple1()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            let sizes = out
                .to_vec::<i64>()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            Ok(sizes[..epochs.len()].to_vec())
        }

        /// Running sizes of a delta log via the Pallas `prefix_scan` kernel.
        pub fn running_sizes(&self, deltas: &[i64]) -> Result<Vec<i64>> {
            if deltas.len() > AOT_L {
                bail!("history too long: {} > {AOT_L}", deltas.len());
            }
            let mut padded = vec![0i64; AOT_L];
            padded[..deltas.len()].copy_from_slice(deltas);
            let input = xla::Literal::vec1(&padded);
            let out = self
                .prefix_scan
                .execute::<xla::Literal>(&[input])
                .map_err(|e| RuntimeError::new(e.to_string()))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(e.to_string()))?
                .to_tuple1()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            let running = out
                .to_vec::<i64>()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            Ok(running[..deltas.len()].to_vec())
        }

        /// Full history validation (running sizes + stats) via the Pallas
        /// pipeline.
        pub fn validate_history(&self, deltas: &[i64]) -> Result<(Vec<i64>, HistoryStats)> {
            if deltas.len() > AOT_L {
                bail!("history too long: {} > {AOT_L}", deltas.len());
            }
            let mut padded = vec![0i64; AOT_L];
            padded[..deltas.len()].copy_from_slice(deltas);
            let input = xla::Literal::vec1(&padded);
            let vlen = xla::Literal::scalar(deltas.len() as i64);
            let (running, stats) = self
                .history_stats
                .execute::<xla::Literal>(&[input, vlen])
                .map_err(|e| RuntimeError::new(e.to_string()))?[0][0]
                .to_literal_sync()
                .map_err(|e| RuntimeError::new(e.to_string()))?
                .to_tuple2()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            let running = running
                .to_vec::<i64>()
                .map_err(|e| RuntimeError::new(e.to_string()))?[..deltas.len()]
                .to_vec();
            let s = stats
                .to_vec::<i64>()
                .map_err(|e| RuntimeError::new(e.to_string()))?;
            Ok((
                running,
                HistoryStats {
                    min: s[0],
                    max: s[1],
                    final_size: s[2],
                    negative_count: s[3],
                },
            ))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend for builds without the `pjrt` feature: the API
    //! compiles, the loaders fail, consumers skip the PJRT cross-check.
    use super::*;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (requires the vendored xla crate and libxla)";

    /// Stub artifacts handle; the loaders always fail, so the methods are
    /// unreachable in practice and just re-report the missing feature.
    pub struct Artifacts {
        _private: (),
    }

    impl Artifacts {
        pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
            Err(RuntimeError::new(UNAVAILABLE))
        }

        pub fn load_default() -> Result<Self> {
            // Distinguish "no runtime" from "no runtime AND no artifacts"
            // so the user fixes the right thing first.
            match find_artifacts_dir() {
                Ok(dir) => Err(RuntimeError::new(format!(
                    "{UNAVAILABLE}; artifacts are present at {}",
                    dir.display()
                ))),
                Err(_) => Err(RuntimeError::new(format!(
                    "{UNAVAILABLE}; artifacts/ not found either"
                ))),
            }
        }

        pub fn epoch_sizes(&self, _epochs: &[Vec<[u64; 2]>]) -> Result<Vec<i64>> {
            Err(RuntimeError::new(UNAVAILABLE))
        }

        pub fn running_sizes(&self, _deltas: &[i64]) -> Result<Vec<i64>> {
            Err(RuntimeError::new(UNAVAILABLE))
        }

        pub fn validate_history(&self, _deltas: &[i64]) -> Result<(Vec<i64>, HistoryStats)> {
            Err(RuntimeError::new(UNAVAILABLE))
        }
    }
}

pub use backend::Artifacts;

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    //! These tests require `make artifacts` to have run (they are part of
    //! the `make test` flow, which guarantees it).
    use super::*;
    use crate::history;

    fn artifacts() -> Artifacts {
        Artifacts::load_default().expect("run `make artifacts` before `cargo test`")
    }

    #[test]
    fn epoch_sizes_match_rust_oracle() {
        let a = artifacts();
        let epochs: Vec<Vec<[u64; 2]>> = (0..10)
            .map(|e| (0..8).map(|t| [(e * t + e) as u64, (e * t / 2) as u64]).collect())
            .collect();
        let got = a.epoch_sizes(&epochs).unwrap();
        let want: Vec<i64> = epochs
            .iter()
            .map(|s| s.iter().map(|p| p[0] as i64 - p[1] as i64).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn running_sizes_match_rust_oracle() {
        let a = artifacts();
        let deltas: Vec<i64> = (0..1000).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        assert_eq!(
            a.running_sizes(&deltas).unwrap(),
            history::running_sizes(&deltas)
        );
    }

    #[test]
    fn validate_history_matches_rust_oracle() {
        let a = artifacts();
        let deltas = vec![1, 1, -1, 1, -1, -1, 1, 1];
        let (running, stats) = a.validate_history(&deltas).unwrap();
        let (want_running, want_stats) = history::validate(&deltas);
        assert_eq!(running, want_running);
        assert_eq!(stats, want_stats);
        assert!(stats.is_legal());
    }

    #[test]
    fn illegal_history_is_flagged_by_kernel() {
        let a = artifacts();
        let (_, stats) = a.validate_history(&[-1, 1]).unwrap();
        assert_eq!(stats.min, -1);
        assert_eq!(stats.negative_count, 1);
        assert!(!stats.is_legal());
    }

    #[test]
    fn empty_epoch_batch() {
        let a = artifacts();
        assert!(a.epoch_sizes(&[]).unwrap().is_empty());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_loaders_report_missing_feature() {
        let err = Artifacts::load_default().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "unhelpful error: {err}");
        let err = Artifacts::load("/nonexistent").err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
    }
}
