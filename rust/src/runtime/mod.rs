//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas analytics
//! artifacts from Rust.
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py` and
//! DESIGN.md): `HloModuleProto::from_text_file` → `client.compile` →
//! `execute`. Executables are compiled once at load and reused; Python is
//! never on any execution path.
//!
//! Shape contract (mirrors `python/compile/aot.py`):
//! * `size_reduce.hlo.txt`   : s64[[`AOT_E`], [`AOT_T`], 2] → (s64[[`AOT_E`]],)
//! * `prefix_scan.hlo.txt`   : s64[[`AOT_L`]] → (s64[[`AOT_L`]],)
//! * `history_stats.hlo.txt` : s64[[`AOT_L`]], s64[] → (s64[[`AOT_L`]], s64[4])

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::history::HistoryStats;

/// Epochs per analytics batch (AOT_E in aot.py).
pub const AOT_E: usize = 256;
/// Thread slots (AOT_T in aot.py; == [`crate::MAX_THREADS`]).
pub const AOT_T: usize = 64;
/// History log capacity (AOT_L in aot.py).
pub const AOT_L: usize = 65536;

/// The three compiled analytics executables.
pub struct Artifacts {
    size_reduce: xla::PjRtLoadedExecutable,
    prefix_scan: xla::PjRtLoadedExecutable,
    history_stats: xla::PjRtLoadedExecutable,
}

impl Artifacts {
    /// Compile all artifacts from `dir` (default: `./artifacts`) on the
    /// PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))
        };
        Ok(Self {
            size_reduce: compile("size_reduce.hlo.txt")?,
            prefix_scan: compile("prefix_scan.hlo.txt")?,
            history_stats: compile("history_stats.hlo.txt")?,
        })
    }

    /// Locate the artifacts directory relative to the repo root (walks up
    /// from the current dir), then [`Self::load`] it.
    pub fn load_default() -> Result<Self> {
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.join("size_reduce.hlo.txt").exists() {
                return Self::load(&cand);
            }
            if !dir.pop() {
                bail!("artifacts/ not found; run `make artifacts` first");
            }
        }
    }

    /// Per-epoch sizes from per-thread counter samples.
    ///
    /// `epochs[e][t] = [insertions, deletions]`; at most [`AOT_E`] epochs of
    /// at most [`AOT_T`] threads (padded with zeros up to the AOT shape).
    pub fn epoch_sizes(&self, epochs: &[Vec<[u64; 2]>]) -> Result<Vec<i64>> {
        if epochs.len() > AOT_E {
            bail!("too many epochs: {} > {AOT_E}", epochs.len());
        }
        let mut flat = vec![0i64; AOT_E * AOT_T * 2];
        for (e, sample) in epochs.iter().enumerate() {
            if sample.len() > AOT_T {
                bail!("too many threads: {} > {AOT_T}", sample.len());
            }
            for (t, pair) in sample.iter().enumerate() {
                flat[(e * AOT_T + t) * 2] = pair[0] as i64;
                flat[(e * AOT_T + t) * 2 + 1] = pair[1] as i64;
            }
        }
        let input = xla::Literal::vec1(&flat).reshape(&[AOT_E as i64, AOT_T as i64, 2])?;
        let out = self.size_reduce.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let sizes = out.to_vec::<i64>()?;
        Ok(sizes[..epochs.len()].to_vec())
    }

    /// Running sizes of a delta log via the Pallas `prefix_scan` kernel.
    pub fn running_sizes(&self, deltas: &[i64]) -> Result<Vec<i64>> {
        if deltas.len() > AOT_L {
            bail!("history too long: {} > {AOT_L}", deltas.len());
        }
        let mut padded = vec![0i64; AOT_L];
        padded[..deltas.len()].copy_from_slice(deltas);
        let input = xla::Literal::vec1(&padded);
        let out = self.prefix_scan.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let running = out.to_vec::<i64>()?;
        Ok(running[..deltas.len()].to_vec())
    }

    /// Full history validation (running sizes + stats) via the Pallas
    /// pipeline.
    pub fn validate_history(&self, deltas: &[i64]) -> Result<(Vec<i64>, HistoryStats)> {
        if deltas.len() > AOT_L {
            bail!("history too long: {} > {AOT_L}", deltas.len());
        }
        let mut padded = vec![0i64; AOT_L];
        padded[..deltas.len()].copy_from_slice(deltas);
        let input = xla::Literal::vec1(&padded);
        let vlen = xla::Literal::scalar(deltas.len() as i64);
        let (running, stats) = self.history_stats.execute::<xla::Literal>(&[input, vlen])?[0][0]
            .to_literal_sync()?
            .to_tuple2()?;
        let running = running.to_vec::<i64>()?[..deltas.len()].to_vec();
        let s = stats.to_vec::<i64>()?;
        Ok((
            running,
            HistoryStats {
                min: s[0],
                max: s[1],
                final_size: s[2],
                negative_count: s[3],
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run (they are part of
    //! the `make test` flow, which guarantees it).
    use super::*;
    use crate::history;

    fn artifacts() -> Artifacts {
        Artifacts::load_default().expect("run `make artifacts` before `cargo test`")
    }

    #[test]
    fn epoch_sizes_match_rust_oracle() {
        let a = artifacts();
        let epochs: Vec<Vec<[u64; 2]>> = (0..10)
            .map(|e| (0..8).map(|t| [(e * t + e) as u64, (e * t / 2) as u64]).collect())
            .collect();
        let got = a.epoch_sizes(&epochs).unwrap();
        let want: Vec<i64> = epochs
            .iter()
            .map(|s| s.iter().map(|p| p[0] as i64 - p[1] as i64).sum())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn running_sizes_match_rust_oracle() {
        let a = artifacts();
        let deltas: Vec<i64> = (0..1000).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        assert_eq!(
            a.running_sizes(&deltas).unwrap(),
            history::running_sizes(&deltas)
        );
    }

    #[test]
    fn validate_history_matches_rust_oracle() {
        let a = artifacts();
        let deltas = vec![1, 1, -1, 1, -1, -1, 1, 1];
        let (running, stats) = a.validate_history(&deltas).unwrap();
        let (want_running, want_stats) = history::validate(&deltas);
        assert_eq!(running, want_running);
        assert_eq!(stats, want_stats);
        assert!(stats.is_legal());
    }

    #[test]
    fn illegal_history_is_flagged_by_kernel() {
        let a = artifacts();
        let (_, stats) = a.validate_history(&[-1, 1]).unwrap();
        assert_eq!(stats.min, -1);
        assert_eq!(stats.negative_count, 1);
        assert!(!stats.is_legal());
    }

    #[test]
    fn empty_epoch_batch() {
        let a = artifacts();
        assert!(a.epoch_sizes(&[]).unwrap().is_empty());
    }
}
