//! The accept front-end: one thread turning the listener's backlog into
//! per-reactor connection handoffs.
//!
//! The acceptor owns the nonblocking listener and nothing else. Each
//! accepted socket is assigned to a reactor shard — round-robin for
//! fairness, overridden by a least-loaded pick when the rotation would
//! land on a shard strictly busier than the emptiest one (so a shard
//! stuck with long-lived connections does not keep collecting new ones) —
//! and sent over that shard's handoff channel. The shard adopts it on its
//! next tick.
//!
//! Accounting: a socket in flight between accept and adoption is counted
//! in its shard's `handoff` gauge (the acceptor increments, the shard
//! decrements on adoption), so the `max_conns` ceiling and the load
//! tiebreak both see connections the instant they exist, and the
//! cluster-wide `peak` high-water ([`Shared::peak_total`]) is exact: the
//! acceptor is the single serialization point where every connection
//! enters, so it alone can observe the true simultaneous maximum.
//!
//! Fault plane: [`FaultSite::AcceptHandoff`] fires per handoff, between
//! the gauge increment and the channel send — a `Delay` stretches the
//! accept→adopt window, and a `Panic` (targeted tests) is contained per
//! socket: that one client is dropped, the acceptor survives.

use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering::SeqCst;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::faults::{self, FaultSite};

use super::{IdleStrategy, Shared};

/// The acceptor's share of the [`super::ServerConfig`] knobs.
pub(crate) struct AcceptorConfig {
    pub idle: IdleStrategy,
    /// Cluster-wide live-connection ceiling (live + pending handoffs);
    /// beyond it new clients get `ERR server full` and are dropped.
    pub max_conns: usize,
}

pub(crate) struct Acceptor {
    listener: TcpListener,
    /// One handoff lane per reactor shard, index-aligned with
    /// `shared.gauges`.
    handoffs: Box<[Sender<TcpStream>]>,
    shared: Arc<Shared>,
    cfg: AcceptorConfig,
    /// Round-robin cursor over the shards.
    rr: usize,
}

impl Acceptor {
    pub fn new(
        listener: TcpListener,
        handoffs: Vec<Sender<TcpStream>>,
        shared: Arc<Shared>,
        cfg: AcceptorConfig,
    ) -> Self {
        assert!(!handoffs.is_empty(), "acceptor needs at least one shard");
        Self {
            listener,
            handoffs: handoffs.into(),
            shared,
            cfg,
            rr: 0,
        }
    }

    /// The accept loop. Returns when [`Shared::stop`] is raised; dropping
    /// the acceptor then closes the listener and the handoff senders.
    pub fn run(mut self) {
        while !self.shared.stop.load(SeqCst) {
            if !self.accept_ready() {
                match self.cfg.idle {
                    IdleStrategy::Sleep(nap) => std::thread::sleep(nap),
                    IdleStrategy::Spin => std::thread::yield_now(),
                }
            }
        }
    }

    /// Accept and hand off every connection the listener has ready.
    fn accept_ready(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    progress = true;
                    self.shared.accepted.fetch_add(1, SeqCst);
                    let total = self.shared.total_conns();
                    if total >= self.cfg.max_conns {
                        // Decline politely; the fresh socket buffer takes
                        // this short write without blocking.
                        let mut stream = stream;
                        let _ = stream.write_all(b"ERR server full\n");
                        continue;
                    }
                    // Every connection enters here, so this fetch_max
                    // records the exact cluster-wide high-water — summing
                    // per-shard peaks would overcount (shards peak at
                    // different times) and maxing them would undercount.
                    self.shared.peak_total.fetch_max(total + 1, SeqCst);
                    let shard = self.pick_shard();
                    self.shared.gauges[shard].handoff.fetch_add(1, SeqCst);
                    let jittered =
                        std::panic::catch_unwind(|| faults::jitter(FaultSite::AcceptHandoff));
                    let handed = jittered.is_ok() && self.handoffs[shard].send(stream).is_ok();
                    if !handed {
                        // Injected handoff panic, or the shard is gone
                        // (shutdown): drop this one socket, keep serving.
                        self.shared.gauges[shard].handoff.fetch_sub(1, SeqCst);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient failures (ECONNABORTED, EMFILE, ...) must
                    // not take the server down; the idle backoff keeps a
                    // persistent error from hot-looping.
                    eprintln!("server: accept failed: {e}");
                    break;
                }
            }
        }
        progress
    }

    /// Round-robin with a least-loaded override: take the next shard in
    /// rotation, unless some shard currently holds strictly fewer
    /// connections (live + pending handoffs) than the rotation's pick —
    /// then take the emptiest instead.
    fn pick_shard(&mut self) -> usize {
        let load = |i: usize| {
            let g = &self.shared.gauges[i];
            g.live.load(SeqCst) + g.handoff.load(SeqCst)
        };
        let pick = self.rr;
        self.rr = (self.rr + 1) % self.handoffs.len();
        let least = (0..self.handoffs.len()).min_by_key(|&i| load(i)).unwrap_or(pick);
        if load(pick) > load(least) {
            least
        } else {
            pick
        }
    }
}
