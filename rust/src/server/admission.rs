//! Size-driven admission control: the "reliable size in a real system"
//! scenario the paper's introduction motivates, closed end to end.
//!
//! The server consults the store's O(shards) bounded-lag probe
//! (`ConcurrentSet::size_estimate`, the [`crate::size::ShardedCounters`]
//! mirror from the scale layer) on every incoming `PUT` and compares it
//! against a high/low watermark pair with **hysteresis**:
//!
//! * estimate ≥ `high` → start **shedding**: `PUT`s get
//!   [`super::proto::OVERLOAD_REPLY`] without touching the store (deletes,
//!   reads and every size probe stay admitted — they are what drains the
//!   overload and what monitoring needs while it happens);
//! * once shedding, stay shedding until the estimate falls **to or below
//!   `low`** — the band between the watermarks absorbs estimate jitter
//!   (the probe may trail the exact size by the in-flight ops), so
//!   admission does not flap at the boundary.
//!
//! The estimate is clamped at zero before any comparison: the mirror's
//! reconciliation sweep already clamps (exact at quiescence, never
//! negative), and this layer re-asserts the contract so a shed decision
//! can never be justified by an absurd negative reading — the proptest in
//! `rust/tests/server.rs` pins both layers.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};

/// High/low occupancy watermarks, in keys. `low <= high`; the gap is the
/// hysteresis band.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Watermarks {
    /// Shed `PUT`s once the estimate reaches this.
    pub high: i64,
    /// Readmit only once the estimate has drained back to this.
    pub low: i64,
}

impl Watermarks {
    /// Build a validated pair. Panics on `low > high` or a negative
    /// `high` — both are configuration errors worth failing loudly on
    /// (CLI surfaces validate first and exit 2 instead).
    pub fn new(high: i64, low: i64) -> Self {
        assert!(
            high >= 0,
            "admission high watermark must be >= 0, got {high}"
        );
        assert!(
            low <= high,
            "admission low watermark {low} above high {high}"
        );
        Self {
            high,
            low: low.max(0),
        }
    }
}

/// The admission gate: watermark state plus shed telemetry. One per
/// server; every decision is a couple of atomic ops, cheap enough for the
/// per-`PUT` hot path.
pub struct Admission {
    marks: Watermarks,
    /// Hysteresis state: currently shedding?
    shedding: AtomicBool,
    /// `PUT`s shed so far (the `STATS` `shed=` field).
    shed: AtomicU64,
    /// Total decisions taken (shed + admitted), for rate accounting.
    decisions: AtomicU64,
}

impl Admission {
    pub fn new(marks: Watermarks) -> Self {
        Self {
            marks,
            shedding: AtomicBool::new(false),
            shed: AtomicU64::new(0),
            decisions: AtomicU64::new(0),
        }
    }

    /// The clamped-estimate contract, in one place: a missing probe (no
    /// sharded mirror) reads as 0 — admission never sheds on a store it
    /// cannot measure — and a negative reading (impossible per the mirror
    /// contract, re-asserted here) clamps to 0.
    pub fn clamp(estimate: Option<i64>) -> i64 {
        estimate.unwrap_or(0).max(0)
    }

    /// Decide one incoming `PUT` given the store's current size estimate;
    /// `true` admits, `false` sheds. Applies the hysteresis transition
    /// described in the module docs.
    pub fn admit(&self, estimate: Option<i64>) -> bool {
        let est = Self::clamp(estimate);
        debug_assert!(est >= 0, "clamped estimate went negative");
        self.decisions.fetch_add(1, Relaxed);
        let shed = if self.shedding.load(SeqCst) {
            if est <= self.marks.low {
                self.shedding.store(false, SeqCst);
                false
            } else {
                true
            }
        } else if est >= self.marks.high {
            self.shedding.store(true, SeqCst);
            true
        } else {
            false
        };
        if shed {
            self.shed.fetch_add(1, Relaxed);
        }
        !shed
    }

    /// Whether the gate is currently shedding (the `STATS` `admitting=`
    /// field is the negation).
    pub fn shedding(&self) -> bool {
        self.shedding.load(SeqCst)
    }

    /// `PUT`s shed so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Relaxed)
    }

    /// Total decisions taken so far.
    pub fn decisions(&self) -> u64 {
        self.decisions.load(Relaxed)
    }

    /// The configured watermarks.
    pub fn watermarks(&self) -> Watermarks {
        self.marks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(high: i64, low: i64) -> Admission {
        Admission::new(Watermarks::new(high, low))
    }

    #[test]
    fn admits_below_high_watermark() {
        let a = gate(10, 5);
        for est in [0, 3, 9] {
            assert!(a.admit(Some(est)), "est={est} must admit");
        }
        assert!(!a.shedding());
        assert_eq!(a.shed_count(), 0);
        assert_eq!(a.decisions(), 3);
    }

    #[test]
    fn sheds_at_high_and_holds_through_the_band() {
        let a = gate(10, 5);
        assert!(!a.admit(Some(10)), "reaching high must shed");
        assert!(a.shedding());
        // Hysteresis: anywhere in (low, high) stays shedding.
        for est in [9, 7, 6] {
            assert!(
                !a.admit(Some(est)),
                "est={est} inside the band must stay shed"
            );
        }
        assert_eq!(a.shed_count(), 4);
    }

    #[test]
    fn readmits_only_at_or_below_low() {
        let a = gate(10, 5);
        assert!(!a.admit(Some(12)));
        assert!(!a.admit(Some(6)), "one above low: still shedding");
        assert!(a.admit(Some(5)), "at low: readmit");
        assert!(!a.shedding());
        // Fresh climb re-triggers at high, not before.
        assert!(a.admit(Some(9)));
        assert!(!a.admit(Some(11)));
    }

    #[test]
    fn clamps_absurd_estimates() {
        assert_eq!(Admission::clamp(None), 0);
        assert_eq!(Admission::clamp(Some(-7)), 0);
        assert_eq!(Admission::clamp(Some(i64::MIN)), 0);
        assert_eq!(Admission::clamp(Some(42)), 42);
        // A negative reading can never justify shedding...
        let a = gate(10, 5);
        assert!(a.admit(Some(-1_000_000)));
        // ...and a missing mirror admits everything.
        assert!(a.admit(None));
        assert_eq!(a.shed_count(), 0);
    }

    #[test]
    fn equal_watermarks_degenerate_band() {
        let a = gate(4, 4);
        assert!(a.admit(Some(3)));
        assert!(!a.admit(Some(4)), "at high: shed");
        assert!(a.admit(Some(4)), "at low (== high): readmit immediately");
    }

    #[test]
    #[should_panic(expected = "low watermark")]
    fn rejects_inverted_watermarks() {
        Watermarks::new(5, 10);
    }
}
