//! Per-connection state for the reactor: nonblocking read/write buffers
//! and the partial-line state machine.
//!
//! A connection owns a nonblocking [`TcpStream`] plus three pieces of
//! state the reactor multiplexes over:
//!
//! * a [`LineBuffer`] accumulating read bytes until a `\n` completes a
//!   command (clients may trickle a line over many packets, or batch many
//!   lines into one);
//! * a FIFO of [`Pending`] work — parsed requests and precomputed error
//!   replies interleaved **in arrival order**, so a malformed line's
//!   `ERR` answer never overtakes the reply of an earlier valid command
//!   still in the handler pool;
//! * a write buffer with a partial-write cursor, flushed as the socket
//!   accepts bytes.
//!
//! At most one *batch* per connection is in flight in the handler pool
//! (`in_flight`): the reactor drains every complete line out of a read
//! into `pending`, then dispatches up to the configured pipeline depth of
//! consecutive pool requests as one job, executed sequentially by a
//! single handler. Per-connection replies stay strictly ordered — program
//! order within a batch, batch order across batches, with error replies
//! and inline answers interleaved at their arrival positions — while each
//! reactor shard pipelines across thousands of connections. The batch's
//! replies come back together and are coalesced into the write buffer in
//! one append ([`Conn::enqueue_replies`]), so a pipelining client gets
//! one write syscall per tick, not one per command.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::proto::{self, MAX_LINE, Request};
use crate::faults::{self, FaultSite};

/// Ordered per-connection work: a parsed request, or an error reply that
/// must go out in sequence with the requests around it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum Pending {
    Req(Request),
    Reply(String),
}

/// Growable byte accumulator that yields complete `\n`-terminated lines,
/// tolerating `\r\n` and enforcing [`MAX_LINE`]. Pure (no I/O), so the
/// partial-line handling is testable without sockets.
#[derive(Default)]
pub(crate) struct LineBuffer {
    buf: Vec<u8>,
    /// Mid-overlong-line: the one `Err(LineTooLong)` was already
    /// reported, and bytes are dropped until the next `\n` resyncs the
    /// stream. Memory stays bounded because the discarded prefix is never
    /// buffered.
    discarding: bool,
}

/// A line longer than [`MAX_LINE`] arrived (terminated or not).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct LineTooLong;

/// Most bytes one connection may read per reactor tick: keeps a single
/// fire-hosing client from starving the sweep, and bounds how far past
/// the queue caps one tick can overshoot.
const READ_BUDGET: usize = 16 * 1024;

/// Stop reading a connection once this many parsed-but-unserved entries
/// queue up (a pipelining client that never reads its replies); TCP
/// backpressure then pushes back on the sender. Reads resume as dispatch
/// drains the queue.
const PENDING_CAP: usize = 1024;

/// Stop reading a connection once this many reply bytes sit unflushed —
/// the client is not draining its side, so stop growing ours.
const OUTBUF_CAP: usize = 64 * 1024;

impl LineBuffer {
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete line (without its terminator, `\r` stripped), or
    /// `Err(LineTooLong)` when a line's *content* exceeds [`MAX_LINE`] —
    /// whether its terminator already arrived (an over-long line is
    /// rejected, not served) or not (an unterminated prefix must not
    /// buffer without bound). Terminator bytes (`\n` and a preceding
    /// `\r`) never count against the cap, so LF and CRLF clients get the
    /// same limit; the unterminated check leaves one byte of slack for a
    /// `\r` whose `\n` is still in flight.
    ///
    /// Each overlong line yields exactly one `Err`; the buffer then
    /// resyncs at the next `\n` and later lines parse normally, so the
    /// caller can answer the error and keep the connection.
    pub fn next_line(&mut self) -> Option<Result<String, LineTooLong>> {
        if self.discarding {
            match self.buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    self.buf.drain(..=i);
                    self.discarding = false;
                }
                None => {
                    self.buf.clear();
                    return None;
                }
            }
        }
        match self.buf.iter().position(|&b| b == b'\n') {
            Some(i) => {
                let content = i - usize::from(i > 0 && self.buf[i - 1] == b'\r');
                if content > MAX_LINE {
                    self.buf.drain(..=i);
                    return Some(Err(LineTooLong));
                }
                let mut line: Vec<u8> = self.buf.drain(..=i).collect();
                line.pop(); // the \n
                if line.last() == Some(&b'\r') {
                    line.pop();
                }
                Some(Ok(String::from_utf8_lossy(&line).into_owned()))
            }
            None if self.buf.len() > MAX_LINE + 1 => {
                self.buf.clear();
                self.discarding = true;
                Some(Err(LineTooLong))
            }
            None => None,
        }
    }
}

/// The one batch a connection currently has in the handler pool:
/// identified so replies that arrive after the deadline fired can be
/// recognized as stale and dropped, timestamped so the reactor's deadline
/// sweep knows when to give up on it, and sized so that sweep can answer
/// `ERR TIMEOUT` once per batched command (and the queue gauge can move
/// by the batch length).
#[derive(Clone, Copy, Debug)]
pub(crate) struct InFlight {
    pub id: u64,
    pub since: Instant,
    /// Commands in the batch (>= 1).
    pub len: usize,
}

/// One client connection, owned by the reactor.
pub(crate) struct Conn {
    stream: TcpStream,
    lines: LineBuffer,
    outbuf: Vec<u8>,
    /// Bytes of `outbuf` already written (partial-write cursor).
    written: usize,
    pub pending: VecDeque<Pending>,
    /// The request from this connection in the handler pool, if any.
    pub in_flight: Option<InFlight>,
    /// Serve what is queued, flush, then close (QUIT / EOF / protocol
    /// violation). No further input is read.
    pub closing: bool,
    /// Hard failure: drop the connection without flushing.
    pub dead: bool,
    /// Last *protocol* progress — a complete line parsed or a reply
    /// enqueued. Raw bytes do not count, so a slowloris client trickling
    /// a never-ending line still looks idle and gets reaped.
    pub last_activity: Instant,
}

impl Conn {
    pub fn new(stream: TcpStream) -> std::io::Result<Self> {
        stream.set_nonblocking(true)?;
        Ok(Self {
            stream,
            lines: LineBuffer::default(),
            outbuf: Vec::new(),
            written: 0,
            pending: VecDeque::new(),
            in_flight: None,
            closing: false,
            dead: false,
            last_activity: Instant::now(),
        })
    }

    /// Drain readable bytes (bounded by [`READ_BUDGET`]) and parse
    /// complete lines into `pending`. Backpressure: a connection whose
    /// pending queue or write buffer is over its cap is not read at all —
    /// the kernel socket buffer fills and TCP pushes back on the client —
    /// so per-connection memory stays bounded no matter how hard a client
    /// pipelines without reading. Returns whether any progress was made
    /// (the reactor's idle signal).
    pub fn pump_read(&mut self) -> bool {
        if self.closing
            || self.dead
            || self.pending.len() >= PENDING_CAP
            || self.outbuf.len() - self.written >= OUTBUF_CAP
        {
            return false;
        }
        let mut progress = false;
        let mut budget = READ_BUDGET;
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                // EOF: the client is done sending; serve what is
                // buffered, flush, then close.
                Ok(0) => {
                    self.closing = true;
                    break;
                }
                Ok(n) => {
                    self.lines.push(&chunk[..n]);
                    progress = true;
                    budget = budget.saturating_sub(n);
                    if budget == 0 {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return progress;
                }
            }
        }
        while let Some(line) = self.lines.next_line() {
            match line {
                Ok(text) => {
                    self.last_activity = Instant::now();
                    match proto::parse(&text) {
                        Ok(req) => self.pending.push_back(Pending::Req(req)),
                        Err(reply) => self.pending.push_back(Pending::Reply(reply)),
                    }
                }
                // One in-sequence error per overlong line; the LineBuffer
                // resyncs at the next newline, so the session survives.
                Err(LineTooLong) => {
                    self.pending.push_back(Pending::Reply(proto::TOOLONG_REPLY.into()));
                }
            }
        }
        progress
    }

    /// Queue one reply line for writing.
    pub fn enqueue_reply(&mut self, reply: &str) {
        self.last_activity = Instant::now();
        self.outbuf.extend_from_slice(reply.as_bytes());
        self.outbuf.push(b'\n');
    }

    /// Queue a completed batch's replies in one coalesced append, so the
    /// whole batch flushes as a single write when the socket takes it.
    pub fn enqueue_replies(&mut self, replies: &[String]) {
        self.last_activity = Instant::now();
        for reply in replies {
            self.outbuf.extend_from_slice(reply.as_bytes());
            self.outbuf.push(b'\n');
        }
    }

    /// Write as much of the out-buffer as the socket accepts. Returns
    /// whether any bytes moved.
    pub fn pump_write(&mut self) -> bool {
        if self.dead {
            return false;
        }
        let mut progress = false;
        while self.written < self.outbuf.len() {
            // Fault plane: cap each write syscall (short/partial writes),
            // exercising the partial-write cursor below — `ConnWrite`
            // shortens any write, `ReplyCoalesce` specifically splits a
            // coalesced reply batch across reply boundaries.
            let remaining = self.outbuf.len() - self.written;
            let cap = faults::write_cap(remaining)
                .min(faults::write_cap_at(FaultSite::ReplyCoalesce, remaining));
            match self.stream.write(&self.outbuf[self.written..self.written + cap]) {
                Ok(0) => {
                    self.dead = true;
                    return progress;
                }
                Ok(n) => {
                    self.written += n;
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    return progress;
                }
            }
        }
        if self.written > 0 && self.written == self.outbuf.len() {
            self.outbuf.clear();
            self.written = 0;
        }
        progress
    }

    /// Whether the reactor should drop this connection now: dead, or
    /// cleanly finished (closing, nothing queued, nothing in flight,
    /// everything flushed).
    pub fn should_close(&self) -> bool {
        self.dead
            || (self.closing
                && self.in_flight.is_none()
                && self.pending.is_empty()
                && self.written == self.outbuf.len())
    }

    /// Whether this connection is quiescent (nothing queued, in flight,
    /// or unflushed) and has made no protocol progress for `limit` — the
    /// reap condition for `--conn-idle-ms`. A connection waiting on its
    /// own slow request is *not* idle; the deadline sweep owns that case.
    pub fn idle_expired(&self, now: Instant, limit: Duration) -> bool {
        self.in_flight.is_none()
            && self.pending.is_empty()
            && self.written == self.outbuf.len()
            && now.duration_since(self.last_activity) >= limit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_buffer_splits_batched_lines() {
        let mut lb = LineBuffer::default();
        lb.push(b"PUT 1\nDEL 2\r\nHAS 3\n");
        assert_eq!(lb.next_line(), Some(Ok("PUT 1".into())));
        assert_eq!(lb.next_line(), Some(Ok("DEL 2".into())));
        assert_eq!(lb.next_line(), Some(Ok("HAS 3".into())));
        assert_eq!(lb.next_line(), None);
    }

    #[test]
    fn line_buffer_reassembles_trickled_bytes() {
        let mut lb = LineBuffer::default();
        lb.push(b"PU");
        assert_eq!(lb.next_line(), None);
        lb.push(b"T 4");
        assert_eq!(lb.next_line(), None);
        lb.push(b"2\nHA");
        assert_eq!(lb.next_line(), Some(Ok("PUT 42".into())));
        assert_eq!(lb.next_line(), None);
        lb.push(b"S 1\n");
        assert_eq!(lb.next_line(), Some(Ok("HAS 1".into())));
    }

    #[test]
    fn line_buffer_rejects_unbounded_lines() {
        // One byte of slack beyond MAX_LINE is reserved for a CRLF's \r
        // whose \n has not arrived; past that, reject.
        let mut lb = LineBuffer::default();
        lb.push(&[b'x'; MAX_LINE + 1]);
        assert_eq!(
            lb.next_line(),
            None,
            "could still be a max-length CRLF line"
        );
        lb.push(b"x");
        assert_eq!(lb.next_line(), Some(Err(LineTooLong)));
        // Exactly one error per overlong line: the tail of the same line
        // keeps draining silently until its newline resyncs the stream.
        lb.push(&[b'x'; 3 * MAX_LINE]);
        assert_eq!(lb.next_line(), None);
        lb.push(b"x\nHAS 9\n");
        assert_eq!(lb.next_line(), Some(Ok("HAS 9".into())));
        assert_eq!(lb.next_line(), None);
    }

    #[test]
    fn line_buffer_resyncs_after_overlong_terminated_line() {
        // A complete overlong line costs one Err; the next line parses.
        let mut lb = LineBuffer::default();
        let mut burst = vec![b'z'; MAX_LINE + 10];
        burst.extend_from_slice(b"\nPUT 7\n");
        lb.push(&burst);
        assert_eq!(lb.next_line(), Some(Err(LineTooLong)));
        assert_eq!(lb.next_line(), Some(Ok("PUT 7".into())));
        assert_eq!(lb.next_line(), None);
    }

    #[test]
    fn line_buffer_one_byte_writes_match_batched() {
        // Satellite: a client trickling one byte per write must see the
        // exact same line/error sequence as one sending a single burst.
        crate::proptest_lite::run("line_buffer_one_byte_writes", |rng| {
            let mut bytes = Vec::new();
            for _ in 0..rng.gen_range_incl(1, 8) {
                let len = match rng.gen_range(4) {
                    0 => rng.gen_range_incl(0, 8) as usize,
                    1 => MAX_LINE - 1 + rng.gen_range(3) as usize, // straddle the cap
                    _ => rng.gen_range_incl(1, 2 * MAX_LINE as u64) as usize,
                };
                for _ in 0..len {
                    bytes.push(b'a' + (rng.gen_range(26) as u8));
                }
                if rng.gen_range(4) == 0 {
                    bytes.push(b'\r');
                }
                bytes.push(b'\n');
            }

            let mut batched = LineBuffer::default();
            batched.push(&bytes);
            let mut want = Vec::new();
            while let Some(r) = batched.next_line() {
                want.push(r);
            }

            let mut trickled = LineBuffer::default();
            let mut got = Vec::new();
            for b in &bytes {
                trickled.push(std::slice::from_ref(b));
                while let Some(r) = trickled.next_line() {
                    got.push(r);
                }
            }
            prop_assert!(
                got == want,
                "1-byte writes diverged: got {got:?}, want {want:?} over {} bytes",
                bytes.len()
            );
            Ok(())
        });
    }

    #[test]
    fn line_buffer_one_byte_writes_resync_after_overlong() {
        // Deterministic companion to the property: overlong line fed one
        // byte at a time yields exactly one Err, then resyncs.
        let mut lb = LineBuffer::default();
        let mut errs = 0;
        let mut lines = Vec::new();
        let mut stream = vec![b'q'; MAX_LINE + 50];
        stream.extend_from_slice(b"\nSIZE\n");
        for b in &stream {
            lb.push(std::slice::from_ref(b));
            while let Some(r) = lb.next_line() {
                match r {
                    Ok(l) => lines.push(l),
                    Err(LineTooLong) => errs += 1,
                }
            }
        }
        assert_eq!(errs, 1);
        assert_eq!(lines, vec!["SIZE".to_string()]);
    }

    #[test]
    fn line_buffer_accepts_exactly_max_line_terminated() {
        // LF and CRLF clients get the same content limit: terminator
        // bytes never count against MAX_LINE.
        for terminator in [b"\n".as_slice(), b"\r\n".as_slice()] {
            let mut lb = LineBuffer::default();
            let mut long = vec![b'y'; MAX_LINE];
            long.extend_from_slice(terminator);
            lb.push(&long);
            assert_eq!(lb.next_line(), Some(Ok("y".repeat(MAX_LINE))));
        }
    }

    #[test]
    fn line_buffer_rejects_overlong_even_when_terminated() {
        // The cap must hold when the whole line (terminator included)
        // arrives in one burst, not just for slow-trickling clients.
        let mut lb = LineBuffer::default();
        let mut long = vec![b'z'; MAX_LINE + 1];
        long.push(b'\n');
        lb.push(&long);
        assert_eq!(lb.next_line(), Some(Err(LineTooLong)));
    }

    #[test]
    fn empty_lines_are_lines() {
        let mut lb = LineBuffer::default();
        lb.push(b"\n\n");
        assert_eq!(lb.next_line(), Some(Ok(String::new())));
        assert_eq!(lb.next_line(), Some(Ok(String::new())));
        assert_eq!(lb.next_line(), None);
    }
}
