//! The async server subsystem: a std-only nonblocking TCP front-end over
//! any [`ConcurrentSet`], with size-driven admission control.
//!
//! This is the paper's motivating scenario made load-bearing: the
//! introduction argues a reliable concurrent size exists *for* real
//! systems — monitoring and admission control — and this module wires the
//! crate's whole size stack into exactly those paths:
//!
//! * the **reactor shards** ([`reactor`]) — `--reactors N` threads, each
//!   multiplexing its own connection table over nonblocking sockets with
//!   per-connection read/write buffers and partial-line state machines
//!   ([`conn`]), fed by one **acceptor** thread ([`acceptor`]) that
//!   distributes sockets round-robin with a least-loaded tiebreak. Each
//!   shard pipelines: every complete command in a read buffer is parsed,
//!   and consecutive pool requests dispatch as one batch a single
//!   handler runs in order, with the batch's replies coalesced into one
//!   write. The shards hold thousands of connections open while a small
//!   shared **handler pool** — never more than
//!   [`crate::thread_id::capacity`]`/2` threads — executes the store
//!   operations;
//! * **admission control** ([`admission`]) — every incoming `PUT`
//!   consults `ConcurrentSet::size_estimate` (the O(shards) bounded-lag
//!   probe of [`crate::size::ShardedCounters`]) against high/low
//!   watermarks with hysteresis, shedding with `ERR OVERLOAD` while the
//!   store drains;
//! * the **protocol** ([`proto`]) — `PUT k [v]`/`DEL`/`HAS`/`GET`/
//!   `SCAN lo hi`/`COUNT lo hi`/`SIZE`/`SIZE~`/`SIZE?`/`STATS`/`QUIT`,
//!   where `SCAN` serves the store's double-collect-validated range scan
//!   as one multi-line reply and `STATS` exposes the server gauges
//!   (live/peak connections, reactor queue depth, shed count, admission
//!   state) merged with [`crate::size::ArbiterStats`].
//!
//! `examples/kv_server.rs` is a thin CLI shim over [`Server::bind`];
//! `rust/tests/server.rs` drives hundreds of concurrent connections and
//! the overload path; `make server-smoke` boots it in CI on every push.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cli::Args;
use crate::faults::{self, FaultSite};
use crate::set_api::ConcurrentSet;
use crate::thread_id;

mod acceptor;
mod admission;
mod conn;
mod monitor;
pub mod proto;
mod reactor;
mod readiness;

pub use admission::{Admission, Watermarks};
pub use proto::{DEFAULT_RECENT_MS, OVERLOAD_REPLY, parse_stats, Request};

use acceptor::{Acceptor, AcceptorConfig};
use monitor::ServerMonitor;
use reactor::{Completion, Job, Reactor, ReactorConfig};

/// Where the in-server monitor drops minimized violation repros.
const ARTIFACT_DIR: &str = "artifacts";

/// What the reactor does when a full tick makes no progress.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IdleStrategy {
    /// Nap briefly (default): ~0 CPU when idle, sub-millisecond wakeup.
    Sleep(Duration),
    /// Busy-spin with `yield_now`: lowest latency, burns a core.
    Spin,
}

/// Default idle nap: short enough that a sequential request/response
/// client sees sub-100µs added latency, long enough that an idle server
/// is invisible in `top`.
pub const IDLE_NAP: Duration = Duration::from_micros(50);

impl IdleStrategy {
    /// Parse the `--reactor` CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sleep" => Some(IdleStrategy::Sleep(IDLE_NAP)),
            "spin" => Some(IdleStrategy::Spin),
            _ => None,
        }
    }
}

/// Server construction knobs (all CLI-reachable through
/// [`ServerConfig::from_args`]).
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Handler pool size; clamped at bind time to half the thread-slot
    /// capacity so handlers (plus the reactor, main thread, refresher and
    /// test clients) always fit the per-thread size metadata.
    pub handlers: usize,
    /// Live-connection ceiling; beyond it new clients get `ERR server
    /// full` and are dropped instead of exhausting fds.
    pub max_conns: usize,
    /// Reactor shards (`--reactors auto|N`, default 1): the acceptor
    /// distributes sockets across this many per-shard connection tables,
    /// each swept by its own thread. 1 reproduces the single-reactor
    /// behavior exactly.
    pub reactors: usize,
    /// Most commands batched into one handler-pool job per connection
    /// dispatch (`--pipeline-depth N`, default 32, min 1): how much of a
    /// pipelining client's read buffer one pool round trip serves.
    pub pipeline_depth: usize,
    /// Global admission watermarks on the store-wide size estimate;
    /// `None` admits everything.
    pub admission: Option<Watermarks>,
    /// Per-shard admission watermarks (the second tier): one gate per
    /// store shard, each fed that shard's `shard_estimate`, shedding
    /// only the hot shard's `PUT`s with `ERR OVERLOAD shard=<i>`.
    /// `None` (default) disables the tier; on a monolithic store it
    /// degenerates to one gate over the whole estimate.
    pub shard_admission: Option<Watermarks>,
    /// Reactor idle behavior.
    pub idle: IdleStrategy,
    /// Per-request handler deadline (`--request-timeout-ms`, 0 = off):
    /// past it the client gets `ERR TIMEOUT` and the connection's pool
    /// slot back; the handler's eventual stale reply is dropped.
    pub request_timeout: Option<Duration>,
    /// Idle-connection reaping (`--conn-idle-ms`, 0/absent = off): a
    /// connection with no *protocol* progress for this long is dropped —
    /// bytes that never complete a line (slowloris) do not count as
    /// progress.
    pub conn_idle: Option<Duration>,
    /// Sampled linearizability monitoring (`--monitor-sample N`, 0 =
    /// off): every N pool requests, record one full window of timestamped
    /// events against a `size_exact` anchor and check it; violations show
    /// in `STATS` and dump minimized repros under `artifacts/`.
    pub monitor_sample: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            handlers: 16,
            max_conns: 4096,
            reactors: 1,
            pipeline_depth: 32,
            admission: None,
            shard_admission: None,
            idle: IdleStrategy::Sleep(IDLE_NAP),
            request_timeout: Some(Duration::from_secs(30)),
            conn_idle: None,
            monitor_sample: 0,
        }
    }
}

impl ServerConfig {
    /// Build from CLI flags: `--workers N`, `--max-conns N`,
    /// `--reactors auto|N` (the `auto|N` shard grammar; clamped to >= 1),
    /// `--pipeline-depth N` (clamped to >= 1),
    /// `--admission-high N [--admission-low N]` (low defaults to half of
    /// high; low alone is an error),
    /// `--shard-admission-high N [--shard-admission-low N]` (same
    /// convention, applied per store shard), `--reactor sleep|spin`,
    /// `--request-timeout-ms N` (0 disables), `--conn-idle-ms N`
    /// (0 disables), `--monitor-sample N` (0 disables). `Err` carries the
    /// usage message.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let defaults = Self::default();
        let admission = Self::watermarks_from(args, "admission-high", "admission-low")?;
        let shard_admission =
            Self::watermarks_from(args, "shard-admission-high", "shard-admission-low")?;
        let idle = match args.get("reactor") {
            None => defaults.idle,
            Some(s) => IdleStrategy::parse(s)
                .ok_or_else(|| format!("--reactor expects sleep|spin, got {s:?}"))?,
        };
        let millis_knob = |name: &str, default: Option<Duration>| match args.get_opt_u64(name) {
            None => default,
            Some(0) => None,
            Some(ms) => Some(Duration::from_millis(ms)),
        };
        Ok(Self {
            handlers: args.get_usize("workers", defaults.handlers),
            max_conns: args.get_usize("max-conns", defaults.max_conns),
            reactors: args.reactors(defaults.reactors).max(1),
            pipeline_depth: args
                .get_usize("pipeline-depth", defaults.pipeline_depth)
                .max(1),
            admission,
            shard_admission,
            idle,
            request_timeout: millis_knob("request-timeout-ms", defaults.request_timeout),
            conn_idle: millis_knob("conn-idle-ms", defaults.conn_idle),
            monitor_sample: args.get_opt_u64("monitor-sample").unwrap_or(defaults.monitor_sample),
        })
    }

    /// Parse one `--<high> N [--<low> N]` watermark pair: low defaults to
    /// half of high, low alone is an error — the shared convention for
    /// both admission tiers.
    fn watermarks_from(
        args: &Args,
        high_flag: &str,
        low_flag: &str,
    ) -> Result<Option<Watermarks>, String> {
        let high = args.get_opt_u64(high_flag);
        let low = args.get_opt_u64(low_flag);
        match (high, low) {
            (None, None) => Ok(None),
            (None, Some(_)) => Err(format!("--{low_flag} needs --{high_flag}")),
            (Some(high), low) => {
                let high = i64::try_from(high).map_err(|_| format!("--{high_flag} too large"))?;
                let low = match low {
                    Some(low) => {
                        i64::try_from(low).map_err(|_| format!("--{low_flag} too large"))?
                    }
                    None => high / 2,
                };
                if low > high {
                    return Err(format!(
                        "--{low_flag} {low} must not exceed --{high_flag} {high}"
                    ));
                }
                Ok(Some(Watermarks::new(high, low)))
            }
        }
    }
}

/// Point-in-time server telemetry (the `STATS` endpoint renders this plus
/// the store's size stats; [`Server::stats`] returns it in-process).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub live_conns: usize,
    /// High-water mark of simultaneously live connections.
    pub peak_conns: usize,
    /// Commands dispatched to the handler pool and not yet completed,
    /// summed over reactor shards.
    pub queue_depth: usize,
    pub handlers: usize,
    /// Reactor shards serving connections.
    pub reactors: usize,
    /// Connections accepted over the server's lifetime.
    pub accepted: u64,
    /// `PUT`s shed by the global admission tier.
    pub shed: u64,
    /// `false` while the global admission tier is shedding.
    pub admitting: bool,
    /// Store shards behind this server (1 for a monolithic store).
    pub store_shards: usize,
    /// `PUT`s shed by the per-shard admission tier, summed over shards.
    pub shard_shed: u64,
    /// Fault-plane injections fired so far, summed over all sites (0
    /// unless the `faults` feature is compiled and a plane is armed).
    pub fault_fires: u64,
    /// Requests answered `ERR TIMEOUT` by the deadline sweep.
    pub timeouts: u64,
    /// Handler panics contained (`ERR PANIC`) or survived by respawn.
    pub panics: u64,
    /// Idle/slowloris connections reaped.
    pub reaped: u64,
    /// Unjustified size observations caught by the sampled monitor.
    pub monitor_violations: u64,
}

/// One reactor shard's telemetry slice. Each shard writes only its own
/// slice (the acceptor also writes `handoff`), so the hot paths never
/// contend on a shared gauge; [`Shared::snapshot`] merges the slices
/// with the [`crate::size::ArbiterStats::merge`] convention — counters
/// add, gauges keep the maximum.
#[derive(Default)]
pub(crate) struct ReactorGauges {
    /// Connections in this shard's table.
    pub live: AtomicUsize,
    /// High-water mark of this shard's table.
    pub peak: AtomicUsize,
    /// Commands this shard dispatched to the pool, not yet completed.
    pub queue: AtomicUsize,
    /// Sockets the acceptor handed to this shard, not yet adopted.
    pub handoff: AtomicUsize,
    /// Commands answered `ERR TIMEOUT` by this shard's deadline sweep.
    pub timeouts: AtomicU64,
    /// Idle/slowloris connections reaped by this shard.
    pub reaped: AtomicU64,
}

/// State shared between the acceptor, the reactor shards, the handler
/// pool, and the [`Server`] handle.
pub(crate) struct Shared {
    pub stop: AtomicBool,
    /// One telemetry slice per reactor shard, index-aligned with the
    /// handoff channels.
    pub gauges: Box<[ReactorGauges]>,
    /// Cluster-wide high-water of simultaneously live connections,
    /// maintained by the acceptor (the single point every connection
    /// enters through). The per-shard `peak` gauges cannot reconstruct
    /// this — shards peak at different times, so their max under-reports
    /// and their sum over-reports; see `Acceptor::accept_ready`.
    pub peak_total: AtomicUsize,
    pub accepted: AtomicU64,
    pub panics: AtomicU64,
    pub admission: Option<Admission>,
    /// Per-shard admission gates (second tier); empty when disabled.
    /// `shard_gates[i]` guards `PUT`s routed to store shard `i`.
    pub shard_gates: Box<[Admission]>,
    /// `store.store_shards()` cached at bind time for `STATS`.
    pub store_shards: usize,
    pub monitor: Option<Arc<ServerMonitor>>,
}

impl Shared {
    fn new(
        reactors: usize,
        admission: Option<Watermarks>,
        shard_admission: Option<Watermarks>,
        store_shards: usize,
        monitor: Option<Arc<ServerMonitor>>,
    ) -> Self {
        let shard_gates = match shard_admission {
            Some(marks) => (0..store_shards).map(|_| Admission::new(marks)).collect(),
            None => Box::default(),
        };
        Self {
            stop: AtomicBool::new(false),
            gauges: (0..reactors.max(1)).map(|_| ReactorGauges::default()).collect(),
            peak_total: AtomicUsize::new(0),
            accepted: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            admission: admission.map(Admission::new),
            shard_gates,
            store_shards,
            monitor,
        }
    }

    /// Connections currently owned by the server: adopted into a shard's
    /// table, or in flight between accept and adoption. The acceptor's
    /// `max_conns` ceiling and the merged `conns=` gauge both read this.
    pub(crate) fn total_conns(&self) -> usize {
        self.gauges
            .iter()
            .map(|g| g.live.load(SeqCst) + g.handoff.load(SeqCst))
            .sum()
    }

    /// Merge the per-reactor slices into one [`ServerStats`], following
    /// the [`crate::size::ArbiterStats::merge`] convention: counters
    /// (`accepted`, `timeouts`, `reaped`, ...) add; gauges keep the
    /// maximum. `live` and `queue` are gauges over *disjoint* connection
    /// sets, so their sum is the true cluster value; `peak` merges by max
    /// against the acceptor's cluster-wide high-water, because summing
    /// per-shard peaks taken at different instants would fabricate a
    /// moment that never existed.
    pub(crate) fn snapshot(&self, handlers: usize) -> ServerStats {
        let mut queue = 0;
        let mut peak = self.peak_total.load(SeqCst);
        let (mut timeouts, mut reaped) = (0u64, 0u64);
        for g in self.gauges.iter() {
            queue += g.queue.load(SeqCst);
            peak = peak.max(g.peak.load(SeqCst));
            timeouts += g.timeouts.load(SeqCst);
            reaped += g.reaped.load(SeqCst);
        }
        ServerStats {
            live_conns: self.total_conns(),
            peak_conns: peak,
            queue_depth: queue,
            handlers,
            reactors: self.gauges.len(),
            accepted: self.accepted.load(SeqCst),
            shed: self.admission.as_ref().map_or(0, Admission::shed_count),
            admitting: self.admission.as_ref().is_none_or(|a| !a.shedding()),
            store_shards: self.store_shards,
            shard_shed: self.shard_gates.iter().map(Admission::shed_count).sum(),
            fault_fires: faults::fire_counts().iter().sum(),
            timeouts,
            panics: self.panics.load(SeqCst),
            reaped,
            monitor_violations: self.monitor.as_ref().map_or(0, |m| m.violations()),
        }
    }
}

/// A running server: the acceptor thread, its reactor shards, and the
/// shared handler pool. Dropping the handle stops them all and joins
/// every thread (shutdown is synchronous, like the size refresher's).
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    handlers: usize,
    acceptor: Option<JoinHandle<()>>,
    reactors: Vec<JoinHandle<()>>,
    pool: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port — [`Self::local_addr`]
    /// reports the real one) and start serving `store` under `config`.
    pub fn bind(
        addr: &str,
        store: Arc<dyn ConcurrentSet>,
        config: ServerConfig,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let handlers = config.handlers.clamp(1, thread_id::capacity() / 2);
        let reactors = config.reactors.max(1);
        let monitor = (config.monitor_sample > 0).then(|| {
            Arc::new(ServerMonitor::new(config.monitor_sample, handlers as i64, ARTIFACT_DIR))
        });
        let shared = Arc::new(Shared::new(
            reactors,
            config.admission,
            config.shard_admission,
            store.store_shards(),
            monitor,
        ));

        // One shared job lane in (any handler serves any shard), one
        // completion lane back *per shard* (replies return to the shard
        // that owns the connection).
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (done_txs, done_rxs): (Vec<Sender<Completion>>, Vec<Receiver<Completion>>) =
            (0..reactors).map(|_| channel::<Completion>()).unzip();
        let pool: Vec<JoinHandle<()>> = (0..handlers)
            .map(|i| {
                let ctx = HandlerCtx {
                    index: i,
                    store: store.clone(),
                    jobs: job_rx.clone(),
                    done: done_txs.clone().into(),
                    shared: shared.clone(),
                };
                spawn_handler(ctx).expect("spawn kv handler")
            })
            .collect();
        // The shards' receivers must see disconnect once the pool exits.
        drop(done_txs);

        let mut handoff_txs = Vec::with_capacity(reactors);
        let mut reactor_handles = Vec::with_capacity(reactors);
        for (index, done_rx) in done_rxs.into_iter().enumerate() {
            let (handoff_tx, handoff_rx) = channel::<TcpStream>();
            handoff_txs.push(handoff_tx);
            let shard = Reactor::new(
                handoff_rx,
                store.clone(),
                shared.clone(),
                job_tx.clone(),
                done_rx,
                ReactorConfig {
                    index,
                    idle: config.idle,
                    handlers,
                    pipeline_depth: config.pipeline_depth.max(1),
                    request_timeout: config.request_timeout,
                    conn_idle: config.conn_idle,
                },
            );
            let handle = std::thread::Builder::new()
                .name(format!("kv-reactor-{index}"))
                .spawn(move || shard.run())
                .expect("spawn kv reactor shard");
            reactor_handles.push(handle);
        }
        // The pool's job receiver must see disconnect once every shard
        // (each holding a sender clone) exits.
        drop(job_tx);

        let acceptor = Acceptor::new(
            listener,
            handoff_txs,
            shared.clone(),
            AcceptorConfig {
                idle: config.idle,
                max_conns: config.max_conns,
            },
        );
        let acceptor = std::thread::Builder::new()
            .name("kv-acceptor".into())
            .spawn(move || acceptor.run())
            .expect("spawn kv acceptor");

        Ok(Self {
            shared,
            addr,
            handlers,
            acceptor: Some(acceptor),
            reactors: reactor_handles,
            pool,
        })
    }

    /// The bound address (the real port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Handler pool size after clamping — by construction at most
    /// [`thread_id::capacity`]`/2`, no matter how many connections are
    /// live.
    pub fn handler_threads(&self) -> usize {
        self.handlers
    }

    /// Number of reactor shards serving connections.
    pub fn reactor_count(&self) -> usize {
        self.shared.gauges.len()
    }

    /// Per-shard live-connection counts (acceptor-distribution
    /// observability; index-aligned with the shards).
    pub fn reactor_loads(&self) -> Vec<usize> {
        self.shared.gauges.iter().map(|g| g.live.load(SeqCst)).collect()
    }

    /// Current server telemetry (same numbers the `STATS` endpoint serves).
    pub fn stats(&self) -> ServerStats {
        self.shared.snapshot(self.handlers)
    }

    /// Block the calling thread on the acceptor (serve-forever mode; it
    /// only exits when another handle to the process raises stop or the
    /// process dies). Threads are joined on drop afterwards.
    pub fn wait(mut self) {
        if let Some(acceptor) = self.acceptor.take() {
            let _ = acceptor.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shared.stop.store(true, SeqCst);
        if let Some(acceptor) = self.acceptor.take() {
            // Joining the acceptor drops the handoff senders.
            let _ = acceptor.join();
        }
        for handle in self.reactors.drain(..) {
            // Each shard drops its job-sender clone on exit; the last
            // one to go drains the handler pool.
            let _ = handle.join();
        }
        for handle in self.pool.drain(..) {
            let _ = handle.join();
        }
    }
}

/// A minimal blocking protocol client: one connection, one line in or
/// out at a time. This is the **test/driver** client shared by the
/// kv_server self-test and the integration suite (and handy for poking a
/// live server from code); every method panics with a pointed message on
/// I/O errors — a broken pipe mid-test IS the failure. The wide-load,
/// error-counting path is [`crate::harness::client_swarm`].
pub struct BlockingClient {
    out: TcpStream,
    reader: BufReader<TcpStream>,
    line: String,
}

impl BlockingClient {
    /// Connect with a 30-second read timeout, so a wedged server fails a
    /// test loudly instead of hanging it.
    pub fn connect(addr: SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("client connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("client read timeout");
        Self {
            out: stream.try_clone().expect("client stream clone"),
            reader: BufReader::new(stream),
            line: String::new(),
        }
    }

    /// Send one command line without waiting for its reply (pipelining).
    pub fn send(&mut self, cmd: impl AsRef<str>) {
        writeln!(self.out, "{}", cmd.as_ref()).expect("client write");
    }

    /// Read the next reply line; `None` when the server closed cleanly.
    pub fn recv(&mut self) -> Option<String> {
        self.line.clear();
        let n = self.reader.read_line(&mut self.line).expect("client read");
        (n > 0).then(|| self.line.trim().to_string())
    }

    /// One command round trip; panics if the server closed instead.
    pub fn cmd(&mut self, cmd: impl AsRef<str>) -> String {
        self.send(cmd);
        self.recv().expect("server closed mid-command")
    }

    /// Read one complete `SCAN` reply: lines up to and including the
    /// `END n` terminator, parsed into pairs. `Err` carries the server's
    /// error reply (e.g. `ERR scan unsupported ...`) when the first line
    /// is not a scan body.
    pub fn recv_scan(&mut self) -> Result<Vec<(u64, u64)>, String> {
        let mut lines = Vec::new();
        loop {
            let line = self.recv().expect("server closed mid-scan");
            if lines.is_empty() && line.starts_with("ERR") {
                return Err(line);
            }
            let done = line.starts_with("END ");
            lines.push(line);
            if done {
                return proto::parse_scan_lines(&lines);
            }
        }
    }

    /// One `SCAN lo hi` round trip.
    pub fn scan(&mut self, lo: u64, hi: u64) -> Result<Vec<(u64, u64)>, String> {
        self.send(format!("SCAN {lo} {hi}"));
        self.recv_scan()
    }
}

/// Everything one pool thread needs, bundled so a panic-respawn can hand
/// the dead thread's identity to its replacement wholesale.
struct HandlerCtx {
    index: usize,
    store: Arc<dyn ConcurrentSet>,
    jobs: Arc<Mutex<Receiver<Job>>>,
    /// One completion sender per reactor shard; `Job::reactor` picks the
    /// lane so a batch's replies return to the shard that owns its
    /// connection.
    done: Box<[Sender<Completion>]>,
    shared: Arc<Shared>,
}

/// Pool replenishment: if a handler thread dies by a panic that escaped
/// the per-request `catch_unwind` (so the per-request containment never
/// saw it), spawn a replacement with the same context — the pool's
/// capacity survives any panic, not just in-request ones. Clean exits
/// (channel disconnect at shutdown) drop with `panicking() == false` and
/// respawn nothing.
struct RespawnGuard {
    ctx: Option<HandlerCtx>,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if !std::thread::panicking() {
            return;
        }
        if let Some(ctx) = self.ctx.take() {
            if !ctx.shared.stop.load(SeqCst) {
                ctx.shared.panics.fetch_add(1, SeqCst);
                // The replacement is detached; it exits on its own when
                // the job channel disconnects at shutdown.
                let _ = spawn_handler(ctx);
            }
        }
    }
}

fn spawn_handler(ctx: HandlerCtx) -> io::Result<JoinHandle<()>> {
    std::thread::Builder::new().name(format!("kv-handler-{}", ctx.index)).spawn(move || {
        let guard = RespawnGuard { ctx: Some(ctx) };
        handler_loop(guard.ctx.as_ref().expect("ctx taken only on panic"));
    })
}

/// One handler thread: dequeue a batch, execute it in program order
/// against the store (each command contained — see [`execute_contained`],
/// so one poisoned command costs one `ERR PANIC` inside the batch, not
/// the batch), send the replies back to the owning shard. Exits when the
/// job senders (the reactor shards) go away.
fn handler_loop(ctx: &HandlerCtx) {
    loop {
        // Hold the lock only to dequeue (the guard dies with the `let`),
        // not while executing the store operations.
        let job = match ctx.jobs.lock().unwrap_or_else(|p| p.into_inner()).recv() {
            Ok(job) => job,
            Err(_) => return,
        };
        let replies: Vec<String> =
            job.reqs.iter().map(|&req| execute_contained(ctx, req)).collect();
        let completion = Completion {
            token: job.token,
            req_id: job.req_id,
            replies,
        };
        if ctx.done[job.reactor].send(completion).is_err() {
            return;
        }
    }
}

/// Execute one pool request inside the self-healing jacket: fault-plane
/// hooks first (dispatch jitter, targeted stalls, poison panics), then
/// the store operation — observed by the sampled monitor when one is
/// configured — all under `catch_unwind`, so a panicking store operation
/// costs the client one `ERR PANIC` reply instead of the pool a thread.
fn execute_contained(ctx: &HandlerCtx, req: Request) -> String {
    let run = || {
        faults::jitter(FaultSite::HandlerDispatch);
        if let Request::Put(key, _) = req {
            if let Some(delay) = faults::stalled_put(key) {
                std::thread::sleep(delay);
            }
            if faults::poisoned_put(key) {
                panic!("faults: poisoned PUT {key}");
            }
        }
        match &ctx.shared.monitor {
            Some(m) => {
                m.observe(ctx.store.as_ref(), req, || proto::execute(ctx.store.as_ref(), req))
            }
            None => proto::execute(ctx.store.as_ref(), req),
        }
    };
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(run)) {
        Ok(reply) => reply,
        Err(_) => {
            ctx.shared.panics.fetch_add(1, SeqCst);
            proto::PANIC_REPLY.into()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn config_defaults() {
        let cfg = ServerConfig::from_args(&args("")).unwrap();
        assert_eq!(cfg.handlers, 16);
        assert_eq!(cfg.max_conns, 4096);
        assert_eq!(cfg.reactors, 1, "default must stay single-reactor");
        assert_eq!(cfg.pipeline_depth, 32);
        assert!(cfg.admission.is_none());
        assert_eq!(cfg.idle, IdleStrategy::Sleep(IDLE_NAP));
        assert_eq!(cfg.request_timeout, Some(Duration::from_secs(30)));
        assert_eq!(cfg.conn_idle, None);
        assert_eq!(cfg.monitor_sample, 0);
    }

    #[test]
    fn config_parses_self_healing_knobs() {
        let cfg = ServerConfig::from_args(&args(
            "--request-timeout-ms 250 --conn-idle-ms 1500 --monitor-sample 64",
        ))
        .unwrap();
        assert_eq!(cfg.request_timeout, Some(Duration::from_millis(250)));
        assert_eq!(cfg.conn_idle, Some(Duration::from_millis(1500)));
        assert_eq!(cfg.monitor_sample, 64);
        // Zero disables both time knobs.
        let cfg =
            ServerConfig::from_args(&args("--request-timeout-ms 0 --conn-idle-ms 0")).unwrap();
        assert_eq!(cfg.request_timeout, None);
        assert_eq!(cfg.conn_idle, None);
    }

    #[test]
    fn config_parses_admission_and_reactor() {
        let cfg = ServerConfig::from_args(&args(
            "--workers 4 --max-conns 128 --admission-high 100 --admission-low 40 --reactor spin",
        ))
        .unwrap();
        assert_eq!(cfg.handlers, 4);
        assert_eq!(cfg.max_conns, 128);
        assert_eq!(cfg.admission, Some(Watermarks { high: 100, low: 40 }));
        assert_eq!(cfg.idle, IdleStrategy::Spin);
    }

    #[test]
    fn config_parses_reactors_and_pipeline_depth() {
        let cfg = ServerConfig::from_args(&args("--reactors 4 --pipeline-depth 8")).unwrap();
        assert_eq!(cfg.reactors, 4);
        assert_eq!(cfg.pipeline_depth, 8);
        // `auto` maps to the machine-detected shard count (>= 1), the
        // same grammar as --size-shards/--store-shards.
        let cfg = ServerConfig::from_args(&args("--reactors auto")).unwrap();
        assert!(cfg.reactors >= 1);
        // Zero is clamped, not an error: both knobs have a working floor.
        let cfg = ServerConfig::from_args(&args("--reactors 0 --pipeline-depth 0")).unwrap();
        assert_eq!(cfg.reactors, 1);
        assert_eq!(cfg.pipeline_depth, 1);
    }

    #[test]
    fn config_low_defaults_to_half_high() {
        let cfg = ServerConfig::from_args(&args("--admission-high 100")).unwrap();
        assert_eq!(cfg.admission, Some(Watermarks { high: 100, low: 50 }));
    }

    #[test]
    fn config_parses_the_shard_admission_tier() {
        let cfg = ServerConfig::from_args(&args(
            "--admission-high 1000 --shard-admission-high 80 --shard-admission-low 20",
        ))
        .unwrap();
        assert_eq!(cfg.admission, Some(Watermarks::new(1000, 500)));
        assert_eq!(cfg.shard_admission, Some(Watermarks::new(80, 20)));
        // Low defaults to half of high, independently of the global tier.
        let cfg = ServerConfig::from_args(&args("--shard-admission-high 80")).unwrap();
        assert_eq!(cfg.admission, None);
        assert_eq!(cfg.shard_admission, Some(Watermarks::new(80, 40)));
    }

    #[test]
    fn config_rejects_bad_combinations() {
        assert!(ServerConfig::from_args(&args("--admission-low 5")).is_err());
        assert!(ServerConfig::from_args(&args("--admission-high 5 --admission-low 9")).is_err());
        assert!(ServerConfig::from_args(&args("--shard-admission-low 5")).is_err());
        assert!(ServerConfig::from_args(&args(
            "--shard-admission-high 5 --shard-admission-low 9"
        ))
        .is_err());
        assert!(ServerConfig::from_args(&args("--reactor epoll")).is_err());
    }

    #[test]
    fn idle_strategy_spellings() {
        assert_eq!(
            IdleStrategy::parse("sleep"),
            Some(IdleStrategy::Sleep(IDLE_NAP))
        );
        assert_eq!(IdleStrategy::parse("spin"), Some(IdleStrategy::Spin));
        assert_eq!(IdleStrategy::parse("poll"), None);
    }

    #[test]
    fn handler_clamp_respects_thread_capacity() {
        let store: Arc<dyn ConcurrentSet> = Arc::from(
            crate::bench_util::make_set("hashtable", crate::cli::PolicyKind::Linearizable, 64)
                .unwrap(),
        );
        let config = ServerConfig {
            handlers: 10_000,
            ..Default::default()
        };
        let server = Server::bind("127.0.0.1:0", store, config).unwrap();
        assert!(server.handler_threads() <= thread_id::capacity() / 2);
        assert!(server.local_addr().port() != 0);
    }
}
