//! In-server sampled size-linearizability monitor.
//!
//! `rust/tests/linearizability.rs` checks size justification offline, on
//! histories a test harness recorded. This module promotes that checker
//! into the live server: every `--monitor-sample N` pool requests, the
//! observing handler takes a linearizable `size_exact` **anchor** and the
//! pool starts recording a full window of timestamped update/size events.
//! When the window fills it is checked with
//! [`crate::history::monitor::check_anchored`] — the anchor supplies the
//! baseline so the server does not need the history since boot — and
//! recording switches off until the next sample point. Violations are
//! counted in the `monitor_violations` `STATS` gauge and a **minimized**
//! repro history ([`crate::history::monitor::minimize_anchored`]) is
//! dumped under `artifacts/` for offline analysis.
//!
//! Soundness: recording only starts after the anchor's response, so every
//! recorded update strictly follows it; requests already in flight in the
//! pool when the window opened may land inside it unrecorded, so the
//! check runs with a slack of the pool size (they number at most one per
//! handler). The interval bound plus slack is still a *necessary*
//! condition — the monitor never flags a legal history — and the
//! empty-set floor (`size < 0`) needs no slack at all, so the paper's
//! Figure 2 anomaly is always caught when sampled.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::SeqCst};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::history::monitor::{check_anchored, minimize_anchored, Report, SizeEvent, UpdateEvent};
use crate::set_api::ConcurrentSet;

use super::proto::Request;

/// Updates per window before it closes and is checked.
const WINDOW_UPDATES: usize = 256;
/// Size observations per window before it closes.
const WINDOW_SIZES: usize = 64;
/// Most violation dumps one server writes (repros, not a log stream).
const MAX_DUMPS: u64 = 16;

/// One recording window's growing history.
#[derive(Default)]
struct Window {
    /// The `size_exact` baseline; `None` = not recording.
    anchor: Option<SizeEvent>,
    updates: Vec<UpdateEvent>,
    sizes: Vec<SizeEvent>,
}

/// See the module docs. One per server, shared by the handler pool.
pub(crate) struct ServerMonitor {
    /// Pool requests between windows (the `--monitor-sample` knob).
    sample_every: u64,
    /// Unrecorded in-flight ops at window start: the handler pool size.
    slack: i64,
    origin: Instant,
    /// Requests until the next window opens; the decrement that hits zero
    /// elects its handler to take the anchor.
    countdown: AtomicU64,
    recording: AtomicBool,
    state: Mutex<Window>,
    violations: AtomicU64,
    windows_checked: AtomicU64,
    dump_seq: AtomicU64,
    dump_dir: PathBuf,
}

impl ServerMonitor {
    pub fn new(sample_every: u64, slack: i64, dump_dir: impl Into<PathBuf>) -> Self {
        assert!(sample_every >= 1, "monitor sample period must be >= 1");
        Self {
            sample_every,
            slack,
            origin: Instant::now(),
            countdown: AtomicU64::new(sample_every),
            recording: AtomicBool::new(false),
            state: Mutex::new(Window::default()),
            violations: AtomicU64::new(0),
            windows_checked: AtomicU64::new(0),
            dump_seq: AtomicU64::new(0),
            dump_dir: dump_dir.into(),
        }
    }

    /// Total unjustified size observations so far (the `STATS` gauge).
    pub fn violations(&self) -> u64 {
        self.violations.load(SeqCst)
    }

    /// Windows fully recorded and checked (test observability).
    pub fn windows_checked(&self) -> u64 {
        self.windows_checked.load(SeqCst)
    }

    #[inline]
    fn nanos(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Window> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Execute one pool request under observation: outside a window this
    /// is a plain `exec()` plus one atomic decrement; inside one, the
    /// request is timestamped and recorded. Called on handler threads.
    pub fn observe(
        &self,
        store: &dyn ConcurrentSet,
        req: Request,
        exec: impl FnOnce() -> String,
    ) -> String {
        self.maybe_open_window(store);
        if !self.recording.load(SeqCst) {
            return exec();
        }
        let inv = self.nanos();
        let reply = exec();
        let resp = self.nanos();
        match req {
            Request::Put(..) if reply == "1" => self.record_update(inv, resp, 1),
            Request::Del(_) if reply == "1" => self.record_update(inv, resp, -1),
            Request::Size => {
                if let Ok(value) = reply.parse::<i64>() {
                    self.record_size(inv, resp, value);
                }
            }
            Request::SizeRecent(ms) => {
                if let Ok(value) = reply.parse::<i64>() {
                    // The value may date back the full staleness bound:
                    // widen the justification window backward by it.
                    let slack = Duration::from_millis(ms).as_nanos() as u64;
                    self.record_size(inv.saturating_sub(slack), resp, value);
                }
            }
            _ => {}
        }
        reply
    }

    /// Count down toward the next sample point; the handler whose
    /// decrement hits zero takes the anchor and opens the window.
    fn maybe_open_window(&self, store: &dyn ConcurrentSet) {
        if self.recording.load(SeqCst) {
            return;
        }
        let elected = self
            .countdown
            .fetch_update(SeqCst, SeqCst, |c| c.checked_sub(1))
            .is_ok_and(|prev| prev == 1);
        if !elected {
            return;
        }
        let inv = self.nanos();
        let Some(view) = store.size_exact() else {
            // Policy without a size: nothing to monitor; re-arm and keep
            // serving (the gauge simply stays zero).
            self.countdown.store(self.sample_every, SeqCst);
            return;
        };
        let resp = self.nanos();
        {
            let mut w = self.lock();
            w.anchor = Some(SizeEvent {
                inv,
                resp,
                value: view.value,
            });
            w.updates.clear();
            w.sizes.clear();
        }
        // Recording flips on only after the anchor's response timestamp,
        // so every recorded event strictly follows it.
        self.recording.store(true, SeqCst);
    }

    fn record_update(&self, inv: u64, resp: u64, delta: i64) {
        let mut w = self.lock();
        if w.anchor.is_none() {
            return; // window closed between the flag check and the lock
        }
        w.updates.push(UpdateEvent { inv, resp, delta });
        if w.updates.len() >= WINDOW_UPDATES {
            self.close_window(&mut w);
        }
    }

    fn record_size(&self, inv: u64, resp: u64, value: i64) {
        let mut w = self.lock();
        if w.anchor.is_none() {
            return;
        }
        w.sizes.push(SizeEvent { inv, resp, value });
        if w.sizes.len() >= WINDOW_SIZES {
            self.close_window(&mut w);
        }
    }

    /// Check the filled window, count violations, dump repros, re-arm.
    fn close_window(&self, w: &mut Window) {
        let Some(anchor) = w.anchor.take() else { return };
        let report = check_anchored(&anchor, self.slack, &w.updates, &w.sizes);
        self.windows_checked.fetch_add(1, SeqCst);
        if !report.is_ok() {
            self.violations.fetch_add(report.violations.len() as u64, SeqCst);
            self.dump(&anchor, &w.updates, &report);
        }
        w.updates.clear();
        w.sizes.clear();
        self.countdown.store(self.sample_every, SeqCst);
        self.recording.store(false, SeqCst);
    }

    /// Write a minimized repro for each violation in the window. Failures
    /// are swallowed: dumping is diagnostics, never worth a served error.
    fn dump(&self, anchor: &SizeEvent, updates: &[UpdateEvent], report: &Report) {
        let seq = self.dump_seq.fetch_add(1, SeqCst);
        if seq >= MAX_DUMPS {
            return;
        }
        let mut body = String::new();
        body.push_str("# size-linearizability violation (sampled in-server monitor)\n");
        body.push_str(&format!(
            "# anchor: value={} window=[{}, {}]ns slack={}\n# updates in window: {}\n",
            anchor.value, anchor.inv, anchor.resp, self.slack, updates.len(),
        ));
        for v in &report.violations {
            body.push_str(&format!(
                "violation: value={} window=[{}, {}] justified=[{}, {}]\n",
                v.event.value, v.event.inv, v.event.resp, v.low, v.high,
            ));
            let core = minimize_anchored(anchor, self.slack, updates, &v.event);
            body.push_str(&format!("  minimized repro ({} updates):\n", core.len()));
            for u in &core {
                body.push_str(&format!(
                    "  update delta={:+} window=[{}, {}]\n",
                    u.delta, u.inv, u.resp,
                ));
            }
        }
        let path = self.dump_dir.join(format!("monitor-violation-{seq}-{}.txt", self.nanos()));
        let _ = std::fs::create_dir_all(&self.dump_dir);
        if std::fs::write(&path, body).is_ok() {
            eprintln!(
                "server monitor: violation repro dumped to {}",
                path.display()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_util::make_set;
    use crate::cli::PolicyKind;

    fn store() -> Box<dyn ConcurrentSet> {
        make_set("hashtable", PolicyKind::Linearizable, 1024).unwrap()
    }

    #[test]
    fn honest_store_records_clean_windows() {
        let store = store();
        let m = ServerMonitor::new(1, 0, std::env::temp_dir());
        let mut key = 0u64;
        // Enough updates to fill and close at least one window.
        for _ in 0..(2 * WINDOW_UPDATES + 8) {
            key += 1;
            let req = Request::Put(key, 0);
            let reply = m.observe(store.as_ref(), req, || {
                crate::server::proto::execute(store.as_ref(), req)
            });
            assert_eq!(reply, "1");
            let reply = m.observe(store.as_ref(), Request::Size, || {
                crate::server::proto::execute(store.as_ref(), Request::Size)
            });
            assert_eq!(reply, key.to_string());
        }
        assert!(m.windows_checked() >= 1, "no window ever closed");
        assert_eq!(m.violations(), 0);
    }

    #[test]
    fn fabricated_sizes_are_flagged_and_dumped() {
        let store = store();
        let dir = std::env::temp_dir().join(format!("csize-monitor-{}", std::process::id()));
        let m = ServerMonitor::new(1, 0, &dir);
        // The store is empty (anchor 0, no updates recorded), so a size
        // reply of 999 is unjustifiable no matter the interleaving.
        for _ in 0..WINDOW_SIZES {
            m.observe(store.as_ref(), Request::Size, || "999".to_string());
        }
        assert_eq!(m.windows_checked(), 1);
        assert_eq!(m.violations(), WINDOW_SIZES as u64);
        let dumped = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert!(dumped >= 1, "expected a repro file in {}", dir.display());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sampling_skips_between_windows() {
        let store = store();
        let m = ServerMonitor::new(1_000_000, 0, std::env::temp_dir());
        // Far fewer ops than the sample period: no window ever opens, so
        // fabricated replies are never even looked at.
        for _ in 0..64 {
            m.observe(store.as_ref(), Request::Size, || "12345".to_string());
        }
        assert_eq!(m.windows_checked(), 0);
        assert_eq!(m.violations(), 0);
    }
}
