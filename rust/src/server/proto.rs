//! The kv line protocol: parsing and store-side execution.
//!
//! One command per `\n`-terminated line (a trailing `\r` is tolerated):
//!
//! | command        | reply                                                |
//! |----------------|------------------------------------------------------|
//! | `PUT k [v]`    | `1` fresh / `0` overwrite (`v` defaults to 0);       |
//! |                | `ERR OVERLOAD` when the global gate sheds,           |
//! |                | `ERR OVERLOAD shard=<i>` when only `k`'s shard does  |
//! | `DEL k`        | `1`/`0`                                              |
//! | `HAS k`        | `1`/`0` (membership only)                            |
//! | `GET k`        | the stored value, or `NIL` when absent               |
//! | `SCAN lo hi`   | one `k v` line per live key in `[lo, hi]`, ascending,|
//! |                | then a terminator line `END n` (`n` = entry count)   |
//! | `COUNT lo hi`  | number of live keys in `[lo, hi]`                    |
//! | `SIZE`         | exact linearizable count (combining arbiter)         |
//! | `SIZE~ [ms]`   | count at most `ms` (default 50) milliseconds stale   |
//! | `SIZE?`        | O(shards) bounded-lag estimate (never negative)      |
//! | `STATS`        | one line of `key=value` server + size telemetry      |
//! | `QUIT`         | no reply; the server closes the connection           |
//!
//! `SCAN`'s key set is justified at a single linearization point (the
//! double-collect validation in [`crate::size::validated_collect`]); each
//! value is the key's atomically-read current value. An inverted range
//! (`lo > hi`) is an empty scan — `END 0` — not an error. The whole scan
//! reply is rendered as ONE string (internal newlines plus the `END`
//! terminator) so it occupies exactly one slot in pipelined reply order.
//!
//! Parsing is separated from I/O so the reactor's partial-line state
//! machine ([`super::conn`]) hands complete lines here, and so the
//! grammar is unit-testable without a socket. Execution is split by
//! blocking behavior: [`execute`] runs the store operations a handler
//! thread may block on (`SIZE` can wait on a handshake drain), while
//! `SIZE?`/`STATS`/`QUIT` are answered inline by the reactor — that is
//! what keeps the cheap probes live while the handler pool is saturated.

use std::collections::HashMap;
use std::time::Duration;

use crate::set_api::ConcurrentSet;
use crate::size::ArbiterStats;

use super::ServerStats;

/// Default staleness bound for `SIZE~` when the client names none.
pub const DEFAULT_RECENT_MS: u64 = 50;

/// Longest accepted command line, in bytes. Commands are tiny; anything
/// larger is a protocol violation (or garbage) and closes the connection
/// instead of growing an unbounded buffer.
pub const MAX_LINE: usize = 256;

/// Reply when the global admission gate sheds a `PUT` (the `429`-style
/// signal clients back off on).
pub const OVERLOAD_REPLY: &str = "ERR OVERLOAD";

/// Reply when only the routed shard's gate sheds a `PUT`: the client can
/// keep writing keys that live on other shards (and
/// `harness::client_swarm` counts any `ERR OVERLOAD` prefix as a shed,
/// not a protocol error).
pub fn overload_shard_reply(shard: usize) -> String {
    format!("{OVERLOAD_REPLY} shard={shard}")
}

/// Reply for a line longer than [`MAX_LINE`]: the offending line is
/// discarded and parsing resyncs at the next newline — the connection
/// survives (a fat-fingered client loses one command, not its session).
pub const TOOLONG_REPLY: &str = "ERR TOOLONG";

/// Reply when a request's handler missed the per-request deadline; the
/// connection's slot is reclaimed and the eventual stale reply dropped.
pub const TIMEOUT_REPLY: &str = "ERR TIMEOUT";

/// Reply when a handler panicked executing the request (contained by the
/// pool's `catch_unwind`; counted in the `panics` gauge).
pub const PANIC_REPLY: &str = "ERR PANIC";

const ERR_NO_SIZE: &str = "ERR size unsupported by this policy";
const ERR_NO_ESTIMATE: &str = "ERR estimate unavailable (no sharded mirror)";

/// Reply when the store does not implement range scans (competitor
/// baselines keep the [`ConcurrentSet::scan`] default of `None`).
pub const ERR_NO_SCAN: &str = "ERR scan unsupported by this store";

/// `GET` reply for an absent key.
pub const NIL_REPLY: &str = "NIL";

/// One parsed client command.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Request {
    /// Upsert `k -> v`; replies `1` on fresh insert, `0` on overwrite.
    Put(u64, u64),
    Del(u64),
    Has(u64),
    /// Value lookup: the stored value, or [`NIL_REPLY`].
    Get(u64),
    /// Linearizable range scan over `[lo, hi]` (multi-line reply).
    Scan(u64, u64),
    /// Range cardinality over `[lo, hi]` (single-line reply).
    Count(u64, u64),
    /// Exact linearizable size through the combining arbiter.
    Size,
    /// Bounded-staleness size; the payload is the bound in milliseconds.
    SizeRecent(u64),
    /// O(shards) bounded-lag estimate from the sharded mirror.
    SizeEstimate,
    /// Server + size telemetry as one `key=value` line.
    Stats,
    /// Close the connection (after flushing earlier replies).
    Quit,
}

impl Request {
    /// Whether the reactor answers this request inline instead of hopping
    /// through the handler pool. Inline requests must never block: `SIZE?`
    /// is an O(shards) load sweep and `STATS` reads counters, so both keep
    /// answering while every handler is wedged in a blocking `SIZE`.
    pub fn inline(self) -> bool {
        matches!(self, Request::SizeEstimate | Request::Stats | Request::Quit)
    }

    /// Whether admission control applies (only `PUT` grows the store).
    /// `SCAN`/`COUNT` deliberately stay admissible: a read-only sweep must
    /// keep answering while the write path is shedding.
    pub fn grows_store(self) -> bool {
        matches!(self, Request::Put(..))
    }
}

fn parse_key(k: Option<&str>) -> Result<u64, String> {
    k.ok_or_else(|| "ERR missing key".to_string())?
        .parse()
        .map_err(|_| "ERR bad key".to_string())
}

fn parse_range(lo: Option<&str>, hi: Option<&str>) -> Result<(u64, u64), String> {
    let lo = lo
        .ok_or_else(|| "ERR missing range".to_string())?
        .parse()
        .map_err(|_| "ERR bad range".to_string())?;
    let hi = hi
        .ok_or_else(|| "ERR missing range".to_string())?
        .parse()
        .map_err(|_| "ERR bad range".to_string())?;
    Ok((lo, hi))
}

/// Parse one complete line. `Err` carries the exact reply to send back —
/// a malformed command is answered, in order, without killing the
/// connection.
pub fn parse(line: &str) -> Result<Request, String> {
    let mut parts = line.split_whitespace();
    match (parts.next(), parts.next()) {
        (Some("PUT"), k) => {
            let key = parse_key(k)?;
            let value = match parts.next() {
                None => 0,
                Some(v) => v.parse().map_err(|_| "ERR bad value".to_string())?,
            };
            Ok(Request::Put(key, value))
        }
        (Some("DEL"), k) => Ok(Request::Del(parse_key(k)?)),
        (Some("HAS"), k) => Ok(Request::Has(parse_key(k)?)),
        // GET was a HAS alias while the stores were sets; with dictionary
        // semantics it answers the stored value (or NIL).
        (Some("GET"), k) => Ok(Request::Get(parse_key(k)?)),
        (Some("SCAN"), lo) => {
            let (lo, hi) = parse_range(lo, parts.next())?;
            Ok(Request::Scan(lo, hi))
        }
        (Some("COUNT"), lo) => {
            let (lo, hi) = parse_range(lo, parts.next())?;
            Ok(Request::Count(lo, hi))
        }
        (Some("SIZE"), _) => Ok(Request::Size),
        (Some("SIZE~"), ms) => match ms.map_or(Ok(DEFAULT_RECENT_MS), str::parse) {
            Ok(ms) => Ok(Request::SizeRecent(ms)),
            Err(_) => Err("ERR bad staleness".into()),
        },
        (Some("SIZE?"), _) => Ok(Request::SizeEstimate),
        (Some("STATS"), _) => Ok(Request::Stats),
        (Some("QUIT"), _) => Ok(Request::Quit),
        (None, _) => Err("ERR empty command".into()),
        _ => Err("ERR unknown command".into()),
    }
}

/// Execute a pool-side request against the store. Only non-[`inline`]
/// requests belong here; an inline one answers with an error instead of
/// panicking a handler thread (a dead handler would silently shrink the
/// pool).
///
/// [`inline`]: Request::inline
pub fn execute(store: &dyn ConcurrentSet, req: Request) -> String {
    match req {
        Request::Put(k, v) => i64::from(store.put(k, v)).to_string(),
        Request::Del(k) => i64::from(store.delete(k)).to_string(),
        Request::Has(k) => i64::from(store.contains(k)).to_string(),
        Request::Get(k) => match store.get(k) {
            Some(v) => v.to_string(),
            None => NIL_REPLY.into(),
        },
        Request::Scan(lo, hi) => match store.scan(lo, hi) {
            Some(pairs) => scan_reply(&pairs),
            None => ERR_NO_SCAN.into(),
        },
        Request::Count(lo, hi) => match store.count_range(lo, hi) {
            Some(n) => n.to_string(),
            None => ERR_NO_SCAN.into(),
        },
        Request::Size => match store.size_exact() {
            Some(v) => v.value.to_string(),
            None => ERR_NO_SIZE.into(),
        },
        Request::SizeRecent(ms) => match store.size_recent(Duration::from_millis(ms)) {
            Some(v) => v.value.to_string(),
            None => ERR_NO_SIZE.into(),
        },
        Request::SizeEstimate | Request::Stats | Request::Quit => {
            debug_assert!(false, "inline request {req:?} reached the pool");
            "ERR internal: inline request routed to pool".into()
        }
    }
}

/// Render a scan result as one reply string: one `k v` line per entry in
/// key order, then `END n`. The internal newlines ride inside a single
/// `String` so the reactor's reply queue treats the whole scan as one
/// reply — pipelined commands around it stay in order.
pub fn scan_reply(pairs: &[(u64, u64)]) -> String {
    let mut out = String::with_capacity(pairs.len() * 12 + 16);
    for &(k, v) in pairs {
        out.push_str(&k.to_string());
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    }
    out.push_str("END ");
    out.push_str(&pairs.len().to_string());
    out
}

/// Parse the body of a [`scan_reply`] back into pairs — the client-side
/// inverse, shared by `BlockingClient`, the harness, and the tests so the
/// wire format can't drift. `lines` are the reply lines *including* the
/// `END n` terminator; `Err` names what went wrong.
pub fn parse_scan_lines(lines: &[String]) -> Result<Vec<(u64, u64)>, String> {
    let (last, entries) = lines
        .split_last()
        .ok_or_else(|| "empty scan reply".to_string())?;
    let n: usize = last
        .strip_prefix("END ")
        .ok_or_else(|| format!("missing END terminator, got {last:?}"))?
        .parse()
        .map_err(|_| format!("bad END count in {last:?}"))?;
    if n != entries.len() {
        return Err(format!("END {n} but {} entries", entries.len()));
    }
    entries
        .iter()
        .map(|line| {
            let (k, v) = line
                .split_once(' ')
                .ok_or_else(|| format!("bad scan entry {line:?}"))?;
            let k = k.parse().map_err(|_| format!("bad key in {line:?}"))?;
            let v = v.parse().map_err(|_| format!("bad value in {line:?}"))?;
            Ok((k, v))
        })
        .collect()
}

/// The `SIZE?` reply: the sharded mirror's bounded-lag estimate, clamped
/// at zero at the protocol edge as well (the mirror already clamps its
/// reconciliation sweep — see `ConcurrentSet::size_estimate` — but a
/// monitoring endpoint must never print a negative count).
pub fn estimate_reply(store: &dyn ConcurrentSet) -> String {
    match store.size_estimate() {
        Some(v) => v.max(0).to_string(),
        None => ERR_NO_ESTIMATE.into(),
    }
}

/// The `STATS` reply: one space-separated `key=value` line merging the
/// server gauges (connections, queue depth, shed count, admission state)
/// with the store's [`ArbiterStats`]. Stable, grep/parse-friendly — the
/// admission-control tests and the CI smoke client both split on
/// whitespace and `=`.
pub fn stats_reply(server: &ServerStats, size: &ArbiterStats) -> String {
    format!(
        "conns={} peak={} queue={} handlers={} reactors={} accepted={} shed={} admitting={} \
         store_shards={} shard_shed={} timeouts={} panics={} reaped={} \
         monitor_violations={} faults={} \
         rounds={} adoptions={} recent_hits={} recent_refreshes={} daemon_rounds={} \
         daemon_stalls={} fallbacks={} retry_budget={} resizes={} migration_pending={}",
        server.live_conns,
        server.peak_conns,
        server.queue_depth,
        server.handlers,
        server.reactors,
        server.accepted,
        server.shed,
        u8::from(server.admitting),
        server.store_shards,
        server.shard_shed,
        server.timeouts,
        server.panics,
        server.reaped,
        server.monitor_violations,
        server.fault_fires,
        size.rounds,
        size.adoptions,
        size.recent_hits,
        size.recent_refreshes,
        size.daemon_rounds,
        size.daemon_stalls,
        size.fallbacks,
        size.retry_budget,
        size.resizes,
        size.migration_pending,
    )
}

/// Parse a [`stats_reply`] line back into its integer fields — the
/// client-side inverse, shared by the self-test and the integration
/// tests so the two never drift from the render format. `Err` names the
/// offending pair.
pub fn parse_stats(line: &str) -> Result<HashMap<String, u64>, String> {
    line.split_whitespace()
        .map(|pair| {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| format!("bad STATS pair {pair:?}"))?;
            let v = v
                .parse()
                .map_err(|_| format!("non-numeric STATS value {pair:?}"))?;
            Ok((k.to_string(), v))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_util::make_set;
    use crate::cli::PolicyKind;

    #[test]
    fn parses_every_command() {
        assert_eq!(parse("PUT 7"), Ok(Request::Put(7, 0)));
        assert_eq!(parse("PUT 7 42"), Ok(Request::Put(7, 42)));
        assert_eq!(parse("DEL 7"), Ok(Request::Del(7)));
        assert_eq!(parse("HAS 0"), Ok(Request::Has(0)));
        assert_eq!(parse("GET 0"), Ok(Request::Get(0)), "GET is a real lookup now");
        assert_eq!(parse("GET x"), Err("ERR bad key".into()));
        assert_eq!(parse("SCAN 3 9"), Ok(Request::Scan(3, 9)));
        assert_eq!(parse("SCAN 9 3"), Ok(Request::Scan(9, 3)), "inverted range parses");
        assert_eq!(parse("COUNT 0 100"), Ok(Request::Count(0, 100)));
        assert_eq!(parse("SIZE"), Ok(Request::Size));
        assert_eq!(parse("SIZE~"), Ok(Request::SizeRecent(DEFAULT_RECENT_MS)));
        assert_eq!(parse("SIZE~ 5"), Ok(Request::SizeRecent(5)));
        assert_eq!(parse("SIZE?"), Ok(Request::SizeEstimate));
        assert_eq!(parse("STATS"), Ok(Request::Stats));
        assert_eq!(parse("QUIT"), Ok(Request::Quit));
        assert_eq!(parse("  PUT   9  "), Ok(Request::Put(9, 0)));
    }

    #[test]
    fn rejects_malformed_lines_with_err_replies() {
        assert_eq!(parse("PUT"), Err("ERR missing key".into()));
        assert_eq!(parse("PUT x"), Err("ERR bad key".into()));
        assert_eq!(parse("PUT 1 x"), Err("ERR bad value".into()));
        assert_eq!(parse("SCAN"), Err("ERR missing range".into()));
        assert_eq!(parse("SCAN 1"), Err("ERR missing range".into()));
        assert_eq!(parse("SCAN 1 x"), Err("ERR bad range".into()));
        assert_eq!(parse("COUNT y 2"), Err("ERR bad range".into()));
        assert_eq!(parse("SIZE~ bogus"), Err("ERR bad staleness".into()));
        assert_eq!(parse("NOPE 1"), Err("ERR unknown command".into()));
        assert_eq!(parse(""), Err("ERR empty command".into()));
        assert_eq!(parse("   "), Err("ERR empty command".into()));
    }

    #[test]
    fn inline_classification() {
        for req in [Request::SizeEstimate, Request::Stats, Request::Quit] {
            assert!(req.inline(), "{req:?}");
        }
        for req in [
            Request::Put(1, 0),
            Request::Del(1),
            Request::Has(1),
            Request::Get(1),
            Request::Scan(0, 9),
            Request::Count(0, 9),
            Request::Size,
            Request::SizeRecent(1),
        ] {
            assert!(!req.inline(), "{req:?}");
        }
        assert!(Request::Put(1, 0).grows_store());
        assert!(!Request::Del(1).grows_store());
        assert!(
            !Request::Scan(0, 9).grows_store() && !Request::Count(0, 9).grows_store(),
            "scans must keep answering through overload shedding"
        );
    }

    #[test]
    fn execute_runs_store_ops() {
        let store = make_set("hashtable", PolicyKind::Linearizable, 64).unwrap();
        assert_eq!(execute(store.as_ref(), Request::Put(3, 30)), "1");
        assert_eq!(execute(store.as_ref(), Request::Put(3, 31)), "0");
        assert_eq!(execute(store.as_ref(), Request::Has(3)), "1");
        assert_eq!(execute(store.as_ref(), Request::Get(3)), "31");
        assert_eq!(execute(store.as_ref(), Request::Get(4)), NIL_REPLY);
        assert_eq!(execute(store.as_ref(), Request::Put(5, 50)), "1");
        assert_eq!(execute(store.as_ref(), Request::Scan(0, 9)), "3 31\n5 50\nEND 2");
        assert_eq!(execute(store.as_ref(), Request::Scan(9, 0)), "END 0");
        assert_eq!(execute(store.as_ref(), Request::Count(0, 9)), "2");
        assert_eq!(execute(store.as_ref(), Request::Size), "2");
        assert_eq!(execute(store.as_ref(), Request::SizeRecent(50)), "2");
        assert_eq!(execute(store.as_ref(), Request::Del(3)), "1");
        assert_eq!(execute(store.as_ref(), Request::Count(0, 9)), "1");
        assert_eq!(execute(store.as_ref(), Request::Size), "1");
    }

    #[test]
    fn scan_reply_round_trips_through_the_client_parser() {
        let pairs = vec![(1, 10), (2, 0), (900, u64::MAX)];
        let reply = scan_reply(&pairs);
        let lines: Vec<String> = reply.lines().map(str::to_string).collect();
        assert_eq!(parse_scan_lines(&lines), Ok(pairs));
        assert_eq!(scan_reply(&[]), "END 0");
        assert_eq!(parse_scan_lines(&["END 0".to_string()]), Ok(vec![]));
        assert!(parse_scan_lines(&[]).is_err());
        assert!(parse_scan_lines(&["1 2".to_string()]).is_err(), "no terminator");
        assert!(
            parse_scan_lines(&["1 2".to_string(), "END 5".to_string()]).is_err(),
            "count mismatch"
        );
    }

    #[test]
    fn execute_answers_gracefully_without_size() {
        let store = make_set("hashtable", PolicyKind::Baseline, 64).unwrap();
        assert_eq!(execute(store.as_ref(), Request::Size), ERR_NO_SIZE);
        assert_eq!(execute(store.as_ref(), Request::SizeRecent(5)), ERR_NO_SIZE);
        assert_eq!(estimate_reply(store.as_ref()), ERR_NO_ESTIMATE);
    }

    #[test]
    fn stats_reply_is_key_value_parseable() {
        let server = ServerStats {
            live_conns: 3,
            peak_conns: 300,
            queue_depth: 2,
            handlers: 4,
            reactors: 2,
            accepted: 310,
            shed: 7,
            admitting: true,
            store_shards: 4,
            shard_shed: 11,
            timeouts: 2,
            panics: 1,
            reaped: 5,
            monitor_violations: 0,
            fault_fires: 0,
        };
        let line = stats_reply(&server, &ArbiterStats::default());
        let stats = parse_stats(&line).expect("round-trip parse");
        for want in [
            "conns",
            "peak",
            "queue",
            "handlers",
            "reactors",
            "shed",
            "admitting",
            "store_shards",
            "shard_shed",
            "timeouts",
            "panics",
            "reaped",
            "monitor_violations",
            "faults",
            "daemon_rounds",
            "daemon_stalls",
            "resizes",
            "migration_pending",
        ] {
            assert!(stats.contains_key(want), "missing {want} in {line}");
        }
        assert_eq!(stats["peak"], 300);
        assert_eq!(stats["reactors"], 2);
        assert_eq!(stats["admitting"], 1);
        assert_eq!(stats["shed"], 7);
        assert_eq!(stats["store_shards"], 4);
        assert_eq!(stats["shard_shed"], 11);
        assert_eq!(stats["timeouts"], 2);
        assert_eq!(stats["panics"], 1);
        assert_eq!(stats["reaped"], 5);
        assert_eq!(stats["monitor_violations"], 0);
    }

    #[test]
    fn shard_overload_reply_keeps_the_overload_prefix() {
        let reply = overload_shard_reply(3);
        assert_eq!(reply, "ERR OVERLOAD shard=3");
        assert!(reply.starts_with(OVERLOAD_REPLY));
    }

    #[test]
    fn parse_stats_rejects_garbage() {
        assert!(parse_stats("conns").is_err());
        assert!(parse_stats("conns=many").is_err());
        assert_eq!(parse_stats("").unwrap().len(), 0);
    }
}
