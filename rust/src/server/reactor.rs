//! The hand-rolled readiness reactor, sharded: N threads, each
//! multiplexing its own slice of the connections over nonblocking
//! sockets.
//!
//! The crate is offline and dependency-free, so there is no `mio`/`tokio`
//! (and no `libc` for raw `epoll` — see [`super::readiness`] for the
//! backend seam). Readiness is therefore *polled*: all sockets run in
//! nonblocking mode and each shard tick sweeps
//! adopt → completions → per-connection read/dispatch/write, treating
//! `WouldBlock` as "not ready". A tick that makes no progress anywhere
//! waits on the shard's [`Readiness`] backend (a short nap by default, a
//! spin for latency-critical deployments) so an idle server costs ~0 CPU
//! while a loaded one never sleeps.
//!
//! One [`Reactor`] is one **shard**: it owns a private connection table
//! fed by the acceptor thread ([`super::acceptor`]) over a handoff
//! channel, so shards share no connection state and the per-connection
//! sweep runs lock-free. What *is* shared — the handler pool's job
//! channel, the two-tier admission gates, the sampled monitor, the
//! merged `STATS` gauges — lives in [`Shared`] behind atomics.
//!
//! Store operations do not run on the reactor threads: parsed requests
//! hop to the bounded handler pool (see [`super::Server`]) through an
//! mpsc pair, one **batch** in flight per connection. A batch is up to
//! `pipeline_depth` consecutive pool requests drained from one
//! connection's read buffer, executed in order by a single handler, so a
//! pipelining client costs one pool round trip per batch instead of one
//! per command while per-connection replies keep program order. The two
//! exceptions are `SIZE?`/`STATS` (answered inline — they only read
//! counters, and must stay live when every handler is wedged in a
//! blocking `SIZE`) and `PUT`s shed by admission control (answered
//! inline with [`proto::OVERLOAD_REPLY`], or the per-shard
//! `ERR OVERLOAD shard=<i>` variant when the second tier trips —
//! shedding that queued behind the saturated pool would defeat its
//! purpose). Admission is evaluated per command at batch-build time: the
//! estimate each `PUT` is judged on is the one current at dispatch, and a
//! shed mid-batch closes the batch so the overload reply keeps its place
//! in the reply order.

use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::atomic::Ordering::SeqCst;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::set_api::ConcurrentSet;

use super::conn::{Conn, InFlight, Pending};
use super::proto::{self, Request};
use super::readiness::Readiness;
use super::{IdleStrategy, Shared};

/// One batch of store requests travelling reactor shard → handler pool.
pub(crate) struct Job {
    /// Index of the shard that dispatched the batch; the handler sends
    /// the [`Completion`] back on this shard's channel.
    pub reactor: usize,
    pub token: u64,
    /// Unique per dispatched batch (within its shard); echoed in the
    /// [`Completion`] so replies that outlived their deadline (the shard
    /// already answered `ERR TIMEOUT` per command and moved on) are
    /// recognized as stale and dropped instead of answering the *next*
    /// batch.
    pub req_id: u64,
    /// The batched commands, in connection program order (>= 1).
    pub reqs: Vec<Request>,
}

/// One batch of replies travelling handler pool → reactor shard, in the
/// same order as [`Job::reqs`].
pub(crate) struct Completion {
    pub token: u64,
    pub req_id: u64,
    pub replies: Vec<String>,
}

/// One shard's share of the [`super::ServerConfig`] knobs.
pub(crate) struct ReactorConfig {
    /// This shard's index into `Shared::gauges` (and the handoff lane it
    /// adopts from).
    pub index: usize,
    pub idle: IdleStrategy,
    /// Pool size, reported through `STATS`.
    pub handlers: usize,
    /// Most commands batched into one pool job per connection dispatch.
    pub pipeline_depth: usize,
    /// Per-request handler deadline: a pool batch unanswered past this
    /// gets `ERR TIMEOUT` per command and its connection slot back
    /// (`None` = wait forever).
    pub request_timeout: Option<Duration>,
    /// Reap connections with no protocol progress for this long
    /// (`None` = never). Counts *parsed lines*, not raw bytes, so
    /// slowloris drip-feeding is reaped too.
    pub conn_idle: Option<Duration>,
}

pub(crate) struct Reactor {
    /// Sockets the acceptor assigned to this shard, awaiting adoption.
    handoffs: Receiver<TcpStream>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    next_req_id: u64,
    jobs: Sender<Job>,
    completions: Receiver<Completion>,
    store: Arc<dyn ConcurrentSet>,
    shared: Arc<Shared>,
    readiness: Readiness,
    cfg: ReactorConfig,
}

/// Two-tier admission for one pool-bound request: `Some(reply)` sheds it
/// inline, `None` admits. A free function (not a `Reactor` method) so the
/// dispatch loop can call it while `self.conns` is mutably borrowed.
fn admission_reply(shared: &Shared, store: &dyn ConcurrentSet, req: Request) -> Option<String> {
    if !req.grows_store() {
        return None;
    }
    // Tier 1: global watermarks on the aggregate estimate — the whole
    // store is too full. The gate is shared by every reactor shard, so
    // hysteresis state is cluster-wide no matter which shard a
    // connection landed on.
    if let Some(gate) = &shared.admission {
        if !gate.admit(store.size_estimate()) {
            return Some(proto::OVERLOAD_REPLY.into());
        }
    }
    // Tier 2: per-store-shard watermarks — shed only the hot shard's
    // PUTs while its siblings admit.
    if !shared.shard_gates.is_empty() {
        if let Request::Put(key, _) = req {
            let shard = store.shard_of(key);
            if !shared.shard_gates[shard].admit(store.shard_estimate(shard)) {
                return Some(proto::overload_shard_reply(shard));
            }
        }
    }
    None
}

impl Reactor {
    pub fn new(
        handoffs: Receiver<TcpStream>,
        store: Arc<dyn ConcurrentSet>,
        shared: Arc<Shared>,
        jobs: Sender<Job>,
        completions: Receiver<Completion>,
        cfg: ReactorConfig,
    ) -> Self {
        Self {
            handoffs,
            conns: HashMap::new(),
            next_token: 0,
            next_req_id: 0,
            jobs,
            completions,
            store,
            shared,
            readiness: Readiness::new(),
            cfg,
        }
    }

    /// The shard event loop. Returns when [`Shared::stop`] is raised;
    /// dropping the shard then closes its connections, and dropping the
    /// last shard's job sender drains the handler pool.
    pub fn run(mut self) {
        while !self.shared.stop.load(SeqCst) {
            let mut progress = self.adopt();
            progress |= self.drain_completions();
            progress |= self.pump_conns();
            progress |= self.heal();
            self.reap();
            if !progress {
                self.readiness.wait(self.cfg.idle);
            }
        }
    }

    /// Adopt every socket the acceptor has handed to this shard: move it
    /// from the handoff gauge into the connection table.
    fn adopt(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.handoffs.try_recv() {
                Ok(stream) => {
                    progress = true;
                    let gauges = &self.shared.gauges[self.cfg.index];
                    gauges.handoff.fetch_sub(1, SeqCst);
                    let Ok(conn) = Conn::new(stream) else { continue };
                    let token = self.next_token;
                    self.next_token += 1;
                    self.conns.insert(token, conn);
                    let live = self.conns.len();
                    gauges.live.store(live, SeqCst);
                    gauges.peak.fetch_max(live, SeqCst);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        progress
    }

    /// Route finished pool batches back to their connections' write
    /// buffers, one coalesced append per batch.
    fn drain_completions(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.completions.try_recv() {
                Ok(done) => {
                    progress = true;
                    self.shared.gauges[self.cfg.index].queue.fetch_sub(done.replies.len(), SeqCst);
                    // The connection may have died while its batch was in
                    // the pool, or the deadline sweep may have already
                    // answered `ERR TIMEOUT` and reclaimed the slot (the
                    // req_id then no longer matches); either way the late
                    // replies are dropped, never misdelivered.
                    if let Some(conn) = self.conns.get_mut(&done.token) {
                        if conn.in_flight.is_some_and(|inf| inf.id == done.req_id) {
                            conn.in_flight = None;
                            conn.enqueue_replies(&done.replies);
                        }
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        progress
    }

    /// Per-connection read → dispatch → write sweep. Iterates the map
    /// in place (no per-tick token Vec — at ~20k idle ticks/sec that
    /// allocation is pure waste); every access in the loop body is a
    /// disjoint field borrow, so this borrows cleanly.
    fn pump_conns(&mut self) -> bool {
        let mut progress = false;
        let depth = self.cfg.pipeline_depth.max(1);
        let store = self.store.as_ref();
        for (&token, conn) in self.conns.iter_mut() {
            progress |= conn.pump_read();

            // Dispatch in arrival order, one pool batch in flight per
            // connection (replies stay ordered); inline work and error
            // replies drain immediately between batches. A closing
            // (EOF'd) connection still drains what it already sent —
            // QUIT clears the queue instead, so nothing after it is
            // served.
            while conn.in_flight.is_none() {
                let Some(front) = conn.pending.pop_front() else { break };
                progress = true;
                match front {
                    Pending::Reply(reply) => conn.enqueue_reply(&reply),
                    Pending::Req(Request::Quit) => {
                        // Flush earlier replies, drop later input.
                        conn.pending.clear();
                        conn.closing = true;
                    }
                    Pending::Req(Request::SizeEstimate) => {
                        let reply = proto::estimate_reply(store);
                        conn.enqueue_reply(&reply);
                    }
                    Pending::Req(Request::Stats) => {
                        // NB: only field borrows here — `conn` mutably
                        // borrows `self.conns`, so no `&self` calls.
                        let server = self.shared.snapshot(self.cfg.handlers);
                        let size = store.size_stats().unwrap_or_default();
                        conn.enqueue_reply(&proto::stats_reply(&server, &size));
                    }
                    Pending::Req(req) => {
                        if let Some(reply) = admission_reply(&self.shared, store, req) {
                            conn.enqueue_reply(&reply);
                            continue;
                        }
                        // Pipelining: extend the batch with every
                        // immediately-following pool request (admission-
                        // checked at dispatch, like the first), up to the
                        // depth; one handler runs it in program order.
                        let mut reqs = vec![req];
                        while reqs.len() < depth {
                            match conn.pending.front() {
                                Some(Pending::Req(next)) if !next.inline() => {
                                    let next = *next;
                                    conn.pending.pop_front();
                                    match admission_reply(&self.shared, store, next) {
                                        // Shed mid-batch: the overload
                                        // reply must *follow* the batch's
                                        // replies, so park it back at the
                                        // queue front and close the batch.
                                        Some(reply) => {
                                            conn.pending.push_front(Pending::Reply(reply));
                                            break;
                                        }
                                        None => reqs.push(next),
                                    }
                                }
                                _ => break,
                            }
                        }
                        let req_id = self.next_req_id;
                        self.next_req_id += 1;
                        let len = reqs.len();
                        let job = Job {
                            reactor: self.cfg.index,
                            token,
                            req_id,
                            reqs,
                        };
                        if self.jobs.send(job).is_err() {
                            // Pool gone: only happens during shutdown.
                            conn.dead = true;
                            break;
                        }
                        self.shared.gauges[self.cfg.index].queue.fetch_add(len, SeqCst);
                        conn.in_flight = Some(InFlight {
                            id: req_id,
                            since: Instant::now(),
                            len,
                        });
                    }
                }
            }

            progress |= conn.pump_write();
        }
        progress
    }

    /// Self-healing sweep: enforce per-request deadlines and reap idle
    /// connections. Runs every tick but is free when both knobs are off.
    fn heal(&mut self) -> bool {
        let (timeout, idle) = (self.cfg.request_timeout, self.cfg.conn_idle);
        if timeout.is_none() && idle.is_none() {
            return false;
        }
        let now = Instant::now();
        let gauges = &self.shared.gauges[self.cfg.index];
        let mut progress = false;
        for conn in self.conns.values_mut() {
            if let (Some(limit), Some(inf)) = (timeout, conn.in_flight) {
                if now.duration_since(inf.since) >= limit {
                    // Stop waiting on the pool: answer every command in
                    // the batch now and reclaim the slot so the
                    // connection's next batch can dispatch. The handler
                    // keeps running (it cannot be cancelled safely); its
                    // eventual completion is dropped by the req_id check
                    // in drain_completions.
                    conn.in_flight = None;
                    for _ in 0..inf.len {
                        conn.enqueue_reply(proto::TIMEOUT_REPLY);
                    }
                    gauges.timeouts.fetch_add(inf.len as u64, SeqCst);
                    progress = true;
                }
            }
            if let Some(limit) = idle {
                if !conn.dead && !conn.closing && conn.idle_expired(now, limit) {
                    conn.dead = true;
                    gauges.reaped.fetch_add(1, SeqCst);
                    progress = true;
                }
            }
        }
        progress
    }

    /// Drop finished and failed connections, keeping the gauge in sync.
    fn reap(&mut self) {
        let before = self.conns.len();
        self.conns.retain(|_, conn| !conn.should_close());
        if self.conns.len() != before {
            self.shared.gauges[self.cfg.index].live.store(self.conns.len(), SeqCst);
        }
    }
}
