//! The hand-rolled readiness reactor: one thread multiplexing every
//! connection over nonblocking sockets.
//!
//! The crate is offline and dependency-free, so there is no `mio`/`tokio`
//! (and no `libc` for raw `epoll`). Readiness is therefore *polled*: all
//! sockets run in nonblocking mode and each reactor tick sweeps
//! accept → completions → per-connection read/dispatch/write, treating
//! `WouldBlock` as "not ready". A tick that makes no progress anywhere
//! applies the configured [`IdleStrategy`] (a short nap by default, a
//! spin for latency-critical deployments) so an idle server costs ~0 CPU
//! while a loaded one never sleeps. This scales to thousands of
//! connections because per-tick work is a few syscalls per socket —
//! against the old model's hard wall where each *connection* consumed a
//! thread slot out of [`crate::thread_id::capacity`].
//!
//! Store operations do not run on the reactor thread: parsed requests hop
//! to the bounded handler pool (see [`super::Server`]) through an mpsc
//! pair, one in flight per connection to keep replies ordered. The two
//! exceptions are `SIZE?`/`STATS` (answered inline — they only read
//! counters, and must stay live when every handler is wedged in a
//! blocking `SIZE`) and `PUT`s shed by admission control (answered
//! inline with [`proto::OVERLOAD_REPLY`], or the per-shard
//! `ERR OVERLOAD shard=<i>` variant when the second tier trips —
//! shedding that queued behind the saturated pool would defeat its
//! purpose).

use std::collections::HashMap;
use std::io::{ErrorKind, Write};
use std::net::TcpListener;
use std::sync::atomic::Ordering::SeqCst;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::set_api::ConcurrentSet;

use super::conn::{Conn, InFlight, Pending};
use super::proto::{self, Request};
use super::{IdleStrategy, Shared};

/// One store request travelling reactor → handler pool.
pub(crate) struct Job {
    pub token: u64,
    /// Globally unique per dispatched request; echoed in the
    /// [`Completion`] so a reply that outlived its deadline (the reactor
    /// already answered `ERR TIMEOUT` and moved on) is recognized as
    /// stale and dropped instead of answering the *next* request.
    pub req_id: u64,
    pub req: Request,
}

/// One reply travelling handler pool → reactor.
pub(crate) struct Completion {
    pub token: u64,
    pub req_id: u64,
    pub reply: String,
}

/// The reactor's share of the [`super::ServerConfig`] knobs.
pub(crate) struct ReactorConfig {
    pub idle: IdleStrategy,
    pub max_conns: usize,
    /// Pool size, reported through `STATS`.
    pub handlers: usize,
    /// Per-request handler deadline: a pool request unanswered past this
    /// gets `ERR TIMEOUT` and its connection slot back (`None` = wait
    /// forever).
    pub request_timeout: Option<Duration>,
    /// Reap connections with no protocol progress for this long
    /// (`None` = never). Counts *parsed lines*, not raw bytes, so
    /// slowloris drip-feeding is reaped too.
    pub conn_idle: Option<Duration>,
}

pub(crate) struct Reactor {
    listener: TcpListener,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    next_req_id: u64,
    jobs: Sender<Job>,
    completions: Receiver<Completion>,
    store: Arc<dyn ConcurrentSet>,
    shared: Arc<Shared>,
    cfg: ReactorConfig,
}

impl Reactor {
    pub fn new(
        listener: TcpListener,
        store: Arc<dyn ConcurrentSet>,
        shared: Arc<Shared>,
        jobs: Sender<Job>,
        completions: Receiver<Completion>,
        cfg: ReactorConfig,
    ) -> Self {
        Self {
            listener,
            conns: HashMap::new(),
            next_token: 0,
            next_req_id: 0,
            jobs,
            completions,
            store,
            shared,
            cfg,
        }
    }

    /// The event loop. Returns when [`Shared::stop`] is raised; dropping
    /// the reactor then closes the listener and every connection, and
    /// dropping its job sender drains the handler pool.
    pub fn run(mut self) {
        while !self.shared.stop.load(SeqCst) {
            let mut progress = self.accept();
            progress |= self.drain_completions();
            progress |= self.pump_conns();
            progress |= self.heal();
            self.reap();
            if !progress {
                match self.cfg.idle {
                    IdleStrategy::Sleep(nap) => std::thread::sleep(nap),
                    IdleStrategy::Spin => std::thread::yield_now(),
                }
            }
        }
    }

    /// Accept every connection the listener has ready.
    fn accept(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    progress = true;
                    self.shared.accepted.fetch_add(1, SeqCst);
                    if self.conns.len() >= self.cfg.max_conns {
                        // Decline politely; the fresh socket buffer takes
                        // this short write without blocking.
                        let mut stream = stream;
                        let _ = stream.write_all(b"ERR server full\n");
                        continue;
                    }
                    let Ok(conn) = Conn::new(stream) else { continue };
                    let token = self.next_token;
                    self.next_token += 1;
                    self.conns.insert(token, conn);
                    let live = self.conns.len();
                    self.shared.live.store(live, SeqCst);
                    self.shared.peak.fetch_max(live, SeqCst);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Transient failures (ECONNABORTED, EMFILE, ...) must
                    // not take the server down; the idle backoff keeps a
                    // persistent error from hot-looping.
                    eprintln!("server: accept failed: {e}");
                    break;
                }
            }
        }
        progress
    }

    /// Route finished pool work back to its connection's write buffer.
    fn drain_completions(&mut self) -> bool {
        let mut progress = false;
        loop {
            match self.completions.try_recv() {
                Ok(done) => {
                    progress = true;
                    self.shared.queue.fetch_sub(1, SeqCst);
                    // The connection may have died while its request was
                    // in the pool, or the deadline sweep may have already
                    // answered `ERR TIMEOUT` and reclaimed the slot (the
                    // req_id then no longer matches); either way the late
                    // reply is dropped, never misdelivered.
                    if let Some(conn) = self.conns.get_mut(&done.token) {
                        if conn.in_flight.is_some_and(|inf| inf.id == done.req_id) {
                            conn.in_flight = None;
                            conn.enqueue_reply(&done.reply);
                        }
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        progress
    }

    /// Per-connection read → dispatch → write sweep. Iterates the map
    /// in place (no per-tick token Vec — at ~20k idle ticks/sec that
    /// allocation is pure waste); every access in the loop body is a
    /// disjoint field borrow, so this borrows cleanly.
    fn pump_conns(&mut self) -> bool {
        let mut progress = false;
        for (&token, conn) in self.conns.iter_mut() {
            progress |= conn.pump_read();

            // Dispatch in arrival order, one pool request in flight per
            // connection (replies stay ordered); inline work and error
            // replies drain immediately. A closing (EOF'd) connection
            // still drains what it already sent — QUIT clears the queue
            // instead, so nothing after it is served.
            while conn.in_flight.is_none() {
                let Some(front) = conn.pending.pop_front() else { break };
                progress = true;
                match front {
                    Pending::Reply(reply) => conn.enqueue_reply(&reply),
                    Pending::Req(Request::Quit) => {
                        // Flush earlier replies, drop later input.
                        conn.pending.clear();
                        conn.closing = true;
                    }
                    Pending::Req(Request::SizeEstimate) => {
                        let reply = proto::estimate_reply(self.store.as_ref());
                        conn.enqueue_reply(&reply);
                    }
                    Pending::Req(Request::Stats) => {
                        // NB: only field borrows here — `conn` mutably
                        // borrows `self.conns`, so no `&self` calls.
                        let server = self.shared.snapshot(self.cfg.handlers);
                        let size = self.store.size_stats().unwrap_or_default();
                        conn.enqueue_reply(&proto::stats_reply(&server, &size));
                    }
                    Pending::Req(req) => {
                        if req.grows_store() {
                            // Tier 1: global watermarks on the aggregate
                            // estimate — the whole store is too full.
                            if let Some(gate) = &self.shared.admission {
                                if !gate.admit(self.store.size_estimate()) {
                                    conn.enqueue_reply(proto::OVERLOAD_REPLY);
                                    continue;
                                }
                            }
                            // Tier 2: per-shard watermarks — shed only the
                            // hot shard's PUTs while its siblings admit.
                            if !self.shared.shard_gates.is_empty() {
                                if let Request::Put(key) = req {
                                    let shard = self.store.shard_of(key);
                                    let gate = &self.shared.shard_gates[shard];
                                    if !gate.admit(self.store.shard_estimate(shard)) {
                                        conn.enqueue_reply(&proto::overload_shard_reply(shard));
                                        continue;
                                    }
                                }
                            }
                        }
                        let req_id = self.next_req_id;
                        self.next_req_id += 1;
                        if self.jobs.send(Job { token, req_id, req }).is_err() {
                            // Pool gone: only happens during shutdown.
                            conn.dead = true;
                            break;
                        }
                        self.shared.queue.fetch_add(1, SeqCst);
                        conn.in_flight = Some(InFlight {
                            id: req_id,
                            since: Instant::now(),
                        });
                    }
                }
            }

            progress |= conn.pump_write();
        }
        progress
    }

    /// Self-healing sweep: enforce per-request deadlines and reap idle
    /// connections. Runs every tick but is free when both knobs are off.
    fn heal(&mut self) -> bool {
        let (timeout, idle) = (self.cfg.request_timeout, self.cfg.conn_idle);
        if timeout.is_none() && idle.is_none() {
            return false;
        }
        let now = Instant::now();
        let mut progress = false;
        for conn in self.conns.values_mut() {
            if let (Some(limit), Some(inf)) = (timeout, conn.in_flight) {
                if now.duration_since(inf.since) >= limit {
                    // Stop waiting on the pool: answer now and reclaim
                    // the slot so the connection's next request can
                    // dispatch. The handler keeps running (it cannot be
                    // cancelled safely); its eventual completion is
                    // dropped by the req_id check in drain_completions.
                    conn.in_flight = None;
                    conn.enqueue_reply(proto::TIMEOUT_REPLY);
                    self.shared.timeouts.fetch_add(1, SeqCst);
                    progress = true;
                }
            }
            if let Some(limit) = idle {
                if !conn.dead && !conn.closing && conn.idle_expired(now, limit) {
                    conn.dead = true;
                    self.shared.reaped.fetch_add(1, SeqCst);
                    progress = true;
                }
            }
        }
        progress
    }

    /// Drop finished and failed connections, keeping the gauge in sync.
    fn reap(&mut self) {
        let before = self.conns.len();
        self.conns.retain(|_, conn| !conn.should_close());
        if self.conns.len() != before {
            self.shared.live.store(self.conns.len(), SeqCst);
        }
    }
}
