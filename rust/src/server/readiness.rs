//! Readiness backends for the reactor shards.
//!
//! The crate is offline and dependency-free — no `mio`, no `libc` — so
//! the default backend is **polled**: every socket runs nonblocking, each
//! shard tick sweeps them all treating `WouldBlock` as "not ready", and a
//! tick with no progress applies the configured [`IdleStrategy`]. That is
//! O(connections) per tick but each probe is one cheap syscall, and an
//! idle server costs ~0 CPU thanks to the nap.
//!
//! The `net-epoll` cargo feature carves out the seam for a real
//! `epoll_wait` backend: construction *attempts* epoll first and falls
//! back to polled, because raw epoll needs a libc syscall shim this crate
//! does not vendor (std exposes no epoll surface). The seam keeps the
//! shard loop backend-agnostic, so landing the shim later touches only
//! this file; compiling with `--features net-epoll` proves the seam
//! builds and degrades cleanly today.

use super::IdleStrategy;

/// A shard's readiness source: how it waits when a tick made no progress.
pub(crate) struct Readiness {
    backend: Backend,
}

enum Backend {
    /// Sweep nonblocking sockets every tick; idle ticks nap or spin.
    Polled,
    /// Kernel readiness via `epoll_wait` (feature-gated seam; see the
    /// module docs — construction currently always falls back).
    #[cfg(feature = "net-epoll")]
    Epoll(epoll::Epoll),
}

impl Readiness {
    /// Pick the best available backend: epoll when the `net-epoll`
    /// feature is on and the host interface is available (it is not until
    /// a libc shim lands), polled otherwise.
    pub fn new() -> Self {
        #[cfg(feature = "net-epoll")]
        match epoll::Epoll::new() {
            Ok(ep) => {
                return Self {
                    backend: Backend::Epoll(ep),
                }
            }
            Err(e) => {
                eprintln!("server: net-epoll backend unavailable ({e}); using polled readiness");
            }
        }
        Self {
            backend: Backend::Polled,
        }
    }

    /// The active backend's name (asserted by the backend tests).
    #[cfg(test)]
    pub fn name(&self) -> &'static str {
        match &self.backend {
            Backend::Polled => "polled",
            #[cfg(feature = "net-epoll")]
            Backend::Epoll(_) => "epoll",
        }
    }

    /// Wait until work may be ready. The polled backend cannot know, so
    /// it applies the shard's idle strategy; the epoll backend would
    /// `epoll_wait` with the nap as its timeout (until the shim lands it
    /// degrades to the same nap, so a future constructible `Epoll` can
    /// never busy-hang a shard).
    pub fn wait(&self, idle: IdleStrategy) {
        match &self.backend {
            Backend::Polled => idle_wait(idle),
            #[cfg(feature = "net-epoll")]
            Backend::Epoll(_) => idle_wait(idle),
        }
    }
}

fn idle_wait(idle: IdleStrategy) {
    match idle {
        IdleStrategy::Sleep(nap) => std::thread::sleep(nap),
        IdleStrategy::Spin => std::thread::yield_now(),
    }
}

#[cfg(feature = "net-epoll")]
mod epoll {
    //! The epoll seam, stubbed: interest registration and wait belong
    //! here once a libc syscall shim exists. Until then construction
    //! reports `Unsupported` so [`super::Readiness::new`] falls back to
    //! the polled backend instead of serving nothing.

    use std::io;

    pub(super) struct Epoll {
        /// The `epoll_create1` fd, once a shim can produce one.
        _epfd: i32,
    }

    impl Epoll {
        pub fn new() -> io::Result<Self> {
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "epoll needs a libc syscall shim (std exposes no epoll interface)",
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_selection_degrades_to_polled() {
        // With `net-epoll` off, polled is the only backend; with it on,
        // the stubbed epoll constructor fails and selection must fall
        // back rather than panic or hang.
        assert_eq!(Readiness::new().name(), "polled");
    }

    #[test]
    fn polled_wait_returns_promptly() {
        let r = Readiness::new();
        let start = std::time::Instant::now();
        r.wait(IdleStrategy::Sleep(std::time::Duration::from_micros(50)));
        r.wait(IdleStrategy::Spin);
        assert!(
            start.elapsed() < std::time::Duration::from_secs(5),
            "idle wait must be a nap, not a block"
        );
    }
}
