//! The common concurrent-set interface (paper Section 2: insert / delete /
//! contains, plus the added `size`).
//!
//! All keys are `u64` with `u64::MAX` reserved as the tail sentinel.
//! Dictionaries are the same transformation with a value payload; the
//! skip-list implementation doubles as a map via [`crate::skiplist`]'s
//! value variant — the paper makes the identical simplification ("we refer
//! only to sets for brevity, but all our claims apply to dictionaries").

/// Object-safe set interface used by the workload harness, so one driver
/// benches every structure/policy combination.
pub trait ConcurrentSet: Send + Sync {
    /// Insert `k`; `true` iff `k` was absent (paper: "returns a failure"
    /// otherwise).
    fn insert(&self, k: u64) -> bool;
    /// Delete `k`; `true` iff `k` was present.
    fn delete(&self, k: u64) -> bool;
    /// Membership test.
    fn contains(&self, k: u64) -> bool;
    /// The structure's `size()`, if its policy provides one.
    fn size(&self) -> Option<i64>;
    /// Structure name for reports (e.g. `SizeSkipList`).
    fn name(&self) -> String;
}

/// Largest insertable key (`u64::MAX` is the tail sentinel).
pub const MAX_KEY: u64 = u64::MAX - 1;
