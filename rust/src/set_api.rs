//! The common concurrent-set interface (paper Section 2: insert / delete /
//! contains, plus the added `size`).
//!
//! All keys are `u64` with `u64::MAX` reserved as the tail sentinel.
//! Dictionaries are the same transformation with a value payload — the
//! paper makes the identical simplification ("we refer only to sets for
//! brevity, but all our claims apply to dictionaries") — and the four
//! transformable structures now carry one: [`ConcurrentSet::put`] /
//! [`ConcurrentSet::get`] store and read a `u64` value per key, and
//! [`ConcurrentSet::scan`] / [`ConcurrentSet::count_range`] extend the
//! paper's global size predicate to key ranges (see the scan contract on
//! those methods). Competitor structures keep the value-less defaults.
//!
//! Beyond the raw `size()` (each caller pays its policy's own
//! synchronization), the trait exposes the arbiter-backed freshness API:
//! [`ConcurrentSet::size_exact`] (linearizable, concurrent callers share
//! one collect) and [`ConcurrentSet::size_recent`] (wait-free published
//! read under a bounded-staleness contract). The four transformable
//! structures override these with their embedded [`crate::size::SizeArbiter`];
//! the defaults keep external/competitor structures source-compatible.

use std::time::Duration;

use crate::size::{ArbiterStats, SizeView};

/// A point-in-time view of a structure's incremental-resize machinery
/// (`None` for structures without one). For a sharded store the fields are
/// aggregates across shards: `capacity`/`occupancy`/`resizes`/
/// `migration_pending` sum, `load_factor` is recomputed from the sums.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ResizeStats {
    /// Current bucket count (current root table generation).
    pub capacity: usize,
    /// Live keys (logical inserts minus deletes; exact at quiescence).
    pub occupancy: i64,
    /// Resizes triggered over the structure's lifetime.
    pub resizes: u64,
    /// Buckets not yet migrated to the successor table (0 when no resize
    /// is in flight).
    pub migration_pending: u64,
    /// `occupancy / capacity` — the trigger fires above
    /// [`crate::hashtable::RESIZE_CHAIN`].
    pub load_factor: f64,
}

/// Object-safe set interface used by the workload harness, so one driver
/// benches every structure/policy combination.
pub trait ConcurrentSet: Send + Sync {
    /// Insert `k`; `true` iff `k` was absent (paper: "returns a failure"
    /// otherwise).
    fn insert(&self, k: u64) -> bool;
    /// Delete `k`; `true` iff `k` was present.
    fn delete(&self, k: u64) -> bool;
    /// Membership test.
    fn contains(&self, k: u64) -> bool;
    /// The structure's `size()`, if its policy provides one. Every caller
    /// pays the policy's own synchronization (see [`Self::size_exact`]
    /// for the combining path).
    fn size(&self) -> Option<i64>;
    /// Structure name for reports (e.g. `SizeSkipList`).
    fn name(&self) -> String;

    /// Dictionary upsert: store `v` under `k`. Returns `true` iff `k` was
    /// absent (a fresh insert); storing over an existing key overwrites
    /// its value and returns `false`, so the reply contract of the wire
    /// `PUT` stays exactly the set-semantics one. Default: value-less
    /// structures ignore `v` and delegate to [`Self::insert`].
    fn put(&self, k: u64, v: u64) -> bool {
        let _ = v;
        self.insert(k)
    }

    /// Dictionary read: the value stored under `k`, `None` when absent.
    /// Default: value-less structures report membership as value `0`.
    fn get(&self, k: u64) -> Option<u64> {
        if self.contains(k) {
            Some(0)
        } else {
            None
        }
    }

    /// Range scan: every `(key, value)` pair with `lo <= key <= hi`,
    /// sorted by key. `None` when the structure does not support scans
    /// (competitor structures keep this default).
    ///
    /// **Scan contract** (what the history monitor's `check_scan`
    /// verifies): the reported *key set* is justified at a single point
    /// inside the call window — implementations validate a helping
    /// traversal with the size policy's double-collect over the update
    /// counters ([`crate::size::validated_collect`]), falling back to a
    /// per-key-justified traversal (each reported key individually live
    /// at some point in the window) under sustained contention or for
    /// policies without a calculator. Each *value* is an atomic per-key
    /// read; a concurrent overwrite may land mid-scan, exactly as an
    /// independent `get` racing the scan could observe.
    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _ = (lo, hi);
        None
    }

    /// Predicate count over a key range: `|{k in the set : lo <= k <= hi}|`,
    /// under the same justification contract as [`Self::scan`] — the
    /// paper's global size predicate restricted to a sub-range. Default:
    /// the scan's length.
    fn count_range(&self, lo: u64, hi: u64) -> Option<i64> {
        self.scan(lo, hi).map(|pairs| pairs.len() as i64)
    }

    /// Linearizable size through the structure's combining arbiter:
    /// concurrent callers register in one queue and a single underlying
    /// collect (handshake, double-collect, snapshot, ...) serves them
    /// all at one shared linearization point. Default: the raw policy
    /// size, taken directly.
    fn size_exact(&self) -> Option<SizeView> {
        self.size().map(SizeView::fresh)
    }

    /// Bounded-staleness size: a wait-free published read when a result
    /// at most `max_staleness` old exists, otherwise a fresh combining
    /// collect. The returned [`SizeView::age`] upper-bounds the true
    /// staleness. Default: falls through to [`Self::size_exact`].
    fn size_recent(&self, max_staleness: Duration) -> Option<SizeView> {
        let _ = max_staleness;
        self.size_exact()
    }

    /// O(shards) bounded-lag size estimate from the policy's sharded
    /// counter mirror: the cheapest probe the structure offers, **not**
    /// linearizable (it may trail the exact size by the number of
    /// in-flight operations; exact at quiescence). `None` when the policy
    /// has no calculator or the mirror is disabled (`SizeOpts::shards`).
    ///
    /// **Clamp contract:** a returned estimate is never negative — the
    /// mirror clamps its reconciliation sweep at zero. Admission control
    /// ([`crate::server::Admission`]) relies on this: a shed decision must
    /// never be justified by an absurd negative reading, so it re-clamps
    /// defensively and a proptest in `rust/tests/server.rs` pins both
    /// layers.
    fn size_estimate(&self) -> Option<i64> {
        None
    }

    /// Start (`Some(period)`), retune, or stop (`None`) the structure's
    /// background [`crate::size::SizeRefresher`]: an owned daemon that
    /// periodically drives the arbiter's round so `size_recent` becomes a
    /// passive published read. Returns whether a daemon is running after
    /// the call; the default (structures without an arbiter) ignores the
    /// request. The daemon is stopped and joined when the structure drops.
    fn set_refresh_period(&self, period: Option<Duration>) -> bool {
        let _ = period;
        false
    }

    /// Diagnostics from the structure's size arbiter (`None` when the
    /// structure has none).
    fn size_stats(&self) -> Option<ArbiterStats> {
        None
    }

    /// Diagnostics from the structure's incremental-resize machinery
    /// (`None` for structures with a fixed layout — only the hashtable
    /// and the sharded store over it resize today).
    fn resize_stats(&self) -> Option<ResizeStats> {
        None
    }

    /// Number of independent store shards behind this set. Monolithic
    /// structures are one shard; [`crate::shardstore::ShardStore`]
    /// overrides with its partition count. The server's per-shard
    /// admission tier sizes its watermark gates from this.
    fn store_shards(&self) -> usize {
        1
    }

    /// Which shard `key` routes to, in `[0, store_shards())`. Total and
    /// deterministic: the same key always answers the same shard for the
    /// lifetime of the structure. Monolithic structures route everything
    /// to shard 0.
    fn shard_of(&self, key: u64) -> usize {
        let _ = key;
        0
    }

    /// [`Self::size_estimate`] restricted to one shard (same clamp
    /// contract). For a monolithic structure shard 0 is the whole set;
    /// out-of-range shards answer `None`.
    fn shard_estimate(&self, shard: usize) -> Option<i64> {
        if shard == 0 {
            self.size_estimate()
        } else {
            None
        }
    }
}

/// Largest insertable key (`u64::MAX` is the tail sentinel).
pub const MAX_KEY: u64 = u64::MAX - 1;
