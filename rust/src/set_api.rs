//! The common concurrent-set interface (paper Section 2: insert / delete /
//! contains, plus the added `size`).
//!
//! All keys are `u64` with `u64::MAX` reserved as the tail sentinel.
//! Dictionaries are the same transformation with a value payload; the
//! skip-list implementation doubles as a map via [`crate::skiplist`]'s
//! value variant — the paper makes the identical simplification ("we refer
//! only to sets for brevity, but all our claims apply to dictionaries").
//!
//! Beyond the raw `size()` (each caller pays its policy's own
//! synchronization), the trait exposes the arbiter-backed freshness API:
//! [`ConcurrentSet::size_exact`] (linearizable, concurrent callers share
//! one collect) and [`ConcurrentSet::size_recent`] (wait-free published
//! read under a bounded-staleness contract). The four transformable
//! structures override these with their embedded [`crate::size::SizeArbiter`];
//! the defaults keep external/competitor structures source-compatible.

use std::time::Duration;

use crate::size::{ArbiterStats, SizeView};

/// Object-safe set interface used by the workload harness, so one driver
/// benches every structure/policy combination.
pub trait ConcurrentSet: Send + Sync {
    /// Insert `k`; `true` iff `k` was absent (paper: "returns a failure"
    /// otherwise).
    fn insert(&self, k: u64) -> bool;
    /// Delete `k`; `true` iff `k` was present.
    fn delete(&self, k: u64) -> bool;
    /// Membership test.
    fn contains(&self, k: u64) -> bool;
    /// The structure's `size()`, if its policy provides one. Every caller
    /// pays the policy's own synchronization (see [`Self::size_exact`]
    /// for the combining path).
    fn size(&self) -> Option<i64>;
    /// Structure name for reports (e.g. `SizeSkipList`).
    fn name(&self) -> String;

    /// Linearizable size through the structure's combining arbiter:
    /// concurrent callers register in one queue and a single underlying
    /// collect (handshake, double-collect, snapshot, ...) serves them
    /// all at one shared linearization point. Default: the raw policy
    /// size, taken directly.
    fn size_exact(&self) -> Option<SizeView> {
        self.size().map(SizeView::fresh)
    }

    /// Bounded-staleness size: a wait-free published read when a result
    /// at most `max_staleness` old exists, otherwise a fresh combining
    /// collect. The returned [`SizeView::age`] upper-bounds the true
    /// staleness. Default: falls through to [`Self::size_exact`].
    fn size_recent(&self, max_staleness: Duration) -> Option<SizeView> {
        let _ = max_staleness;
        self.size_exact()
    }

    /// O(shards) bounded-lag size estimate from the policy's sharded
    /// counter mirror: the cheapest probe the structure offers, **not**
    /// linearizable (it may trail the exact size by the number of
    /// in-flight operations; exact at quiescence). `None` when the policy
    /// has no calculator or the mirror is disabled (`SizeOpts::shards`).
    ///
    /// **Clamp contract:** a returned estimate is never negative — the
    /// mirror clamps its reconciliation sweep at zero. Admission control
    /// ([`crate::server::Admission`]) relies on this: a shed decision must
    /// never be justified by an absurd negative reading, so it re-clamps
    /// defensively and a proptest in `rust/tests/server.rs` pins both
    /// layers.
    fn size_estimate(&self) -> Option<i64> {
        None
    }

    /// Start (`Some(period)`), retune, or stop (`None`) the structure's
    /// background [`crate::size::SizeRefresher`]: an owned daemon that
    /// periodically drives the arbiter's round so `size_recent` becomes a
    /// passive published read. Returns whether a daemon is running after
    /// the call; the default (structures without an arbiter) ignores the
    /// request. The daemon is stopped and joined when the structure drops.
    fn set_refresh_period(&self, period: Option<Duration>) -> bool {
        let _ = period;
        false
    }

    /// Diagnostics from the structure's size arbiter (`None` when the
    /// structure has none).
    fn size_stats(&self) -> Option<ArbiterStats> {
        None
    }

    /// Number of independent store shards behind this set. Monolithic
    /// structures are one shard; [`crate::shardstore::ShardStore`]
    /// overrides with its partition count. The server's per-shard
    /// admission tier sizes its watermark gates from this.
    fn store_shards(&self) -> usize {
        1
    }

    /// Which shard `key` routes to, in `[0, store_shards())`. Total and
    /// deterministic: the same key always answers the same shard for the
    /// lifetime of the structure. Monolithic structures route everything
    /// to shard 0.
    fn shard_of(&self, key: u64) -> usize {
        let _ = key;
        0
    }

    /// [`Self::size_estimate`] restricted to one shard (same clamp
    /// contract). For a monolithic structure shard 0 is the whole set;
    /// out-of-range shards answer `None`.
    fn shard_estimate(&self, shard: usize) -> Option<i64> {
        if shard == 0 {
            self.size_estimate()
        } else {
            None
        }
    }
}

/// Largest insertable key (`u64::MAX` is the tail sentinel).
pub const MAX_KEY: u64 = u64::MAX - 1;
