//! The cluster-wide size aggregator — the arbiter's combining idea
//! applied one level up ("arbiter of arbiters").
//!
//! Each shard owns an independent [`SizeArbiter`]; the aggregator
//! composes their answers into one global reading with an explicit
//! justification story:
//!
//! * [`SizeAggregator::global_exact`] fans one collect out to every
//!   shard's arbiter and sums under a **two-phase collect**. Phase 1
//!   drives (or adopts) one exact round per shard. Phase 2 re-reads each
//!   shard's round generation and re-collects any shard whose generation
//!   moved during the sweep. Every retained per-shard value was that
//!   shard's exact size at some instant inside the aggregator call's own
//!   window, so the sum lies inside the sum of the per-shard
//!   justification intervals over that window — exactly the criterion
//!   [`crate::history::monitor::check_aggregated`] checks. (The sum is
//!   *interval-justified*, not linearizable: the per-shard instants need
//!   not coincide. That is the honest contract of a partitioned size,
//!   and the monitor's aggregated check is its oracle.)
//! * [`SizeAggregator::global_recent`] sums the EBR-published per-shard
//!   views (wait-free when every shard's view is fresh enough) and
//!   reports `age = max(per-shard ages)` — the composed staleness bound.
//!   Each shard individually honors `age <= max_staleness`, so the
//!   composed bound does too.
//! * [`SizeAggregator::global_stats`] folds per-shard [`ArbiterStats`]
//!   into one telemetry line via [`ArbiterStats::merge`].
//!
//! [`SizeArbiter`]: crate::size::SizeArbiter

use std::time::Duration;

use crate::hashtable::HashTableSet;
use crate::set_api::ConcurrentSet;
use crate::size::{ArbiterStats, SizePolicy, SizeView};

/// Borrowing view over a shard slice; obtained from
/// [`super::ShardStore::aggregator`].
pub struct SizeAggregator<'a, P: SizePolicy> {
    shards: &'a [HashTableSet<P>],
}

impl<'a, P: SizePolicy> SizeAggregator<'a, P> {
    pub(super) fn new(shards: &'a [HashTableSet<P>]) -> Self {
        debug_assert!(!shards.is_empty());
        Self { shards }
    }

    /// Exact global size under the two-phase collect (module docs). The
    /// returned view sums the values, takes the *maximum* per-shard age,
    /// sums the per-shard round numbers into a monotone aggregate
    /// generation, and is `shared` only if every shard's round was
    /// adopted rather than driven. `None` iff the policy has no size.
    pub fn global_exact(&self) -> Option<SizeView> {
        if !P::HAS_SIZE {
            return None;
        }
        let mut views = Vec::with_capacity(self.shards.len());
        // Phase 1: one exact round per shard (driven or adopted).
        for shard in self.shards {
            views.push(shard.arbiter().exact_for(shard.policy())?);
        }
        // Phase 2: any shard whose round generation moved since its
        // collect may have published a value from before this call's
        // window closed around the others — re-collect it so every
        // retained value's collect interval lies inside this call.
        for (shard, view) in self.shards.iter().zip(views.iter_mut()) {
            if shard.arbiter().rounds() != view.round {
                *view = shard.arbiter().exact_for(shard.policy())?;
            }
        }
        Some(Self::compose(&views))
    }

    /// Bounded-staleness global size: per shard, the published view when
    /// it is at most `max_staleness` old (wait-free), else a refresh
    /// through that shard's arbiter (daemon-aware, so a stalled
    /// refresher is detected and repaired per shard). The composed
    /// `age` is the maximum per-shard age and stays `<= max_staleness`
    /// by each shard's own contract.
    pub fn global_recent(&self, max_staleness: Duration) -> Option<SizeView> {
        if !P::HAS_SIZE {
            return None;
        }
        let mut views = Vec::with_capacity(self.shards.len());
        for shard in self.shards {
            views.push(shard.size_recent(max_staleness)?);
        }
        Some(Self::compose(&views))
    }

    /// Cluster-wide range scan under the same two-phase discipline as
    /// [`Self::global_exact`], keyed on the policy's update counters
    /// instead of arbiter round generations (scans have no rounds).
    /// Phase 1 pre-samples each shard's counters and collects its range.
    /// Phase 2 re-samples: a shard whose counters moved during the sweep
    /// may have answered from before the last shard's collect, so it is
    /// re-collected. Keys partition across shards, so the merged set is
    /// the union of per-shard membership snapshots each justified inside
    /// this call's window — the aggregated analogue of the monolithic
    /// scan contract, and what `check_scan_aggregated` verifies.
    ///
    /// Untracked policies have no counters ([`SizePolicy::calculator`]
    /// is `None`); their shards fall back to the per-key-justified scan
    /// and skip phase 2. `None` is impossible for a hash-table shard
    /// today but kept for [`ConcurrentSet::scan`] signature parity.
    pub fn global_scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let sample = |shard: &HashTableSet<P>| {
            shard.policy().calculator().map(|c| c.sample_counters())
        };
        let mut parts = Vec::with_capacity(self.shards.len());
        for shard in self.shards {
            let before = sample(shard);
            parts.push((before, shard.scan(lo, hi)?));
        }
        for (shard, (before, part)) in self.shards.iter().zip(parts.iter_mut()) {
            if before.is_some() && sample(shard) != *before {
                *part = shard.scan(lo, hi)?;
            }
        }
        let mut merged: Vec<(u64, u64)> = parts.into_iter().flat_map(|(_, p)| p).collect();
        merged.sort_unstable_by_key(|&(k, _)| k);
        Some(merged)
    }

    /// Cluster-wide range cardinality: the [`Self::global_scan`] key set's
    /// size, so the count is justified by the same two-phase window.
    pub fn global_count(&self, lo: u64, hi: u64) -> Option<i64> {
        self.global_scan(lo, hi).map(|pairs| pairs.len() as i64)
    }

    /// Per-shard [`ArbiterStats`] folded into one line (counters add,
    /// gauges take the max — see [`ArbiterStats::merge`]).
    pub fn global_stats(&self) -> ArbiterStats {
        self.shards
            .iter()
            .filter_map(|shard| shard.size_stats())
            .fold(ArbiterStats::default(), |acc, s| acc.merge(&s))
    }

    fn compose(views: &[SizeView]) -> SizeView {
        SizeView {
            value: views.iter().map(|v| v.value).sum(),
            age: views.iter().map(|v| v.age).max().unwrap_or(Duration::ZERO),
            round: views.iter().map(|v| v.round).sum(),
            shared: views.iter().all(|v| v.shared),
        }
    }
}
