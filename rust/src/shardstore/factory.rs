//! Monomorphizing factory for [`ShardStore`] — the sharded counterpart
//! of [`crate::bench_util::make_set_opts`], so every CLI surface
//! (`kv_server --store-shards`, the `shard_scale` ablation scenario,
//! tests) builds the same store the same way.

use crate::cli::PolicyKind;
use crate::set_api::ConcurrentSet;
use crate::size::{
    HandshakeSize, LinearizableSize, LockSize, NaiveSize, NoSize, OptimisticSize, SizeOpts,
};
use crate::MAX_THREADS;

use super::ShardStore;

/// Build a `shards`-way [`ShardStore`] of hash tables instantiated with
/// `policy`, sized for `expected` total elements. `None` if `shards` is
/// zero (callers surface `--store-shards auto|N` and `auto` resolves via
/// [`crate::size::detect_shards`] before reaching here).
pub fn make_shard_store(
    policy: PolicyKind,
    shards: usize,
    expected: usize,
    opts: SizeOpts,
) -> Option<Box<dyn ConcurrentSet>> {
    if shards == 0 {
        return None;
    }
    let t = MAX_THREADS;
    Some(match policy {
        PolicyKind::Baseline => Box::new(ShardStore::<NoSize>::new(t, shards, expected, opts)),
        PolicyKind::Linearizable => {
            Box::new(ShardStore::<LinearizableSize>::new(t, shards, expected, opts))
        }
        PolicyKind::Naive => Box::new(ShardStore::<NaiveSize>::new(t, shards, expected, opts)),
        PolicyKind::Lock => Box::new(ShardStore::<LockSize>::new(t, shards, expected, opts)),
        PolicyKind::Handshake => {
            Box::new(ShardStore::<HandshakeSize>::new(t, shards, expected, opts))
        }
        PolicyKind::Optimistic => {
            Box::new(ShardStore::<OptimisticSize>::new(t, shards, expected, opts))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_covers_every_policy() {
        for policy in PolicyKind::ALL {
            let store = make_shard_store(policy, 3, 256, SizeOpts::default().with_shards(2))
                .unwrap_or_else(|| panic!("no shard store for {policy:?}"));
            assert_eq!(store.store_shards(), 3);
            assert!(store.insert(11), "{policy:?} insert");
            assert!(store.contains(11));
            assert!(store.shard_of(11) < 3);
            if policy.provides_size() {
                assert_eq!(store.size(), Some(1), "{policy:?} aggregated size");
            } else {
                assert_eq!(store.size(), None, "{policy:?} must stay sizeless");
            }
        }
        assert!(make_shard_store(PolicyKind::Linearizable, 0, 64, SizeOpts::default()).is_none());
    }
}
