//! Sharded store subsystem: S independent hash-table shards behind one
//! [`ConcurrentSet`] face, with a cluster-wide size aggregator.
//!
//! The paper makes `size()` wait-free and O(threads) *per structure*;
//! this module is the scale step above it. The key space is partitioned
//! by [`route`] over `S` shards, each a full [`HashTableSet`] with its
//! own `Arc<SizeCore>` (policy + arbiter), sharded counter mirror and
//! [`SizeRefresher`] slot — so updates on different shards share no size
//! metadata at all, and per-shard contention is the only contention.
//! Reads of the global size go through the [`SizeAggregator`] ("arbiter
//! of arbiters"): `global_exact()` is a two-phase fan-out collect whose
//! sum is justified by overlapping per-shard linearization intervals,
//! `global_recent(d)` composes the EBR-published per-shard views under
//! `age = max(per-shard ages) <= d`, `global_scan(lo, hi)` composes the
//! per-shard validated range scans under a counter-keyed two-phase
//! sweep, and `global_stats()` merges the per-shard
//! [`crate::size::ArbiterStats`].
//!
//! The server mounts a [`ShardStore`] like any other structure (the
//! [`ConcurrentSet`] defaults `store_shards`/`shard_of`/`shard_estimate`
//! are overridden here), which is what the reactor's **two-tier
//! admission** keys off: global watermarks on the aggregate estimate,
//! plus per-shard watermarks that shed only the hot shard's `PUT`s
//! (`ERR OVERLOAD shard=<i>`), so zipfian skew degrades one shard
//! instead of the whole server.
//!
//! [`SizeRefresher`]: crate::size::SizeRefresher

mod aggregator;
mod factory;
mod route;

pub use aggregator::SizeAggregator;
pub use factory::make_shard_store;
pub use route::route;

use std::time::Duration;

use crate::hashtable::HashTableSet;
use crate::set_api::{ConcurrentSet, ResizeStats};
use crate::size::{ArbiterStats, SizeOpts, SizePolicy, SizeView};

/// `S` independent [`HashTableSet`] shards under hash routing.
pub struct ShardStore<P: SizePolicy> {
    shards: Box<[HashTableSet<P>]>,
}

impl<P: SizePolicy> ShardStore<P> {
    /// Build `shards` partitions sized for `expected` total elements
    /// (each shard's table gets `expected / shards`, floored at 16).
    /// `opts` (notably the `--size-shards` counter-mirror stripe count)
    /// applies to every shard's own size subsystem.
    ///
    /// # Panics
    /// If `shards == 0`.
    pub fn new(max_threads: usize, shards: usize, expected: usize, opts: SizeOpts) -> Self {
        assert!(shards > 0, "ShardStore needs at least one shard");
        let per_shard = (expected / shards).max(16);
        Self {
            shards: (0..shards)
                .map(|_| HashTableSet::with_opts(max_threads, per_shard, opts))
                .collect(),
        }
    }

    /// Number of shards (also [`ConcurrentSet::store_shards`]).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Direct access to shard `i` (tests, benches).
    pub fn shard(&self, i: usize) -> &HashTableSet<P> {
        &self.shards[i]
    }

    /// Where `key` lives: [`route`] over this store's shard count.
    #[inline]
    pub fn route(&self, key: u64) -> usize {
        route(key, self.shards.len())
    }

    /// The cluster-wide size aggregator over this store's shards.
    pub fn aggregator(&self) -> SizeAggregator<'_, P> {
        SizeAggregator::new(&self.shards)
    }

    /// Sum of per-shard quiescent bucket walks (test oracle; only
    /// meaningful with no concurrent updates).
    pub fn quiescent_count(&self) -> usize {
        self.shards.iter().map(|s| s.quiescent_count()).sum()
    }
}

impl<P: SizePolicy> ConcurrentSet for ShardStore<P> {
    fn insert(&self, k: u64) -> bool {
        self.shards[self.route(k)].insert(k)
    }

    fn delete(&self, k: u64) -> bool {
        self.shards[self.route(k)].delete(k)
    }

    fn contains(&self, k: u64) -> bool {
        self.shards[self.route(k)].contains(k)
    }

    fn put(&self, k: u64, v: u64) -> bool {
        self.shards[self.route(k)].put(k, v)
    }

    fn get(&self, k: u64) -> Option<u64> {
        self.shards[self.route(k)].get(k)
    }

    /// Cluster-wide range scan: per-shard validated collects composed
    /// under the aggregator's counter-keyed two-phase sweep (see
    /// [`SizeAggregator::global_scan`]).
    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        self.aggregator().global_scan(lo, hi)
    }

    fn count_range(&self, lo: u64, hi: u64) -> Option<i64> {
        self.aggregator().global_count(lo, hi)
    }

    /// The aggregated exact size (two-phase collect). Unlike a monolithic
    /// structure's `size()`, this is interval-justified rather than
    /// linearizable — see the [`aggregator`] module docs.
    fn size(&self) -> Option<i64> {
        self.aggregator().global_exact().map(|v| v.value)
    }

    fn size_exact(&self) -> Option<SizeView> {
        self.aggregator().global_exact()
    }

    fn size_recent(&self, max_staleness: Duration) -> Option<SizeView> {
        self.aggregator().global_recent(max_staleness)
    }

    /// Sum of the per-shard O(stripes) estimates; `None` if any shard's
    /// mirror is disabled. Each addend honors the never-negative clamp,
    /// so the sum does too.
    fn size_estimate(&self) -> Option<i64> {
        let mut total = 0i64;
        for shard in self.shards.iter() {
            total += shard.size_estimate()?;
        }
        Some(total)
    }

    /// Fans the period out to every shard's refresher (one daemon per
    /// shard); `true` iff every shard accepted.
    fn set_refresh_period(&self, period: Option<Duration>) -> bool {
        let mut all = true;
        for shard in self.shards.iter() {
            all &= shard.set_refresh_period(period);
        }
        all
    }

    fn size_stats(&self) -> Option<ArbiterStats> {
        Some(self.aggregator().global_stats())
    }

    /// Shards grow independently (each is its own resizable table, so a
    /// hot shard under zipfian skew doubles alone); the aggregate sums
    /// their capacities/occupancies/pending buckets and recomputes the
    /// cluster-wide load factor.
    fn resize_stats(&self) -> Option<ResizeStats> {
        let mut agg = ResizeStats::default();
        for shard in self.shards.iter() {
            let s = shard.resize_stats()?;
            agg.capacity += s.capacity;
            agg.occupancy += s.occupancy;
            agg.resizes += s.resizes;
            agg.migration_pending += s.migration_pending;
        }
        agg.load_factor = agg.occupancy as f64 / agg.capacity.max(1) as f64;
        Some(agg)
    }

    fn name(&self) -> String {
        format!(
            "ShardStore[{}x{}]",
            self.shards.len(),
            self.shards[0].name()
        )
    }

    fn store_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, key: u64) -> usize {
        self.route(key)
    }

    fn shard_estimate(&self, shard: usize) -> Option<i64> {
        self.shards.get(shard)?.size_estimate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NoSize};
    use crate::MAX_THREADS;

    fn store(shards: usize) -> ShardStore<LinearizableSize> {
        ShardStore::new(
            MAX_THREADS,
            shards,
            1 << 10,
            SizeOpts::default().with_shards(2),
        )
    }

    #[test]
    fn routes_partition_the_key_space() {
        let s = store(4);
        for k in 1..=400u64 {
            assert!(s.insert(k), "fresh key {k}");
            assert!(s.contains(k));
            // The key landed on exactly the routed shard.
            let home = s.shard_of(k);
            for i in 0..s.shards() {
                assert_eq!(s.shard(i).contains(k), i == home, "key {k} shard {i}");
            }
        }
        assert_eq!(s.size(), Some(400));
        for k in 1..=400u64 {
            assert!(s.delete(k));
        }
        assert_eq!(s.size(), Some(0));
    }

    #[test]
    fn global_exact_agrees_with_quiesced_per_shard_sum() {
        let s = store(5);
        for k in 1..=321u64 {
            s.insert(k);
        }
        let per_shard: i64 = (0..s.shards())
            .map(|i| s.shard(i).size().expect("shard size"))
            .sum();
        let global = s.aggregator().global_exact().expect("global size");
        assert_eq!(global.value, per_shard);
        assert_eq!(global.value, 321);
        assert_eq!(s.quiescent_count(), 321);
    }

    #[test]
    fn global_recent_composes_the_staleness_bound() {
        let s = store(3);
        for k in 1..=50u64 {
            s.insert(k);
        }
        let bound = Duration::from_millis(50);
        let view = s.size_recent(bound).expect("recent view");
        assert_eq!(view.value, 50);
        assert!(view.age <= bound, "age {:?} over bound {bound:?}", view.age);
    }

    #[test]
    fn estimates_and_stats_aggregate() {
        let s = store(4);
        for k in 1..=128u64 {
            s.insert(k);
        }
        // Mirror is on (2 stripes) in every shard: quiescent sum is exact.
        assert_eq!(s.size_estimate(), Some(128));
        let per_shard: i64 = (0..s.shards()).filter_map(|i| s.shard_estimate(i)).sum();
        assert_eq!(per_shard, 128);
        assert_eq!(s.shard_estimate(99), None, "out-of-range shard");
        let stats = s.size_stats().expect("aggregated stats");
        assert!(stats.rounds > 0, "exact collects must have driven rounds");
    }

    #[test]
    fn global_scan_merges_shards_in_key_order() {
        let s = store(4);
        for k in (1..=400u64).rev() {
            assert!(s.put(k, k + 1000));
        }
        let pairs = s.scan(100, 149).expect("scan");
        let want: Vec<_> = (100..=149).map(|k| (k, k + 1000)).collect();
        assert_eq!(pairs, want);
        assert_eq!(s.count_range(1, 400), Some(400));
        assert_eq!(s.scan(400, 1), Some(vec![]), "inverted range is empty");
        // Overwrite routes to the same shard the key lives on.
        assert!(!s.put(123, 7), "upsert over existing key reports 0");
        assert_eq!(s.get(123), Some(7));
        assert_eq!(s.get(401), None);
        assert!(s.delete(123));
        assert_eq!(s.count_range(100, 149), Some(49));
    }

    #[test]
    fn shards_grow_independently_under_skew() {
        // Tiny shards so a hot-shard insert burst crosses the load-factor
        // threshold: only shards actually holding keys double.
        let s: ShardStore<LinearizableSize> =
            ShardStore::new(MAX_THREADS, 4, 16, SizeOpts::default());
        let caps_before: Vec<_> = (0..4).map(|i| s.shard(i).capacity()).collect();
        // Load one shard ~50x past its threshold; route() finds the keys.
        let hot = s.shard_of(1);
        let mut loaded = 0;
        for k in 1..=20_000u64 {
            if s.shard_of(k) == hot {
                assert!(s.insert(k));
                loaded += 1;
                if loaded == 800 {
                    break;
                }
            }
        }
        s.shard(hot).finish_migration();
        assert!(s.shard(hot).resizes() >= 1, "hot shard never grew");
        assert!(s.shard(hot).capacity() > caps_before[hot]);
        for i in 0..4 {
            if i != hot {
                assert_eq!(s.shard(i).capacity(), caps_before[i], "cold shard {i} grew");
            }
        }
        let rs = s.resize_stats().expect("aggregated resize stats");
        assert_eq!(rs.occupancy, loaded as i64);
        assert_eq!(rs.resizes, s.shard(hot).resizes());
        assert_eq!(rs.migration_pending, 0);
        assert_eq!(
            rs.capacity,
            (0..4).map(|i| s.shard(i).capacity()).sum::<usize>()
        );
        // Every key survived the hot shard's migrations.
        let mut found = 0;
        for k in 1..=20_000u64 {
            if s.contains(k) {
                found += 1;
            }
        }
        assert_eq!(found, loaded);
    }

    #[test]
    fn sizeless_policy_answers_none_but_still_counts_shards() {
        let s: ShardStore<NoSize> = ShardStore::new(MAX_THREADS, 3, 64, SizeOpts::default());
        assert!(s.insert(7));
        assert_eq!(s.size(), None);
        assert_eq!(s.size_exact(), None);
        assert_eq!(s.size_recent(Duration::from_millis(5)), None);
        assert_eq!(s.store_shards(), 3);
        assert!(s.size_stats().is_some(), "stats stay present for telemetry");
    }

    #[test]
    fn refresher_fans_out_to_every_shard() {
        let s = store(2);
        for k in 1..=10u64 {
            s.insert(k);
        }
        assert!(s.set_refresh_period(Some(Duration::from_millis(1))));
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            let stats = s.size_stats().unwrap();
            // Every shard runs its own daemon; together they must drive
            // at least one round each (merged counter >= shard count).
            if stats.daemon_rounds >= 2 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "daemons drove no rounds"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(
            !s.set_refresh_period(None),
            "stopped daemons report not-running"
        );
    }
}
