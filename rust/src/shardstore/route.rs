//! Deterministic key → shard routing.
//!
//! The router is a pure function of `(key, shard_count)`: no per-store
//! state, no randomization, so the reactor, the admission tier, tests and
//! external clients all agree on where a key lives for the lifetime of a
//! store. Keys are pre-mixed with the Fibonacci multiplier (the same
//! spreader the hash table uses for buckets) so dense key ranges — the
//! workload generator hands out `1..=r` — do not stripe across shards in
//! lockstep with the table's own bucket choice.

/// Which of `shards` partitions `key` routes to. Total (every `u64`
/// answers) and stable (same inputs, same answer, on every call site).
///
/// # Panics
/// Debug-asserts `shards > 0`; release builds with `shards == 0` would
/// divide by zero, so the store constructor rejects that earlier.
#[inline]
pub fn route(key: u64, shards: usize) -> usize {
    debug_assert!(shards > 0, "route: no shards to route to");
    // Fibonacci spread, then a high-bits fold: the multiplier alone maps
    // consecutive keys to consecutive strides, which `% shards` would
    // turn back into a round-robin — fine for balance, but correlated
    // with the per-shard table's own spreader. The xor-shift decorrelates.
    let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let h = h ^ (h >> 29);
    (h % shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_is_total_and_in_range() {
        for shards in [1usize, 2, 3, 4, 7, 8, 64] {
            for key in (0..1000u64).chain([u64::MAX - 1, u64::MAX / 2]) {
                assert!(route(key, shards) < shards);
            }
        }
    }

    #[test]
    fn route_is_stable() {
        for key in 0..512u64 {
            assert_eq!(route(key, 6), route(key, 6));
        }
    }

    #[test]
    fn route_spreads_a_dense_range() {
        // The workload draws keys from a dense `1..=r`; every shard must
        // see a healthy fraction of them (no empty or dominant shard).
        let shards = 8;
        let mut counts = vec![0usize; shards];
        let total = 8000u64;
        for key in 1..=total {
            counts[route(key, shards)] += 1;
        }
        let expect = total as usize / shards;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                c > expect / 2 && c < expect * 2,
                "shard {i} got {c}/{total} keys (expected ~{expect})"
            );
        }
    }

    #[test]
    fn single_shard_routes_everything_to_zero() {
        for key in [0u64, 1, 42, u64::MAX] {
            assert_eq!(route(key, 1), 0);
        }
    }
}
