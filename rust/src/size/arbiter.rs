//! The size arbiter: a combining front-end over any [`SizePolicy`]'s
//! `size()`, plus a published last-result channel for wait-free
//! bounded-staleness reads.
//!
//! ## Why
//!
//! Every policy in this crate makes each `size()` caller pay for its own
//! synchronization: the paper's wait-free method re-runs (or joins) a
//! counter collect per call, `OptimisticSize` re-runs its double-collect,
//! and `HandshakeSize` callers *serialize behind a mutex and freeze the
//! structure once each* — so a size-hammering workload (the `kv_server`
//! `SIZE` endpoint under load) collapses exactly where it should scale.
//! The synchronization-methods study (arXiv 2506.16350) names the fix:
//! batch concurrent size calls behind one collect, and publish the result
//! so readers that tolerate bounded staleness never synchronize at all —
//! the announce-and-share structure of linearizable-iterator frameworks
//! (Agarwal et al., arXiv 1705.08885) applied to a single scalar.
//!
//! ## Protocol
//!
//! `size_exact(collect)` is a *combining* linearizable size:
//!
//! 1. A caller registers by reading `round_started` (its **ticket**).
//! 2. It tries to become the **combiner** (`try_lock`; waiters never
//!    block on the lock). The combiner optionally dwells for
//!    [`SizeArbiter::set_combine_window`] so concurrent callers can pile
//!    on, bumps `round_started`, runs the underlying collect **once**,
//!    swaps the result into `published` (EBR-reclaimed), and bumps
//!    `round_done`.
//! 3. A caller that observes `round_done > ticket` *adopts* the
//!    published result instead of collecting. Correctness: the round
//!    that raised `round_done` above the ticket incremented
//!    `round_started` after the ticket was read (the counter is
//!    monotone), so its collect — and hence its linearization point —
//!    lies inside the adopter's call window. Adopted reads are
//!    linearizable, and N concurrent callers cost one collect.
//!
//! `size_recent(max_staleness, collect)` reads `published` under an EBR
//! pin — one wait-free load. Results are stamped at **round start**
//! (before the collect), so `age` over-approximates true staleness and
//! the bound is conservative. Only when the published result is older
//! than `max_staleness` (or absent) does the call fall into the
//! `size_exact` path.

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};
use std::sync::{Mutex, MutexGuard, TryLockError};
use std::time::{Duration, Instant};

use crate::ebr;
use crate::faults::{self, FaultSite};

use super::policy::SizePolicy;
use super::spin_backoff;

/// One size reading plus its freshness provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SizeView {
    /// The size value.
    pub value: i64,
    /// Upper bound on the reading's staleness: zero for a linearizable
    /// read (the linearization point lies inside the call), positive for
    /// a published `size_recent` hit (stamped at the producing round's
    /// start, so true staleness is never larger).
    pub age: Duration,
    /// Arbiter round that produced the value (0 = taken outside any
    /// arbiter, e.g. through the default [`ConcurrentSet`] path).
    ///
    /// [`ConcurrentSet`]: crate::set_api::ConcurrentSet
    pub round: u64,
    /// Whether another caller's collect served this reading.
    pub shared: bool,
}

impl SizeView {
    /// A reading taken directly by the caller: fresh by construction.
    pub fn fresh(value: i64) -> Self {
        Self {
            value,
            age: Duration::ZERO,
            round: 0,
            shared: false,
        }
    }
}

/// Arbiter diagnostics (the ablation bench records these). The last three
/// fields come from outside the arbiter proper — the structure's
/// `size_stats()` merges in its [`SizeRefresher`] round count and the
/// policy's [`SizeTuning`] — so one struct carries the whole size-path
/// telemetry.
///
/// [`SizeRefresher`]: super::SizeRefresher
/// [`SizeTuning`]: super::SizeTuning
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArbiterStats {
    /// Combine rounds performed — each is exactly one underlying collect
    /// (one handshake, one double-collect, ...).
    pub rounds: u64,
    /// `size_exact` calls served by another caller's round.
    pub adoptions: u64,
    /// `size_recent` calls satisfied wait-free from the published result.
    pub recent_hits: u64,
    /// `size_recent` calls that were too stale and ran/joined a round.
    pub recent_refreshes: u64,
    /// Rounds driven by the structure's background `SizeRefresher`
    /// (0 when no daemon ran).
    pub daemon_rounds: u64,
    /// `size_recent` calls that had to drive a direct arbiter round even
    /// though a refresher daemon with `period <= max_staleness` was
    /// configured — i.e. the daemon stalled (or had not published yet)
    /// and the caller self-healed by collecting (0 when no daemon ran).
    pub daemon_stalls: u64,
    /// Policy-level size fallbacks (`OptimisticSize`; 0 otherwise).
    pub fallbacks: u64,
    /// Policy-level current retry budget (`OptimisticSize`; 0 otherwise).
    pub retry_budget: u64,
    /// Hashtable resizes triggered (0 for non-resizable structures);
    /// merged in by the structure's `size_stats()` like the daemon fields.
    pub resizes: u64,
    /// Buckets still awaiting migration across in-flight resizes (0 when
    /// no migration is running — the resize-stress CI gate asserts this
    /// drains).
    pub migration_pending: u64,
}

impl ArbiterStats {
    /// Fold another arbiter's telemetry into this one — the
    /// [`crate::shardstore::SizeAggregator`] composes per-shard stats
    /// into one cluster-wide line this way. Counters add; `retry_budget`
    /// is a gauge, so the merge keeps the maximum.
    pub fn merge(&self, other: &ArbiterStats) -> ArbiterStats {
        ArbiterStats {
            rounds: self.rounds + other.rounds,
            adoptions: self.adoptions + other.adoptions,
            recent_hits: self.recent_hits + other.recent_hits,
            recent_refreshes: self.recent_refreshes + other.recent_refreshes,
            daemon_rounds: self.daemon_rounds + other.daemon_rounds,
            daemon_stalls: self.daemon_stalls + other.daemon_stalls,
            fallbacks: self.fallbacks + other.fallbacks,
            retry_budget: self.retry_budget.max(other.retry_budget),
            resizes: self.resizes + other.resizes,
            migration_pending: self.migration_pending + other.migration_pending,
        }
    }
}

/// The published result of one combine round.
struct Published {
    value: i64,
    round: u64,
    /// Nanoseconds since the arbiter's origin, stamped at round *start*.
    at_nanos: u64,
}

pub struct SizeArbiter {
    origin: Instant,
    /// Rounds started: bumped by each combiner *before* it collects.
    /// A caller's ticket is a load of this counter; monotonicity is what
    /// makes adopted results linearizable (see module docs).
    round_started: AtomicU64,
    /// Rounds completed; trails `round_started` by at most one (the lock
    /// serializes combiners).
    round_done: AtomicU64,
    /// Latest result (null until the first round); EBR-reclaimed.
    published: AtomicPtr<Published>,
    /// Combiner election. Waiters only ever `try_lock`, so nobody blocks
    /// on it — they spin on `round_done` and adopt.
    combine_lock: Mutex<()>,
    /// Combiner dwell before collecting, in nanos (0 = collect at once).
    combine_window: AtomicU64,
    adoptions: AtomicU64,
    recent_hits: AtomicU64,
    recent_refreshes: AtomicU64,
    daemon_stalls: AtomicU64,
}

impl Default for SizeArbiter {
    fn default() -> Self {
        Self::new()
    }
}

impl SizeArbiter {
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
            round_started: AtomicU64::new(0),
            round_done: AtomicU64::new(0),
            published: AtomicPtr::new(std::ptr::null_mut()),
            combine_lock: Mutex::new(()),
            combine_window: AtomicU64::new(0),
            adoptions: AtomicU64::new(0),
            recent_hits: AtomicU64::new(0),
            recent_refreshes: AtomicU64::new(0),
            daemon_stalls: AtomicU64::new(0),
        }
    }

    /// Batched/amortized collects: make each combiner dwell for `window`
    /// before collecting so concurrent callers can register and share the
    /// round. Off by default (latency-neutral); size-hammering servers
    /// trade a bounded latency bump for a large drop in collect count.
    pub fn set_combine_window(&self, window: Duration) {
        self.combine_window.store(window.as_nanos() as u64, SeqCst);
    }

    pub fn stats(&self) -> ArbiterStats {
        ArbiterStats {
            rounds: self.round_done.load(SeqCst),
            adoptions: self.adoptions.load(SeqCst),
            recent_hits: self.recent_hits.load(SeqCst),
            recent_refreshes: self.recent_refreshes.load(SeqCst),
            daemon_rounds: 0,
            daemon_stalls: self.daemon_stalls.load(SeqCst),
            fallbacks: 0,
            retry_budget: 0,
            resizes: 0,
            migration_pending: 0,
        }
    }

    /// Completed combine rounds so far.
    pub fn rounds(&self) -> u64 {
        self.round_done.load(SeqCst)
    }

    /// The latest published result, with its age measured now (`None`
    /// before the first round). A pure read: touches no round state and
    /// records no stats — the refresher uses it to skip redundant rounds,
    /// tests use it to observe publication.
    pub fn published_view(&self) -> Option<SizeView> {
        let _pin = ebr::pin();
        unsafe { self.published.load(SeqCst).as_ref() }.map(|p| {
            let now = self.origin.elapsed().as_nanos() as u64;
            SizeView {
                value: p.value,
                age: Duration::from_nanos(now.saturating_sub(p.at_nanos)),
                round: p.round,
                shared: true,
            }
        })
    }

    /// Age of the latest published result (`None` before the first round).
    pub fn published_age(&self) -> Option<Duration> {
        self.published_view().map(|v| v.age)
    }

    /// Poison-tolerant `try_lock` (a panicking combiner must not wedge
    /// every future size call into the spin loop).
    fn try_combine_lock(&self) -> Option<MutexGuard<'_, ()>> {
        match self.combine_lock.try_lock() {
            Ok(g) => Some(g),
            Err(TryLockError::Poisoned(p)) => Some(p.into_inner()),
            Err(TryLockError::WouldBlock) => None,
        }
    }

    /// Linearizable size with combining: at most one underlying `collect`
    /// runs per round, no matter how many callers arrive concurrently.
    /// The returned view has `age == 0`; `shared` says whether this call
    /// adopted another caller's round.
    ///
    /// Contract: do **not** call while holding a policy op guard. The
    /// combiner's collect may need every in-flight operation to drain
    /// (handshake) or take a write lock (lock policy); a guard-holding
    /// waiter would then wedge the round. Structure operations never call
    /// size internally, so this only concerns direct policy-API users.
    pub fn size_exact(&self, collect: impl FnOnce() -> i64) -> SizeView {
        let ticket = self.round_started.load(SeqCst);
        let mut collect = Some(collect);
        let mut spins = 0u32;
        loop {
            if self.round_done.load(SeqCst) > ticket {
                // A round that started after our registration completed:
                // its published value is linearizable within our window
                // (any even-newer value in `published` started later
                // still — also fine).
                let _pin = ebr::pin();
                let p = unsafe { self.published.load(SeqCst).as_ref() }
                    .expect("round_done > 0 implies a published result");
                self.adoptions.fetch_add(1, Relaxed);
                return SizeView {
                    value: p.value,
                    age: Duration::ZERO,
                    round: p.round,
                    shared: true,
                };
            }
            if let Some(_lock) = self.try_combine_lock() {
                if self.round_done.load(SeqCst) > ticket {
                    // Satisfied while we raced for the lock; adopt above.
                    continue;
                }
                // We are the combiner: dwell first so late arrivals can
                // join this round, then stamp. The stamp precedes the
                // collect (whose linearization point dates the value), so
                // `age` stays a conservative staleness bound — without
                // baking the dwell into every published result's age.
                faults::jitter(FaultSite::ArbiterRoundStart);
                let window = self.combine_window.load(Relaxed);
                if window > 0 {
                    std::thread::sleep(Duration::from_nanos(window));
                }
                let at_nanos = self.origin.elapsed().as_nanos() as u64;
                // The ticketing point comes AFTER the dwell: callers that
                // arrived during it still hold tickets below `started`,
                // so this round satisfies them — that is what lets the
                // dwell recruit a batch. It must stay BEFORE the collect:
                // adopters rely on the collect (and its linearization
                // point) starting after their ticket load.
                let started = self.round_started.fetch_add(1, SeqCst) + 1;
                let value = (collect.take().expect("combiner runs once"))();
                faults::jitter(FaultSite::ArbiterPublish);
                let fresh = Box::into_raw(Box::new(Published {
                    value,
                    round: started,
                    at_nanos,
                }));
                let old = self.published.swap(fresh, SeqCst);
                self.round_done.store(started, SeqCst);
                if !old.is_null() {
                    // Unreachable through `published` after the swap;
                    // pinned readers are protected by EBR's grace period.
                    let _pin = ebr::pin();
                    unsafe { ebr::retire(old) };
                }
                return SizeView {
                    value,
                    age: Duration::ZERO,
                    round: started,
                    shared: false,
                };
            }
            // A combiner is collecting on our behalf; wait for its round.
            spin_backoff(spins);
            spins = spins.saturating_add(1);
        }
    }

    /// Bounded-staleness size: one wait-free EBR-pinned load when the
    /// published result is at most `max_staleness` old, otherwise a fresh
    /// (combining) collect. The returned `age` upper-bounds the true
    /// staleness and never exceeds `max_staleness`. A zero bound always
    /// refreshes (a same-clock-tick publish would otherwise be
    /// indistinguishable from an exact read on coarse monotonic clocks).
    pub fn size_recent(&self, max_staleness: Duration, collect: impl FnOnce() -> i64) -> SizeView {
        self.size_recent_inner(max_staleness, collect).0
    }

    /// [`Self::size_recent`] plus whether the call had to refresh (fall
    /// into the `size_exact` path) — the signal behind refresher-stall
    /// detection in [`Self::recent_for_daemon`].
    fn size_recent_inner(
        &self,
        max_staleness: Duration,
        collect: impl FnOnce() -> i64,
    ) -> (SizeView, bool) {
        if !max_staleness.is_zero() {
            let _pin = ebr::pin();
            if let Some(p) = unsafe { self.published.load(SeqCst).as_ref() } {
                let now = self.origin.elapsed().as_nanos() as u64;
                let age = Duration::from_nanos(now.saturating_sub(p.at_nanos));
                if age <= max_staleness {
                    self.recent_hits.fetch_add(1, Relaxed);
                    return (
                        SizeView {
                            value: p.value,
                            age,
                            round: p.round,
                            shared: true,
                        },
                        false,
                    );
                }
            }
        }
        self.recent_refreshes.fetch_add(1, Relaxed);
        (self.size_exact(collect), true)
    }

    /// [`Self::size_exact`] wired to a policy: `None` for size-less
    /// policies, so every structure exposes the API identically.
    pub fn exact_for<P: SizePolicy>(&self, policy: &P) -> Option<SizeView> {
        if !P::HAS_SIZE {
            return None;
        }
        Some(self.size_exact(|| policy.size().expect("HAS_SIZE policy returned no size")))
    }

    /// [`Self::size_recent`] wired to a policy (see [`Self::exact_for`]).
    pub fn recent_for<P: SizePolicy>(
        &self,
        policy: &P,
        max_staleness: Duration,
    ) -> Option<SizeView> {
        self.recent_for_daemon(policy, max_staleness, None)
    }

    /// [`Self::recent_for`] with refresher-stall detection: when a
    /// refresher daemon with `period <= max_staleness` is configured, a
    /// published result fresh enough for the caller should always exist —
    /// having to drive a direct round means the daemon stalled, and the
    /// `daemon_stalls` gauge records the self-healing fallback.
    pub fn recent_for_daemon<P: SizePolicy>(
        &self,
        policy: &P,
        max_staleness: Duration,
        daemon_period: Option<Duration>,
    ) -> Option<SizeView> {
        if !P::HAS_SIZE {
            return None;
        }
        let (view, refreshed) = self.size_recent_inner(max_staleness, || {
            policy.size().expect("HAS_SIZE policy returned no size")
        });
        if refreshed && daemon_period.is_some_and(|p| p <= max_staleness) {
            self.daemon_stalls.fetch_add(1, Relaxed);
        }
        Some(view)
    }
}

impl Drop for SizeArbiter {
    fn drop(&mut self) {
        let p = *self.published.get_mut();
        if !p.is_null() {
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn sequential_exact_rounds_and_values() {
        let a = SizeArbiter::new();
        let v = a.size_exact(|| 7);
        assert_eq!(v.value, 7);
        assert_eq!(v.round, 1);
        assert!(!v.shared);
        assert_eq!(v.age, Duration::ZERO);
        let v2 = a.size_exact(|| 9);
        assert_eq!((v2.value, v2.round), (9, 2));
        assert_eq!(a.stats().rounds, 2);
        assert_eq!(a.stats().adoptions, 0);
    }

    #[test]
    fn recent_hits_published_without_new_round() {
        let a = SizeArbiter::new();
        a.size_exact(|| 42);
        for _ in 0..50 {
            let v = a.size_recent(Duration::from_secs(60), || panic!("must not collect"));
            assert_eq!(v.value, 42);
            assert_eq!(v.round, 1);
            assert!(v.shared);
            assert!(v.age <= Duration::from_secs(60));
        }
        let s = a.stats();
        assert_eq!(s.rounds, 1, "hits must not start rounds");
        assert_eq!(s.recent_hits, 50);
        assert_eq!(s.recent_refreshes, 0);
    }

    #[test]
    fn recent_refreshes_when_stale_or_unpublished() {
        let a = SizeArbiter::new();
        // Nothing published yet: must collect.
        let v = a.size_recent(Duration::from_secs(60), || 5);
        assert_eq!((v.value, v.round), (5, 1));
        assert_eq!(v.age, Duration::ZERO);
        std::thread::sleep(Duration::from_millis(3));
        // Published result now older than the bound: must re-collect.
        let v2 = a.size_recent(Duration::from_micros(1), || 6);
        assert_eq!((v2.value, v2.round), (6, 2));
        assert_eq!(a.stats().recent_refreshes, 2);
    }

    #[test]
    fn concurrent_exact_callers_share_rounds() {
        let a = Arc::new(SizeArbiter::new());
        // Dwell long enough that hammering threads must overlap a round.
        a.set_combine_window(Duration::from_micros(800));
        let collects = Arc::new(AtomicU64::new(0));
        const THREADS: usize = 4;
        const CALLS: u64 = 25;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let a = a.clone();
                let collects = collects.clone();
                std::thread::spawn(move || {
                    for _ in 0..CALLS {
                        let v = a.size_exact(|| {
                            collects.fetch_add(1, SeqCst);
                            11
                        });
                        assert_eq!(v.value, 11);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let total = THREADS as u64 * CALLS;
        let s = a.stats();
        assert_eq!(s.rounds, collects.load(SeqCst), "one collect per round");
        assert!(
            s.rounds < total,
            "combining failed: {} rounds for {} calls",
            s.rounds,
            total
        );
        assert!(s.adoptions > 0, "no caller ever shared a round");
        assert_eq!(s.rounds + s.adoptions, total);
    }

    #[test]
    fn adopted_round_starts_inside_callers_window() {
        // A round completed entirely BEFORE the call registers must never
        // be adopted: a fresh exact call after quiescence re-collects.
        let a = SizeArbiter::new();
        assert_eq!(a.size_exact(|| 1).round, 1);
        let v = a.size_exact(|| 2);
        assert_eq!(v.round, 2, "stale round adopted");
        assert_eq!(v.value, 2);
    }

    #[test]
    fn stats_start_zeroed() {
        assert_eq!(SizeArbiter::new().stats(), ArbiterStats::default());
    }

    #[test]
    fn daemon_stalls_count_only_broken_freshness_promises() {
        use crate::size::{LinearizableSize, SizeOpts};
        let a = SizeArbiter::new();
        let p = LinearizableSize::new(4, SizeOpts::default());
        let bound = Duration::from_millis(50);
        // Nothing published though a fast daemon is configured: stall.
        a.recent_for_daemon(&p, bound, Some(Duration::from_millis(5)));
        assert_eq!(a.stats().daemon_stalls, 1);
        // Fresh published hit: no stall.
        a.recent_for_daemon(&p, Duration::from_secs(60), Some(Duration::from_millis(5)));
        assert_eq!(a.stats().daemon_stalls, 1);
        // Refresh with no daemon configured: no promise broken.
        std::thread::sleep(Duration::from_millis(3));
        a.recent_for_daemon(&p, Duration::from_micros(1), None);
        assert_eq!(a.stats().daemon_stalls, 1);
        // Daemon slower than the caller's bound: no promise either.
        std::thread::sleep(Duration::from_millis(3));
        a.recent_for_daemon(&p, Duration::from_millis(1), Some(Duration::from_secs(1)));
        assert_eq!(a.stats().daemon_stalls, 1);
        // Stale publish while a fast daemon should have refreshed: stall.
        std::thread::sleep(Duration::from_millis(3));
        a.recent_for_daemon(
            &p,
            Duration::from_millis(1),
            Some(Duration::from_micros(100)),
        );
        assert_eq!(a.stats().daemon_stalls, 2);
    }

    #[test]
    fn published_view_tracks_rounds_without_stats_noise() {
        let a = SizeArbiter::new();
        assert_eq!(a.published_view(), None);
        assert_eq!(a.published_age(), None);
        a.size_exact(|| 13);
        let v = a.published_view().expect("round published");
        assert_eq!((v.value, v.round, v.shared), (13, 1, true));
        std::thread::sleep(Duration::from_millis(2));
        assert!(a.published_age().unwrap() >= Duration::from_millis(2));
        let s = a.stats();
        assert_eq!(
            (s.recent_hits, s.recent_refreshes, s.adoptions),
            (0, 0, 0),
            "published_view must record no stats"
        );
    }
}
