//! `SizeCalculator` — paper Figure 5, line-by-line.
//!
//! Holds the size metadata (one cache-padded (insertions, deletions)
//! counter pair per thread, paper Section 5) and the currently-announced
//! [`CountersSnapshot`]. Replaced snapshot instances are retired through
//! [`crate::ebr`] (the Java original relies on the GC for this), keeping
//! `compute` wait-free and `update_metadata` constant-time.

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering::SeqCst};

use crate::pad::CachePadded;

use super::{CountersSnapshot, OpKind, ShardedCounters, UpdateInfo};
use crate::ebr;
use crate::faults::{self, FaultSite};

/// Optimization toggles (paper Section 7); all enabled by default, exposed
/// for the `ablation_opts` bench — plus the sharded-mirror scale knob.
#[derive(Clone, Copy, Debug)]
pub struct SizeOpts {
    /// §7.1 — clear a node's insert-info slot once its insert is reflected,
    /// sparing every later operation on the node a metadata check.
    pub clear_insert_info: bool,
    /// §7.2 — exponential backoff before competing on an adopted
    /// `CountersSnapshot`'s collection.
    pub backoff: bool,
    /// §7.3 — return an already-agreed size early instead of re-collecting.
    pub early_size_check: bool,
    /// Stripe count of the sharded counter mirror behind
    /// [`SizeCalculator::approx_size`] (`0` = mirror disabled, the
    /// default — the paper path stays bit-identical). CLI surfaces set
    /// this from `--size-shards` (`auto` = [`super::detect_shards`]).
    pub shards: usize,
}

impl Default for SizeOpts {
    fn default() -> Self {
        Self {
            clear_insert_info: true,
            backoff: true,
            early_size_check: true,
            shards: 0,
        }
    }
}

impl SizeOpts {
    pub const NONE: SizeOpts = SizeOpts {
        clear_insert_info: false,
        backoff: false,
        early_size_check: false,
        shards: 0,
    };

    /// `self` with the sharded mirror set to `shards` stripes.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }
}

/// Bounded backoff: at most `ROUNDS` waits of up to `MAX_SPINS` spin hints,
/// preserving wait-freedom of `compute`.
const BACKOFF_ROUNDS: u32 = 6;
const BACKOFF_MAX_SPINS: u32 = 512;

pub struct SizeCalculator {
    /// `metadataCounters[tid] = [insertions, deletions]`, padded so each
    /// thread's pair sits in its own cache line (paper Section 6.1).
    metadata: Box<[CachePadded<[AtomicU64; 2]>]>,
    /// The most recent `CountersSnapshot` (paper Fig. 4). Old instances are
    /// EBR-retired on replacement.
    counters_snapshot: AtomicPtr<CountersSnapshot>,
    /// Optional striped mirror of the metadata (see `sharded.rs`): kept in
    /// sync at the exactly-once counter-CAS win, read by [`Self::approx_size`].
    sharded: Option<ShardedCounters>,
    opts: SizeOpts,
    nthreads: usize,
}

impl SizeCalculator {
    /// Paper Fig. 5 lines 53–56: zeroed counters plus a dummy non-collecting
    /// snapshot so the first `size()` announces a fresh one.
    pub fn new(nthreads: usize, opts: SizeOpts) -> Self {
        let dummy = Box::new(CountersSnapshot::new(nthreads));
        dummy.collecting.store(false, SeqCst);
        Self {
            metadata: (0..nthreads)
                .map(|_| CachePadded::new([AtomicU64::new(0), AtomicU64::new(0)]))
                .collect(),
            counters_snapshot: AtomicPtr::new(Box::into_raw(dummy)),
            sharded: (opts.shards > 0).then(|| ShardedCounters::new(opts.shards)),
            opts,
            nthreads,
        }
    }

    #[inline]
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    #[inline]
    pub fn opts(&self) -> SizeOpts {
        self.opts
    }

    /// Paper Fig. 5 lines 57–61 (+ §7.2/§7.3): the wait-free `size()`.
    /// O(nthreads); the caller's thread must be EBR-safe (we pin
    /// internally, so any call site is fine).
    pub fn compute(&self) -> i64 {
        let _g = ebr::pin();
        let (active, adopted) = self.obtain_collecting_counters_snapshot();

        // §7.3: a size agreed by a concurrent compute is ours too.
        if self.opts.early_size_check {
            if let Some(s) = active.agreed_size() {
                return s;
            }
        }
        // §7.2: if we adopted an instance announced by another size call,
        // give it bounded time to finish before contending on the CASes.
        if adopted && self.opts.backoff {
            let mut spins = 8u32;
            for _ in 0..BACKOFF_ROUNDS {
                if let Some(s) = active.agreed_size() {
                    return s;
                }
                for _ in 0..spins {
                    std::hint::spin_loop();
                }
                spins = (spins * 2).min(BACKOFF_MAX_SPINS);
            }
        }

        self.collect(active); // line 59
        active.collecting.store(false, SeqCst); // line 60: linearization pt
        active.compute_size(self.opts.early_size_check) // line 61
    }

    /// Paper Fig. 5 lines 62–70. Returns the collecting instance plus
    /// whether it was announced by someone else (`adopted`, for §7.2).
    ///
    /// Safety: returned reference is valid while the caller's EBR pin is
    /// held — instances are only freed two epochs after replacement.
    fn obtain_collecting_counters_snapshot(&self) -> (&CountersSnapshot, bool) {
        let current = self.counters_snapshot.load(SeqCst);
        let current_ref = unsafe { &*current };
        if current_ref.is_collecting() {
            return (current_ref, true); // line 64–65
        }
        let fresh = Box::into_raw(Box::new(CountersSnapshot::new(self.nthreads)));
        match self
            .counters_snapshot
            .compare_exchange(current, fresh, SeqCst, SeqCst)
        {
            Ok(_) => {
                // We replaced `current`; nobody can reach it anymore through
                // the calculator, but pinned readers may still hold it.
                unsafe { ebr::retire(current) };
                (unsafe { &*fresh }, false) // lines 68–69
            }
            Err(witnessed) => {
                // Our instance was never published: free it immediately.
                drop(unsafe { Box::from_raw(fresh) });
                (unsafe { &*witnessed }, true) // line 70
            }
        }
    }

    /// Paper Fig. 5 lines 71–74.
    fn collect(&self, target: &CountersSnapshot) {
        for tid in 0..self.nthreads {
            for kind in [OpKind::Insert, OpKind::Delete] {
                target.add(tid, kind, self.metadata[tid][kind as usize].load(SeqCst));
            }
        }
    }

    /// Paper Fig. 5 lines 75–83: make the metadata reflect `info`'s
    /// operation (idempotent — callable by the initiator and any helper),
    /// then forward to a concurrent collection if one might have missed it.
    ///
    /// Constant time: the counter CAS runs at most once and `forward` loops
    /// at most twice (paper Claim 8.4).
    pub fn update_metadata(&self, packed: u64, kind: OpKind) {
        debug_assert_ne!(packed, 0);
        let UpdateInfo { tid, counter } = UpdateInfo::unpack(packed);
        let cell = &self.metadata[tid][kind as usize];

        // Lines 78–79: reflect the operation (exactly-once via monotone CAS).
        // The CAS winner — initiator or helper, whoever lands it — also
        // bumps the sharded mirror, preserving exactly-once for the stripes.
        // Fault sites bracket the CAS: widening the load→CAS window races
        // helpers against the initiator; delaying after a win stretches
        // the gap before the mirror sync and the forwarding check.
        faults::jitter(FaultSite::PreCounterCas);
        if cell.load(SeqCst) == counter - 1
            && cell.compare_exchange(counter - 1, counter, SeqCst, SeqCst).is_ok()
        {
            faults::jitter(FaultSite::PostCounterCas);
            if let Some(sharded) = &self.sharded {
                sharded.record(tid, kind);
            }
        }

        // Lines 80–83: forward to an ongoing collection. The check order
        // (obtain snapshot → still collecting → counter still current) is
        // what bounds `forward` to two iterations (§8.2).
        //
        // The snapshot deref needs an EBR pin; every data-structure call
        // site already holds one (operations pin on entry), so this is a
        // single Cell read on the hot path instead of a fresh pin.
        let _g = if ebr::is_pinned() { None } else { Some(ebr::pin()) };
        let snap = unsafe { &*self.counters_snapshot.load(SeqCst) };
        if snap.is_collecting() && cell.load(SeqCst) == counter {
            snap.forward(tid, kind, counter);
        }
    }

    /// Paper Fig. 5 lines 84–85: the info the calling thread's upcoming
    /// `kind` operation publishes for helpers.
    pub fn create_update_info(&self, kind: OpKind, tid: usize) -> u64 {
        let counter = self.metadata[tid][kind as usize].load(SeqCst) + 1;
        UpdateInfo { tid, counter }.pack()
    }

    /// The sharded counter mirror, when `SizeOpts::shards` enabled one.
    pub fn sharded(&self) -> Option<&ShardedCounters> {
        self.sharded.as_ref()
    }

    /// O(shards) bounded-lag size estimate from the sharded mirror
    /// (`None` when the mirror is disabled): the batched reconciliation
    /// collect of `sharded.rs`. Exact at quiescence; mid-churn it may
    /// trail the exact size by up to the number of in-flight operations.
    /// Use [`Self::compute`] for a linearizable size.
    pub fn approx_size(&self) -> Option<i64> {
        self.sharded.as_ref().map(ShardedCounters::reconcile)
    }

    /// Raw counter sample `[tid][ins, del]` for the offline analytics
    /// pipeline (NOT linearizable — epoch analytics tolerance is documented
    /// in `analytics`; use [`Self::compute`] for a linearizable size).
    pub fn sample_counters(&self) -> Vec<[u64; 2]> {
        (0..self.nthreads)
            .map(|tid| {
                [
                    self.metadata[tid][0].load(SeqCst),
                    self.metadata[tid][1].load(SeqCst),
                ]
            })
            .collect()
    }

    /// Current value of one metadata counter (tests/diagnostics).
    pub fn counter(&self, tid: usize, kind: OpKind) -> u64 {
        self.metadata[tid][kind as usize].load(SeqCst)
    }
}

impl Drop for SizeCalculator {
    fn drop(&mut self) {
        let p = *self.counters_snapshot.get_mut();
        drop(unsafe { Box::from_raw(p) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::INVALID_CELL;
    use std::sync::Arc;

    fn info(tid: usize, counter: u64) -> u64 {
        UpdateInfo { tid, counter }.pack()
    }

    #[test]
    fn empty_calculator_size_is_zero() {
        let sc = SizeCalculator::new(4, SizeOpts::default());
        assert_eq!(sc.compute(), 0);
    }

    #[test]
    fn update_metadata_is_idempotent() {
        let sc = SizeCalculator::new(2, SizeOpts::default());
        let i1 = info(0, 1);
        sc.update_metadata(i1, OpKind::Insert);
        sc.update_metadata(i1, OpKind::Insert); // helper repeats: no effect
        sc.update_metadata(i1, OpKind::Insert);
        assert_eq!(sc.counter(0, OpKind::Insert), 1);
        assert_eq!(sc.compute(), 1);
    }

    #[test]
    fn size_tracks_inserts_and_deletes() {
        let sc = SizeCalculator::new(2, SizeOpts::default());
        sc.update_metadata(info(0, 1), OpKind::Insert);
        sc.update_metadata(info(0, 2), OpKind::Insert);
        sc.update_metadata(info(1, 1), OpKind::Insert);
        sc.update_metadata(info(0, 1), OpKind::Delete);
        assert_eq!(sc.compute(), 2);
    }

    #[test]
    fn create_update_info_targets_next_counter() {
        let sc = SizeCalculator::new(2, SizeOpts::default());
        let p = sc.create_update_info(OpKind::Insert, 1);
        assert_eq!(UpdateInfo::unpack(p), UpdateInfo { tid: 1, counter: 1 });
        sc.update_metadata(p, OpKind::Insert);
        let p2 = sc.create_update_info(OpKind::Insert, 1);
        assert_eq!(UpdateInfo::unpack(p2).counter, 2);
    }

    #[test]
    fn compute_twice_announces_fresh_snapshots() {
        let sc = SizeCalculator::new(2, SizeOpts::default());
        assert_eq!(sc.compute(), 0);
        sc.update_metadata(info(0, 1), OpKind::Insert);
        assert_eq!(sc.compute(), 1); // must not return the stale agreed 0
    }

    #[test]
    fn update_during_collection_is_forwarded() {
        // Build a collecting snapshot manually, then update metadata: the
        // new value must be forwarded into the snapshot (paper lines 80-83).
        let sc = SizeCalculator::new(2, SizeOpts::default());
        let _g = ebr::pin();
        let snap = unsafe { &*sc.counters_snapshot.load(SeqCst) };
        snap.collecting.store(true, SeqCst);
        sc.update_metadata(info(0, 1), OpKind::Insert);
        assert_eq!(snap.cell(0, OpKind::Insert), 1);
        assert_ne!(snap.cell(0, OpKind::Insert), INVALID_CELL);
        snap.collecting.store(false, SeqCst);
    }

    #[test]
    fn concurrent_sizes_agree() {
        let sc = Arc::new(SizeCalculator::new(8, SizeOpts::default()));
        // Preload 100 net inserts by thread 0.
        for c in 1..=100 {
            sc.update_metadata(info(0, c), OpKind::Insert);
        }
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let sc = sc.clone();
                std::thread::spawn(move || sc.compute())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 100);
        }
    }

    #[test]
    fn stress_size_never_negative_with_paired_ops() {
        // Updaters always insert-then-delete: any linearizable size is >= 0.
        let sc = Arc::new(SizeCalculator::new(8, SizeOpts::default()));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let updaters: Vec<_> = (0..3)
            .map(|t| {
                let sc = sc.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let tid = t; // distinct logical tids for this test
                    let mut c = 0u64;
                    while !stop.load(SeqCst) {
                        c += 1;
                        sc.update_metadata(info(tid, c), OpKind::Insert);
                        sc.update_metadata(info(tid, c), OpKind::Delete);
                    }
                })
            })
            .collect();
        for _ in 0..2_000 {
            let s = sc.compute();
            assert!((0..=3).contains(&s), "non-linearizable size {s}");
        }
        stop.store(true, SeqCst);
        for u in updaters {
            u.join().unwrap();
        }
    }

    #[test]
    fn sharded_mirror_disabled_by_default() {
        let sc = SizeCalculator::new(2, SizeOpts::default());
        assert!(sc.sharded().is_none());
        assert_eq!(sc.approx_size(), None);
    }

    #[test]
    fn sharded_mirror_tracks_the_metadata() {
        let sc = SizeCalculator::new(8, SizeOpts::default().with_shards(2));
        assert_eq!(sc.sharded().unwrap().shards(), 2);
        assert_eq!(sc.approx_size(), Some(0));
        for tid in 0..4 {
            sc.update_metadata(info(tid, 1), OpKind::Insert);
            sc.update_metadata(info(tid, 2), OpKind::Insert);
        }
        sc.update_metadata(info(0, 1), OpKind::Delete);
        assert_eq!(sc.compute(), 7);
        assert_eq!(sc.approx_size(), Some(7), "exact at quiescence");
    }

    #[test]
    fn sharded_mirror_counts_helped_commits_once() {
        // A helper repeating update_metadata must not double-bump stripes.
        let sc = SizeCalculator::new(4, SizeOpts::default().with_shards(4));
        let i1 = info(1, 1);
        sc.update_metadata(i1, OpKind::Insert);
        sc.update_metadata(i1, OpKind::Insert);
        sc.update_metadata(i1, OpKind::Insert);
        assert_eq!(sc.approx_size(), Some(1));
        assert_eq!(sc.sharded().unwrap().collect(), (1, 0));
    }

    #[test]
    fn opts_none_still_correct() {
        let sc = SizeCalculator::new(2, SizeOpts::NONE);
        sc.update_metadata(info(0, 1), OpKind::Insert);
        sc.update_metadata(info(1, 1), OpKind::Insert);
        sc.update_metadata(info(1, 1), OpKind::Delete);
        assert_eq!(sc.compute(), 1);
        assert_eq!(sc.compute(), 1);
    }
}
