//! `CountersSnapshot` — paper Figure 6, line-by-line.
//!
//! One instance coordinates every `size()` call of a single collection
//! phase; updating operations `forward` counter values a concurrent
//! collection might have missed (Jayanti's second array, adapted to
//! multiple concurrent scanners à la Petrank–Timnat).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering::SeqCst};

use super::OpKind;

/// Sentinel for "not yet collected" snapshot cells (paper: `Long.MAX_VALUE`;
/// counters are < 2^48 so `u64::MAX` can never be a real counter).
pub const INVALID_CELL: u64 = u64::MAX;

/// Sentinel for "size not yet determined".
pub const INVALID_SIZE: i64 = i64::MIN;

/// Snapshot of the metadata counters plus the agreed size (paper Fig. 4).
pub struct CountersSnapshot {
    /// `snapshot[tid][kind]` — collected/forwarded counter values.
    snapshot: Box<[[AtomicU64; 2]]>,
    /// True while the collection phase is ongoing; setting it false is the
    /// linearization point of every `size()` using this instance (§8.1.1).
    pub(crate) collecting: AtomicBool,
    /// The agreed size; first successful CAS from [`INVALID_SIZE`] wins.
    size: AtomicI64,
}

impl CountersSnapshot {
    /// Paper Fig. 6 lines 87–91.
    pub fn new(nthreads: usize) -> Self {
        Self {
            snapshot: (0..nthreads)
                .map(|_| [AtomicU64::new(INVALID_CELL), AtomicU64::new(INVALID_CELL)])
                .collect(),
            collecting: AtomicBool::new(true),
            size: AtomicI64::new(INVALID_SIZE),
        }
    }

    /// Collect `counter` into the snapshot unless some operation already
    /// collected or forwarded this cell (paper lines 92–94).
    pub fn add(&self, tid: usize, kind: OpKind, counter: u64) {
        let cell = &self.snapshot[tid][kind as usize];
        if cell.load(SeqCst) == INVALID_CELL {
            let _ = cell.compare_exchange(INVALID_CELL, counter, SeqCst, SeqCst);
        }
    }

    /// Forward a freshly-written metadata value a concurrent collection may
    /// have missed (paper lines 95–100). Executes at most two loop
    /// iterations (paper Claim 8.4) because stale forwards are filtered by
    /// the caller's check sequence in `update_metadata`.
    pub fn forward(&self, tid: usize, kind: OpKind, counter: u64) {
        let cell = &self.snapshot[tid][kind as usize];
        let mut snap = cell.load(SeqCst);
        while snap == INVALID_CELL || counter > snap {
            match cell.compare_exchange(snap, counter, SeqCst, SeqCst) {
                Ok(_) => return,
                Err(witnessed) => snap = witnessed,
            }
        }
    }

    /// Compute/adopt the agreed size (paper lines 101–109). With
    /// `early_check` (optimization §7.3) the already-agreed size short-cuts
    /// both the summation and the CAS.
    pub fn compute_size(&self, early_check: bool) -> i64 {
        if early_check {
            let s = self.size.load(SeqCst);
            if s != INVALID_SIZE {
                return s;
            }
        }
        let mut computed: i64 = 0;
        for cells in self.snapshot.iter() {
            let ins = cells[OpKind::Insert as usize].load(SeqCst);
            let del = cells[OpKind::Delete as usize].load(SeqCst);
            debug_assert_ne!(
                ins,
                INVALID_CELL,
                "compute_size before collection completed"
            );
            debug_assert_ne!(
                del,
                INVALID_CELL,
                "compute_size before collection completed"
            );
            computed += ins as i64 - del as i64;
        }
        if early_check {
            let s = self.size.load(SeqCst);
            if s != INVALID_SIZE {
                return s;
            }
        }
        match self
            .size
            .compare_exchange(INVALID_SIZE, computed, SeqCst, SeqCst)
        {
            Ok(_) => computed,
            Err(witnessed) => witnessed, // adopt the concurrently-agreed size
        }
    }

    /// The agreed size if already determined.
    #[inline]
    pub fn agreed_size(&self) -> Option<i64> {
        match self.size.load(SeqCst) {
            INVALID_SIZE => None,
            s => Some(s),
        }
    }

    /// Whether the collection phase is still ongoing.
    #[inline]
    pub fn is_collecting(&self) -> bool {
        self.collecting.load(SeqCst)
    }

    /// Raw snapshot cell (tests/diagnostics).
    #[cfg(test)]
    pub(crate) fn cell(&self, tid: usize, kind: OpKind) -> u64 {
        self.snapshot[tid][kind as usize].load(SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_instance_is_collecting_and_invalid() {
        let cs = CountersSnapshot::new(4);
        assert!(cs.is_collecting());
        assert_eq!(cs.agreed_size(), None);
        assert_eq!(cs.cell(0, OpKind::Insert), INVALID_CELL);
    }

    #[test]
    fn add_only_fills_invalid_cells() {
        let cs = CountersSnapshot::new(2);
        cs.add(0, OpKind::Insert, 5);
        cs.add(0, OpKind::Insert, 99); // must not override
        assert_eq!(cs.cell(0, OpKind::Insert), 5);
    }

    #[test]
    fn forward_overrides_smaller_values() {
        let cs = CountersSnapshot::new(2);
        cs.add(1, OpKind::Delete, 3);
        cs.forward(1, OpKind::Delete, 7);
        assert_eq!(cs.cell(1, OpKind::Delete), 7);
        cs.forward(1, OpKind::Delete, 4); // stale: ignored
        assert_eq!(cs.cell(1, OpKind::Delete), 7);
    }

    #[test]
    fn forward_fills_invalid_cells() {
        let cs = CountersSnapshot::new(1);
        cs.forward(0, OpKind::Insert, 2);
        assert_eq!(cs.cell(0, OpKind::Insert), 2);
    }

    #[test]
    fn compute_size_sums_ins_minus_del() {
        let cs = CountersSnapshot::new(3);
        for tid in 0..3 {
            cs.add(tid, OpKind::Insert, (tid as u64 + 1) * 10);
            cs.add(tid, OpKind::Delete, tid as u64);
        }
        // 10+20+30 - (0+1+2) = 57
        assert_eq!(cs.compute_size(true), 57);
        assert_eq!(cs.agreed_size(), Some(57));
    }

    #[test]
    fn first_compute_wins_and_is_adopted() {
        let cs = CountersSnapshot::new(1);
        cs.add(0, OpKind::Insert, 4);
        cs.add(0, OpKind::Delete, 1);
        assert_eq!(cs.compute_size(false), 3);
        // Later forwards change cells but not the agreed size.
        cs.forward(0, OpKind::Insert, 100);
        assert_eq!(cs.compute_size(false), 3);
        assert_eq!(cs.compute_size(true), 3);
    }
}
