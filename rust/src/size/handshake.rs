//! Handshake-based size (the synchronization-methods study, arXiv
//! 2506.16350): make `size()` pay for synchronization so updates don't.
//!
//! ## Protocol
//!
//! Updates keep plain per-thread `[insertions, deletions]` counters —
//! bumped *inside* the operation, on the thread's own cache line, with no
//! `UpdateInfo` publication, no helping and no shared-counter contention.
//! On its own such a counter sum is the paper's non-linearizable "naive"
//! size; the handshake is what makes reading it sound:
//!
//! 1. `size()` raises [`HandshakeSize::size_flag`] (one per structure) and
//!    then waits for every per-thread **epoch/ack slot** to go *even* —
//!    each slot is odd exactly while its owner thread is inside an
//!    operation, so an even sweep means all in-flight operations drained.
//! 2. An operation entering while the flag is up **acknowledges** by
//!    backing its slot out to even and parking until the flag drops; the
//!    slot-store→flag-load / flag-store→slot-load SeqCst pairing (Dekker)
//!    guarantees an operation either sees the flag and parks, or its odd
//!    slot is seen by the drain sweep and waited for.
//! 3. Between the drain and the flag drop the structure is *quiescent*:
//!    `size()` reads the counters at leisure — every completed update is
//!    reflected, nothing is mid-flight — and that whole window is its
//!    linearization point.
//!
//! ## Trade-off (when this method wins)
//!
//! The update fast path is two private-line stores plus one flag load per
//! operation — strictly cheaper than the wait-free method's metadata CAS +
//! announced-snapshot forwarding — and read-only `contains` skips the
//! handshake entirely (reads never touch the counters, and during a
//! size's frozen window they observe exactly the counted state, so only
//! update drains are load-bearing). The price: `size()` blocks updates
//! for its O(#threads) window and size callers serialize, so the method
//! shines on update-heavy workloads with rare/periodic size calls and
//! loses when size is hammered concurrently (`ablation_policies`
//! quantifies both). Unlike the paper's method, `size()` here is
//! blocking, not wait-free — linearizability is preserved, progress
//! guarantees are the trade.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed, Ordering::SeqCst};
use std::sync::{Mutex, MutexGuard, TryLockError};

use crate::faults::{self, FaultSite};
use crate::pad::CachePadded;
use crate::thread_id;

use super::policy::SizePolicy;
use super::{OpKind, SizeOpts, spin_wait_while};

/// Per-thread epoch/ack slot: even = quiescent, odd = inside an operation.
/// Monotonically increasing, so a stuck reader can tell "same op" from
/// "new op" when debugging.
type AckSlot = CachePadded<AtomicU64>;

pub struct HandshakeSize {
    /// `counters[tid] = [insertions, deletions]`; each owner-written only.
    counters: Box<[CachePadded<[AtomicU64; 2]>]>,
    /// Epoch/ack slots, one per thread (see module docs).
    ack: Box<[AckSlot]>,
    /// Raised while a `size()` handshake is in progress.
    size_flag: AtomicBool,
    /// Completed handshakes (diagnostics; one per successful `size()`).
    handshakes: AtomicU64,
    /// Serializes size callers: one handshake at a time.
    size_lock: Mutex<()>,
}

/// RAII op guard: flips the owner's ack slot odd on entry, even on drop.
/// Read-only operations carry no slot (see [`SizePolicy::enter_read`]) —
/// they neither toggle parity nor park, so reads stay handshake-free.
pub struct HandshakeGuard<'a> {
    slot: Option<&'a AtomicU64>,
}

impl Drop for HandshakeGuard<'_> {
    #[inline]
    fn drop(&mut self) {
        if let Some(slot) = self.slot {
            // Sole writer of the slot: load can be relaxed, the store must
            // be SeqCst so the drain sweep that observes it also observes
            // every counter bump this operation performed.
            let v = slot.load(Relaxed);
            debug_assert!(v % 2 == 1, "guard dropped on a quiescent slot");
            slot.store(v + 1, SeqCst);
        }
    }
}

impl HandshakeSize {
    #[inline]
    fn park_while_flag_up(&self) {
        spin_wait_while(|| self.size_flag.load(SeqCst));
    }

    #[inline]
    fn bump(&self, kind: OpKind) {
        let tid = thread_id::current();
        // Owner-only counter: Relaxed is enough — visibility to size() is
        // carried by the SeqCst even-store of the enclosing guard.
        self.counters[tid][kind as usize].fetch_add(1, Relaxed);
    }

    /// Completed handshakes so far (one per successful `size()`).
    pub fn handshake_count(&self) -> u64 {
        self.handshakes.load(SeqCst)
    }

    /// Raw counter value (tests/diagnostics; not linearizable on its own).
    pub fn counter(&self, tid: usize, kind: OpKind) -> u64 {
        self.counters[tid][kind as usize].load(SeqCst)
    }
}

impl SizePolicy for HandshakeSize {
    // No per-node metadata at all: the node layout stays baseline.
    type InfoSlot = ();
    type OpGuard<'a>
        = HandshakeGuard<'a>
    where
        Self: 'a;
    const TRACKED: bool = false;
    const HAS_SIZE: bool = true;

    fn new(max_threads: usize, _opts: SizeOpts) -> Self {
        Self {
            counters: (0..max_threads)
                .map(|_| CachePadded::new([AtomicU64::new(0), AtomicU64::new(0)]))
                .collect(),
            ack: (0..max_threads)
                .map(|_| CachePadded::new(AtomicU64::new(0)))
                .collect(),
            size_flag: AtomicBool::new(false),
            handshakes: AtomicU64::new(0),
            size_lock: Mutex::new(()),
        }
    }

    #[inline]
    fn enter(&self) -> HandshakeGuard<'_> {
        let slot: &AtomicU64 = &self.ack[thread_id::current()];
        loop {
            let v = slot.load(Relaxed);
            debug_assert!(v % 2 == 0, "re-entrant operation on one thread");
            // Announce "in operation" BEFORE checking the flag (Dekker
            // store→load): if we miss a concurrent handshake's flag, the
            // handshake's drain sweep is guaranteed to see our odd slot.
            slot.store(v + 1, SeqCst);
            if !self.size_flag.load(SeqCst) {
                return HandshakeGuard { slot: Some(slot) };
            }
            // A handshake is in progress: acknowledge by backing out to a
            // quiescent (even) slot, park until it completes, retry.
            slot.store(v + 2, SeqCst);
            self.park_while_flag_up();
        }
    }

    #[inline]
    fn enter_read(&self) -> HandshakeGuard<'_> {
        // Reads never touch the counters and the structure is frozen while
        // a size() holds its quiescent window, so a reader running through
        // it still observes exactly the counted state — no parity toggle,
        // no parking, no flag load. Reads are completely handshake-free.
        HandshakeGuard { slot: None }
    }

    #[inline(always)]
    fn begin_insert(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn stash_insert_info(_: &(), _: u64) {}

    #[inline]
    fn commit_insert(&self, _: &(), _: u64) {
        self.bump(OpKind::Insert);
    }

    #[inline(always)]
    fn help_insert(&self, _: &()) {}
    #[inline(always)]
    fn begin_delete(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn try_claim_delete(_: &(), _: u64) -> u64 {
        0
    }
    #[inline(always)]
    fn read_delete_info(_: &()) -> u64 {
        0
    }

    #[inline]
    fn commit_delete(&self, _: u64) {
        self.bump(OpKind::Delete);
    }

    fn size(&self) -> Option<i64> {
        // The caller's own ack slot must be excluded from the drain: if
        // this thread holds its own op guard (odd slot), spinning on it
        // would self-deadlock — nobody else can flip it even. Skipping is
        // sound: the caller's counter bumps are its own writes, already
        // visible to the sum below.
        let me = thread_id::current();
        let my_slot: &AtomicU64 = &self.ack[me];
        let held_guard = my_slot.load(SeqCst) % 2 == 1;
        let _serialize: MutexGuard<'_, ()> = if held_guard {
            // Cross-deadlock avoidance: another guard-holding size()
            // caller may own the lock and spin on OUR odd slot while we
            // block on the lock. Back our slot out to even while waiting
            // (our bumps so far are already visible; the enclosing op
            // simply linearizes after any handshake that overlaps the
            // wait) and restore the odd parity below, once we hold the
            // lock and no handshake can be mid-drain.
            loop {
                match self.size_lock.try_lock() {
                    Ok(g) => break g,
                    Err(TryLockError::Poisoned(p)) => break p.into_inner(),
                    Err(TryLockError::WouldBlock) => {
                        let v = my_slot.load(Relaxed);
                        if v % 2 == 1 {
                            my_slot.store(v + 1, SeqCst);
                        }
                        std::thread::yield_now();
                    }
                }
            }
        } else {
            // Poison-tolerant, matching the try_lock branch: one
            // panicking size() caller must not wedge all future ones.
            self.size_lock.lock().unwrap_or_else(|p| p.into_inner())
        };
        if held_guard {
            // Restore the parity the enclosing guard's Drop expects. The
            // flag is down and we hold the lock, so no drain can observe
            // the flip mid-sweep.
            let v = my_slot.load(Relaxed);
            if v % 2 == 0 {
                my_slot.store(v + 1, SeqCst);
            }
        }
        let my_parity = my_slot.load(SeqCst) % 2;
        self.size_flag.store(true, SeqCst);
        // Stretching the flag-raise→drain window here maximizes the
        // number of updaters that must take the acknowledge/park path.
        faults::jitter(FaultSite::HandshakeDrain);
        // Drain: wait until every other thread is at a quiescent point.
        // Threads that entered before the flag finish their op; threads
        // entering after it park (see `enter`), so after this sweep
        // nothing moves.
        for (tid, slot) in self.ack.iter().enumerate() {
            if tid == me {
                continue;
            }
            spin_wait_while(|| slot.load(SeqCst) % 2 == 1);
        }
        // While we hold the flag and the size lock, this thread cannot
        // enter or leave an operation — its slot parity must be frozen.
        debug_assert_eq!(
            self.ack[me].load(SeqCst) % 2,
            my_parity,
            "caller's ack slot changed parity during its own handshake"
        );
        // Quiescent window: the counter sum is the exact current size, and
        // any point in this window is a valid linearization point.
        let mut total = 0i64;
        for pair in self.counters.iter() {
            total += pair[OpKind::Insert as usize].load(SeqCst) as i64;
            total -= pair[OpKind::Delete as usize].load(SeqCst) as i64;
        }
        self.handshakes.fetch_add(1, SeqCst);
        self.size_flag.store(false, SeqCst);
        // Fairness: with the flag down but the size lock still held, give
        // parked updaters a scheduling window before the next handshake
        // can raise the flag again — without this, back-to-back size()
        // callers can starve updates on machines with few cores.
        std::thread::yield_now();
        debug_assert!(total >= 0, "handshake size went negative: {total}");
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn policy() -> HandshakeSize {
        HandshakeSize::new(crate::MAX_THREADS, SizeOpts::default())
    }

    #[test]
    fn info_slot_is_zero_sized() {
        assert_eq!(
            std::mem::size_of::<<HandshakeSize as SizePolicy>::InfoSlot>(),
            0,
            "handshake must add no per-node metadata"
        );
    }

    #[test]
    fn sequential_counting() {
        let p = policy();
        {
            let _g = p.enter();
            p.commit_insert(&(), 0);
            p.commit_insert(&(), 0);
        }
        {
            let _g = p.enter();
            p.commit_delete(0);
        }
        assert_eq!(p.size(), Some(1));
        assert_eq!(p.handshake_count(), 1);
    }

    #[test]
    fn read_guard_is_handshake_free() {
        let p = policy();
        let tid = thread_id::current();
        let before = p.ack[tid].load(SeqCst);
        // Even with a handshake "in progress", a read guard neither parks
        // nor touches the ack slot.
        p.size_flag.store(true, SeqCst);
        {
            let _g = p.enter_read();
            assert_eq!(p.ack[tid].load(SeqCst), before);
        }
        p.size_flag.store(false, SeqCst);
        assert_eq!(p.ack[tid].load(SeqCst), before);
    }

    #[test]
    fn guard_toggles_ack_slot_parity() {
        let p = policy();
        let tid = thread_id::current();
        let before = p.ack[tid].load(SeqCst);
        assert_eq!(before % 2, 0);
        {
            let _g = p.enter();
            assert_eq!(p.ack[tid].load(SeqCst) % 2, 1);
        }
        assert_eq!(p.ack[tid].load(SeqCst) % 2, 0);
        assert!(p.ack[tid].load(SeqCst) > before, "slot must be monotone");
    }

    #[test]
    fn size_inside_own_op_guard_does_not_self_deadlock() {
        // Regression: the drain sweep used to spin forever on the
        // caller's OWN odd ack slot when size() ran under an op guard.
        let p = policy();
        let g = p.enter();
        p.commit_insert(&(), 0);
        p.commit_insert(&(), 0);
        assert_eq!(p.size(), Some(2), "size under own guard must return");
        assert_eq!(p.handshake_count(), 1);
        drop(g);
        assert_eq!(p.size(), Some(2));
    }

    #[test]
    fn concurrent_guard_holding_sizers_do_not_cross_deadlock() {
        // Two threads each hold their own op guard and call size()
        // concurrently: the lock winner must not spin forever on the
        // waiter's odd slot (the waiter backs its slot out while parked
        // on the lock).
        let p = Arc::new(policy());
        let ready = Arc::new(std::sync::Barrier::new(2));
        let sizers: Vec<_> = (0..2)
            .map(|_| {
                let p = p.clone();
                let ready = ready.clone();
                std::thread::spawn(move || {
                    let _g = p.enter();
                    p.commit_insert(&(), 0);
                    ready.wait();
                    p.size().unwrap()
                })
            })
            .collect();
        for s in sizers {
            // Each caller sees at least its own committed insert; the
            // other thread's may still be mid-flight (backed-out slot).
            let seen = s.join().unwrap();
            assert!((1..=2).contains(&seen), "impossible size {seen}");
        }
        assert_eq!(p.size(), Some(2));
    }

    #[test]
    fn size_drains_in_flight_op() {
        // An operation holds its guard while size() runs in another
        // thread: size must wait for the guard and then count the op.
        let p = Arc::new(policy());
        let entered = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        let sizer = {
            let p = p.clone();
            let entered = entered.clone();
            let release = release.clone();
            std::thread::spawn(move || {
                while !entered.load(SeqCst) {
                    std::hint::spin_loop();
                }
                release.store(true, SeqCst); // let the op finish…
                p.size().unwrap() // …and drain it
            })
        };
        {
            let _g = p.enter();
            p.commit_insert(&(), 0);
            entered.store(true, SeqCst);
            while !release.load(SeqCst) {
                std::hint::spin_loop();
            }
            // Hold the guard a little longer so the drain really waits.
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(sizer.join().unwrap(), 1);
    }

    #[test]
    fn concurrent_churn_never_negative() {
        let p = Arc::new(policy());
        let stop = Arc::new(AtomicBool::new(false));
        let churners: Vec<_> = (0..3)
            .map(|_| {
                let p = p.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(SeqCst) {
                        {
                            let _g = p.enter();
                            p.commit_insert(&(), 0);
                        }
                        {
                            let _g = p.enter();
                            p.commit_delete(0);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..300 {
            let s = p.size().unwrap();
            assert!((0..=3).contains(&s), "non-linearizable size {s}");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(p.size(), Some(0));
    }
}
