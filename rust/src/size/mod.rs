//! The paper's core contribution: wait-free linearizable `size()`.
//!
//! * [`SizeCalculator`] — per-thread insertion/deletion metadata counters +
//!   the announced [`CountersSnapshot`] (paper Fig. 5).
//! * [`CountersSnapshot`] — the Jayanti-style wait-free collect object
//!   shared by concurrent `size()` calls (paper Fig. 6).
//! * [`UpdateInfo`] — the trace a successful insert/delete leaves for
//!   helpers (paper Fig. 4). We pack it into a single `u64`
//!   (`tid << 48 | counter`) so publishing it is one relaxed store and no
//!   allocation — the protocol is unchanged, only the representation.
//! * [`SizePolicy`] and its implementations — the compile-time switch that
//!   instantiates each data structure as baseline / paper-transformed /
//!   naive / global-lock (see `policy.rs`).
//! * [`HandshakeSize`], [`OptimisticSize`] — the optimized size methods of
//!   the follow-up synchronization-methods study (Kas-Sharir, Sela &
//!   Petrank, arXiv 2506.16350): a blocking handshake that makes updates
//!   nearly free, and an optimistic double-collect with a wait-free
//!   fallback (see `handshake.rs` / `optimistic.rs`).
//! * [`SizeArbiter`], [`SizeView`] — the combining size front-end
//!   (`arbiter.rs`): concurrent `size_exact()` callers share one
//!   underlying collect, and the published last result serves
//!   `size_recent(max_staleness)` with a single wait-free load. Every
//!   structure embeds one, over every policy.
//! * [`ShardedCounters`] — the scale layer (`sharded.rs`): a striped,
//!   cache-padded mirror of the metadata kept in sync at the exactly-once
//!   counter-CAS point, whose batched reconciliation collect serves
//!   O(shards) bounded-lag size estimates (`--size-shards`).
//! * [`SizeCore`], [`SizeRefresher`], [`RefresherSlot`] — the background
//!   refresh layer (`refresher.rs`): an owned daemon per structure that
//!   periodically drives the arbiter's round so `size_recent` becomes a
//!   truly passive published read (`set_refresh_period`), with clean
//!   join-on-drop shutdown.

mod arbiter;
mod calculator;
mod counters_snapshot;
mod handshake;
mod optimistic;
mod policy;
mod refresher;
mod sharded;

pub use arbiter::{ArbiterStats, SizeArbiter, SizeView};
pub use calculator::{SizeCalculator, SizeOpts};
pub use counters_snapshot::{CountersSnapshot, INVALID_CELL, INVALID_SIZE};
pub use handshake::HandshakeSize;
pub use optimistic::{OPTIMISTIC_MAX_RETRIES, OPTIMISTIC_TUNE_MAX, OptimisticSize};
pub use policy::{LinearizableSize, LockSize, NaiveSize, NoSize, SizePolicy, SizeTuning};
pub use refresher::{MIN_REFRESH_PERIOD, RefresherSlot, SizeCore, SizeRefresher};
pub use sharded::{detect_shards, ShardedCounters};

/// Expands to the six shared [`ConcurrentSet`] size-surface methods —
/// raw `size`, arbiter-backed `size_exact`/`size_recent`, the sharded
/// `size_estimate`, `set_refresh_period` and merged `size_stats` — for a
/// structure embedding `core: Arc<SizeCore<P>>` and
/// `refresher: RefresherSlot` (all four structures do). One definition
/// keeps the four `impl ConcurrentSet` blocks in lockstep.
///
/// [`ConcurrentSet`]: crate::set_api::ConcurrentSet
macro_rules! impl_size_surface {
    () => {
        crate::size::impl_size_surface!(except_stats);

        fn size_stats(&self) -> Option<crate::size::ArbiterStats> {
            Some(self.core.stats(self.refresher.rounds()))
        }
    };
    // Everything but `size_stats` — for structures that decorate the
    // merged stats with their own fields (the resizable hashtable adds
    // `resizes` / `migration_pending`).
    (except_stats) => {
        fn size(&self) -> Option<i64> {
            self.core.policy.size()
        }

        fn size_exact(&self) -> Option<crate::size::SizeView> {
            self.core.arbiter.exact_for(&self.core.policy)
        }

        fn size_recent(
            &self,
            max_staleness: std::time::Duration,
        ) -> Option<crate::size::SizeView> {
            // Stall-aware: when the structure's refresher daemon should
            // have kept the published result fresh enough but did not,
            // the direct-round fallback is counted in `daemon_stalls`.
            self.core.arbiter.recent_for_daemon(
                &self.core.policy,
                max_staleness,
                self.refresher.active_period(),
            )
        }

        fn size_estimate(&self) -> Option<i64> {
            self.core.policy.calculator().and_then(|c| c.approx_size())
        }

        fn set_refresh_period(&self, period: Option<std::time::Duration>) -> bool {
            self.refresher.set(&self.core, period)
        }
    };
}
pub(crate) use impl_size_surface;

/// Retries of the scan double-collect before falling back to a
/// per-key-justified traversal (mirrors the optimistic size method's
/// bounded-retry shape).
pub const SCAN_RETRIES: u32 = 8;

/// Double-collect validation for range scans, built on the same
/// exactly-once counters the size predicate uses: sample every thread's
/// `(insertions, deletions)` pair, run `collect`, and re-sample. If no
/// counter moved, no tracked update linearized during the traversal —
/// the collected view is an atomic snapshot of the membership (the
/// traversal helps pending inserts and commits observed deletes, so any
/// in-flight update it could have half-seen bumps a counter and
/// invalidates the attempt). After [`SCAN_RETRIES`] failed attempts, or
/// when the policy has no calculator, the last traversal is returned
/// un-validated; the `bool` reports whether the snapshot validated.
pub fn validated_collect<T>(
    calc: Option<&SizeCalculator>,
    mut collect: impl FnMut() -> T,
) -> (T, bool) {
    if let Some(calc) = calc {
        for _ in 0..SCAN_RETRIES {
            let before = calc.sample_counters();
            crate::faults::jitter(crate::faults::FaultSite::ScanCollect);
            let out = collect();
            if calc.sample_counters() == before {
                return (out, true);
            }
        }
    }
    (collect(), false)
}

/// Spins before each yield in the size subsystem's wait loops
/// (single-core containers need the yield to make progress at all).
pub(crate) const SPINS_BEFORE_YIELD: u32 = 64;

/// One step of a spin-then-yield backoff: spin-hint for the first
/// [`SPINS_BEFORE_YIELD`] steps, then yield the core.
#[inline]
pub(crate) fn spin_backoff(step: u32) {
    if step < SPINS_BEFORE_YIELD {
        std::hint::spin_loop();
    } else {
        std::thread::yield_now();
    }
}

/// Spin-then-yield until `cond` turns false.
#[inline]
pub(crate) fn spin_wait_while(cond: impl Fn() -> bool) {
    let mut step = 0u32;
    while cond() {
        spin_backoff(step);
        step = step.saturating_add(1);
    }
}

/// Operation kind: index into the per-thread counter pair (paper line 1:
/// `INSERT = 0, DELETE = 1`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Insert = 0,
    Delete = 1,
}

/// Bits reserved for the per-thread operation counter.
pub const COUNTER_BITS: u32 = 48;
const COUNTER_MASK: u64 = (1 << COUNTER_BITS) - 1;

/// The information the `c`-th successful operation of thread `tid` leaves
/// for helpers (paper Section 5): which counter to update and its target
/// value. `counter` starts at 1, so the packed form is never 0 — `0` is the
/// "no pending operation" sentinel in node info slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UpdateInfo {
    pub tid: usize,
    pub counter: u64,
}

impl UpdateInfo {
    /// Pack into the single-word form stored in node info slots.
    #[inline]
    pub fn pack(self) -> u64 {
        debug_assert!(self.tid < (1 << (64 - COUNTER_BITS)));
        debug_assert!(self.counter != 0 && self.counter <= COUNTER_MASK);
        ((self.tid as u64) << COUNTER_BITS) | self.counter
    }

    /// Unpack a non-zero packed word.
    #[inline]
    pub fn unpack(packed: u64) -> Self {
        debug_assert!(packed != 0);
        Self {
            tid: (packed >> COUNTER_BITS) as usize,
            counter: packed & COUNTER_MASK,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        for (tid, counter) in [(0, 1), (5, 42), (63, (1u64 << 48) - 1)] {
            let info = UpdateInfo { tid, counter };
            assert_eq!(UpdateInfo::unpack(info.pack()), info);
        }
    }

    #[test]
    fn packed_is_never_zero() {
        assert_ne!(UpdateInfo { tid: 0, counter: 1 }.pack(), 0);
    }

    #[test]
    fn opkind_indices_match_paper() {
        assert_eq!(OpKind::Insert as usize, 0);
        assert_eq!(OpKind::Delete as usize, 1);
    }
}
