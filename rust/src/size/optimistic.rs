//! Optimistic size (the synchronization-methods study, arXiv 2506.16350):
//! keep the paper's update-side metadata protocol, but let `size()` dodge
//! the wait-free snapshot machinery in the common case.
//!
//! ## Protocol
//!
//! Updates are *identical* to [`super::LinearizableSize`] — this policy
//! embeds one and delegates every update hook to it, so the two can never
//! drift apart: updates publish `UpdateInfo`, help dependent operations,
//! and move the per-thread metadata counters at the operation's
//! linearization point. What changes is the read side. The metadata
//! counters are **monotone** — each is its own version stamp — so
//! `size()` first runs a bounded retry loop of optimistic double-collects
//! over the counter array:
//!
//! 1. read all `2 × #threads` counters (collect #1);
//! 2. read them all again (collect #2);
//! 3. if the two collects are identical, every counter held its collected
//!    value throughout the instant between the collects (monotonicity
//!    rules out ABA), so the vector is an atomic snapshot and the sum is a
//!    linearizable size — return it.
//!
//! A collect costs two plain sweeps: no `CountersSnapshot` allocation, no
//! announce CAS, and — crucially — concurrent updates never enter the
//! forwarding path (`updateMetadata` lines 80–83 only fire while a
//! snapshot is announced as collecting, which the optimistic path never
//! does). After the configured retry budget (default
//! [`OPTIMISTIC_MAX_RETRIES`]; see [`OptimisticSize::with_max_retries`])
//! is exhausted under update-heavy contention, it falls back to the
//! paper's wait-free [`super::SizeCalculator::compute`], so `size()`
//! stays lock-free with a wait-free fallback bound rather than spinning
//! unboundedly.
//!
//! ## Trade-off (when this method wins)
//!
//! Wherever sizes interleave with moderate update traffic, the optimistic
//! path turns every `size()` into two counter sweeps and spares updaters
//! the snapshot-forwarding traffic. Under extreme update churn the double
//! collect keeps failing and the method degrades gracefully to exactly
//! the paper's cost (plus the wasted sweeps).

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};

use super::policy::SizePolicy;
use super::{LinearizableSize, OpKind, SizeCalculator, SizeOpts};

/// Default failed double-collect rounds before falling back to the
/// wait-free path.
pub const OPTIMISTIC_MAX_RETRIES: usize = 8;

pub struct OptimisticSize {
    /// The embedded paper policy: carries the calculator and the entire
    /// update-side protocol (every update hook below delegates to it).
    inner: LinearizableSize,
    /// Times `size()` exhausted its retries and took the wait-free path
    /// (diagnostics for the ablation bench).
    fallbacks: AtomicU64,
    /// Per-instance retry budget (ROADMAP: per-structure tuning); a
    /// budget of 0 makes every `size()` take the wait-free path.
    max_retries: usize,
}

impl OptimisticSize {
    /// Times `size()` fell back to the wait-free snapshot so far.
    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(SeqCst)
    }

    /// Build with an explicit double-collect retry budget instead of
    /// [`OPTIMISTIC_MAX_RETRIES`].
    pub fn with_max_retries(max_threads: usize, opts: SizeOpts, max_retries: usize) -> Self {
        Self {
            inner: LinearizableSize::new(max_threads, opts),
            fallbacks: AtomicU64::new(0),
            max_retries,
        }
    }

    /// The configured retry budget.
    pub fn max_retries(&self) -> usize {
        self.max_retries
    }
}

impl SizePolicy for OptimisticSize {
    type InfoSlot = AtomicU64;
    type OpGuard<'a> = ();
    const TRACKED: bool = true;
    const HAS_SIZE: bool = true;

    fn new(max_threads: usize, opts: SizeOpts) -> Self {
        Self::with_max_retries(max_threads, opts, OPTIMISTIC_MAX_RETRIES)
    }

    #[inline(always)]
    fn enter(&self) -> () {}

    // ---- update side: delegated verbatim to the paper's protocol ----

    #[inline]
    fn begin_insert(&self, tid: usize) -> u64 {
        self.inner.begin_insert(tid)
    }

    #[inline]
    fn stash_insert_info(slot: &AtomicU64, packed: u64) {
        LinearizableSize::stash_insert_info(slot, packed);
    }

    #[inline]
    fn commit_insert(&self, slot: &AtomicU64, packed: u64) {
        self.inner.commit_insert(slot, packed);
    }

    #[inline]
    fn help_insert(&self, slot: &AtomicU64) {
        self.inner.help_insert(slot);
    }

    #[inline]
    fn begin_delete(&self, tid: usize) -> u64 {
        self.inner.begin_delete(tid)
    }

    #[inline]
    fn try_claim_delete(slot: &AtomicU64, packed: u64) -> u64 {
        LinearizableSize::try_claim_delete(slot, packed)
    }

    #[inline]
    fn read_delete_info(slot: &AtomicU64) -> u64 {
        LinearizableSize::read_delete_info(slot)
    }

    #[inline]
    fn commit_delete(&self, packed: u64) {
        self.inner.commit_delete(packed);
    }

    // ---- read side: optimistic double-collect, wait-free fallback ----

    fn size(&self) -> Option<i64> {
        let calc = self.inner.calc();
        let n = calc.nthreads();
        // Stack buffer, no per-call allocation (the whole point of the
        // optimistic path is that a size() is just two counter sweeps).
        // Calculators are never built wider than MAX_THREADS; if one ever
        // is, take the wait-free path rather than miscount.
        if n > crate::MAX_THREADS {
            return Some(calc.compute());
        }
        let mut snap = [0u64; 2 * crate::MAX_THREADS];
        'retry: for _ in 0..self.max_retries {
            for tid in 0..n {
                snap[2 * tid] = calc.counter(tid, OpKind::Insert);
                snap[2 * tid + 1] = calc.counter(tid, OpKind::Delete);
            }
            // Verify pass: each counter is monotone, so equality means it
            // held the collected value across the whole gap between the
            // two sweeps — the vector is a snapshot at that instant.
            for tid in 0..n {
                if calc.counter(tid, OpKind::Insert) != snap[2 * tid]
                    || calc.counter(tid, OpKind::Delete) != snap[2 * tid + 1]
                {
                    continue 'retry;
                }
            }
            let total: i64 = snap[..2 * n]
                .chunks_exact(2)
                .map(|p| p[0] as i64 - p[1] as i64)
                .sum();
            debug_assert!(total >= 0, "optimistic size went negative: {total}");
            return Some(total);
        }
        self.fallbacks.fetch_add(1, SeqCst);
        Some(calc.compute())
    }

    fn calculator(&self) -> Option<&SizeCalculator> {
        Some(self.inner.calc())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn policy() -> OptimisticSize {
        OptimisticSize::new(8, SizeOpts::default())
    }

    #[test]
    fn sequential_size_never_falls_back() {
        let p = policy();
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(0);
        OptimisticSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        assert_eq!(p.size(), Some(1));
        let d = p.begin_delete(0);
        let won = OptimisticSize::try_claim_delete(&AtomicU64::new(0), d);
        p.commit_delete(won);
        assert_eq!(p.size(), Some(0));
        assert_eq!(p.fallback_count(), 0, "quiescent collects must succeed");
    }

    #[test]
    fn update_protocol_matches_linearizable_semantics() {
        let p = policy();
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(2);
        OptimisticSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        assert_eq!(slot.load(SeqCst), 0, "§7.1 slot clearing must be on");
        p.help_insert(&slot); // idempotent after clear
        assert_eq!(p.size(), Some(1));
    }

    #[test]
    fn claim_race_single_winner() {
        let slot = AtomicU64::new(0);
        let a = crate::size::UpdateInfo { tid: 1, counter: 1 }.pack();
        let b = crate::size::UpdateInfo { tid: 2, counter: 1 }.pack();
        assert_eq!(OptimisticSize::try_claim_delete(&slot, a), a);
        assert_eq!(OptimisticSize::try_claim_delete(&slot, b), a);
    }

    #[test]
    fn concurrent_churn_never_negative_and_fallback_safe() {
        let p = Arc::new(policy());
        let stop = Arc::new(AtomicBool::new(false));
        let churners: Vec<_> = (0..3usize)
            .map(|t| {
                let p = p.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    // Drive the calculator directly with per-thread legal
                    // (insert-then-delete) histories.
                    let mut c = 0u64;
                    while !stop.load(SeqCst) {
                        c += 1;
                        let i = crate::size::UpdateInfo { tid: t, counter: c }.pack();
                        p.inner.calc().update_metadata(i, OpKind::Insert);
                        p.inner.calc().update_metadata(i, OpKind::Delete);
                    }
                })
            })
            .collect();
        for _ in 0..2000 {
            let s = p.size().unwrap();
            assert!((0..=3).contains(&s), "non-linearizable size {s}");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(p.size(), Some(0));
    }

    #[test]
    fn calculator_is_exposed_for_analytics() {
        let p = policy();
        assert!(p.calculator().is_some());
    }
}
