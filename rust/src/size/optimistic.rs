//! Optimistic size (the synchronization-methods study, arXiv 2506.16350):
//! keep the paper's update-side metadata protocol, but let `size()` dodge
//! the wait-free snapshot machinery in the common case.
//!
//! ## Protocol
//!
//! Updates are *identical* to [`super::LinearizableSize`] — this policy
//! embeds one and delegates every update hook to it, so the two can never
//! drift apart: updates publish `UpdateInfo`, help dependent operations,
//! and move the per-thread metadata counters at the operation's
//! linearization point. What changes is the read side. The metadata
//! counters are **monotone** — each is its own version stamp — so
//! `size()` first runs a bounded retry loop of optimistic double-collects
//! over the counter array:
//!
//! 1. read all `2 × #threads` counters (collect #1);
//! 2. read them all again (collect #2);
//! 3. if the two collects are identical, every counter held its collected
//!    value throughout the instant between the collects (monotonicity
//!    rules out ABA), so the vector is an atomic snapshot and the sum is a
//!    linearizable size — return it.
//!
//! A collect costs two plain sweeps: no `CountersSnapshot` allocation, no
//! announce CAS, and — crucially — concurrent updates never enter the
//! forwarding path (`updateMetadata` lines 80–83 only fire while a
//! snapshot is announced as collecting, which the optimistic path never
//! does). After the configured retry budget (default
//! [`OPTIMISTIC_MAX_RETRIES`]; see [`OptimisticSize::with_max_retries`])
//! is exhausted under update-heavy contention, it falls back to the
//! paper's wait-free [`super::SizeCalculator::compute`], so `size()`
//! stays lock-free with a wait-free fallback bound rather than spinning
//! unboundedly.
//!
//! ## Trade-off (when this method wins)
//!
//! Wherever sizes interleave with moderate update traffic, the optimistic
//! path turns every `size()` into two counter sweeps and spares updaters
//! the snapshot-forwarding traffic. Under extreme update churn the double
//! collect keeps failing and the method degrades gracefully to exactly
//! the paper's cost (plus the wasted sweeps).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed, Ordering::SeqCst};

use super::policy::{SizePolicy, SizeTuning};
use super::{LinearizableSize, OpKind, SizeCalculator, SizeOpts};
use crate::faults::{self, FaultSite};

/// Default failed double-collect rounds before falling back to the
/// wait-free path (also the auto-tuner's starting budget).
pub const OPTIMISTIC_MAX_RETRIES: usize = 8;

/// Auto-tune ceiling: the budget never grows past this.
pub const OPTIMISTIC_TUNE_MAX: usize = 4 * OPTIMISTIC_MAX_RETRIES;

/// First-try successes in a row before the auto-tuner grows the budget
/// by one (growth is slow; shrinking on fallback is a halving).
const TUNE_GROW_STREAK: u64 = 16;

pub struct OptimisticSize {
    /// The embedded paper policy: carries the calculator and the entire
    /// update-side protocol (every update hook below delegates to it).
    inner: LinearizableSize,
    /// Times `size()` exhausted its retries and took the wait-free path
    /// (diagnostics for the ablation bench).
    fallbacks: AtomicU64,
    /// Per-instance retry budget. Fixed by [`Self::with_max_retries`]
    /// (0 makes every `size()` take the wait-free path); otherwise
    /// auto-tuned within `[1, OPTIMISTIC_TUNE_MAX]` from observed
    /// fallback rates (ROADMAP: per-structure retry-budget auto-tuning).
    budget: AtomicUsize,
    /// Whether the budget adapts (off for `with_max_retries` instances).
    auto_tune: bool,
    /// Consecutive first-try successes (auto-tune growth trigger).
    streak: AtomicU64,
}

impl OptimisticSize {
    /// Times `size()` fell back to the wait-free snapshot so far.
    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(SeqCst)
    }

    /// Build with an explicit, *fixed* double-collect retry budget
    /// instead of the auto-tuned default.
    pub fn with_max_retries(max_threads: usize, opts: SizeOpts, max_retries: usize) -> Self {
        Self {
            inner: LinearizableSize::new(max_threads, opts),
            fallbacks: AtomicU64::new(0),
            budget: AtomicUsize::new(max_retries),
            auto_tune: false,
            streak: AtomicU64::new(0),
        }
    }

    /// The current retry budget (the configured value for fixed-budget
    /// instances, the adapted one for auto-tuned instances).
    pub fn max_retries(&self) -> usize {
        self.budget.load(Relaxed)
    }

    /// Whether this instance adapts its budget to observed fallbacks.
    pub fn auto_tuned(&self) -> bool {
        self.auto_tune
    }

    /// Auto-tune bookkeeping after an optimistic collect that succeeded
    /// on attempt `attempt` (0-based). Racy relaxed updates are fine —
    /// the budget is a heuristic, bounded on every path.
    #[inline]
    fn note_success(&self, attempt: usize) {
        if !self.auto_tune {
            return;
        }
        if attempt == 0 {
            let streak = self.streak.fetch_add(1, Relaxed) + 1;
            if streak % TUNE_GROW_STREAK == 0 {
                let budget = self.budget.load(Relaxed);
                if budget < OPTIMISTIC_TUNE_MAX {
                    self.budget.store(budget + 1, Relaxed);
                }
            }
        } else {
            self.streak.store(0, Relaxed);
        }
    }

    /// Auto-tune bookkeeping after a fallback: halve the budget (floor 1)
    /// so a contended instance stops burning sweeps it will not cash in.
    #[inline]
    fn note_fallback(&self) {
        if !self.auto_tune {
            return;
        }
        self.streak.store(0, Relaxed);
        let budget = self.budget.load(Relaxed);
        self.budget.store((budget / 2).max(1), Relaxed);
    }
}

impl SizePolicy for OptimisticSize {
    type InfoSlot = AtomicU64;
    type OpGuard<'a> = ();
    const TRACKED: bool = true;
    const HAS_SIZE: bool = true;

    fn new(max_threads: usize, opts: SizeOpts) -> Self {
        let mut p = Self::with_max_retries(max_threads, opts, OPTIMISTIC_MAX_RETRIES);
        p.auto_tune = true;
        p
    }

    #[inline(always)]
    fn enter(&self) -> () {}

    // ---- update side: delegated verbatim to the paper's protocol ----

    #[inline]
    fn begin_insert(&self, tid: usize) -> u64 {
        self.inner.begin_insert(tid)
    }

    #[inline]
    fn stash_insert_info(slot: &AtomicU64, packed: u64) {
        LinearizableSize::stash_insert_info(slot, packed);
    }

    #[inline]
    fn commit_insert(&self, slot: &AtomicU64, packed: u64) {
        self.inner.commit_insert(slot, packed);
    }

    #[inline]
    fn help_insert(&self, slot: &AtomicU64) {
        self.inner.help_insert(slot);
    }

    #[inline]
    fn begin_delete(&self, tid: usize) -> u64 {
        self.inner.begin_delete(tid)
    }

    #[inline]
    fn try_claim_delete(slot: &AtomicU64, packed: u64) -> u64 {
        LinearizableSize::try_claim_delete(slot, packed)
    }

    #[inline]
    fn read_delete_info(slot: &AtomicU64) -> u64 {
        LinearizableSize::read_delete_info(slot)
    }

    #[inline]
    fn commit_delete(&self, packed: u64) {
        self.inner.commit_delete(packed);
    }

    // ---- read side: optimistic double-collect, wait-free fallback ----

    fn size(&self) -> Option<i64> {
        let calc = self.inner.calc();
        let n = calc.nthreads();
        // Stack buffer, no per-call allocation (the whole point of the
        // optimistic path is that a size() is just two counter sweeps).
        // Calculators are never built wider than MAX_THREADS; if one ever
        // is, take the wait-free path rather than miscount.
        if n > crate::MAX_THREADS {
            return Some(calc.compute());
        }
        // Forced-fallback injection: behave exactly as if the retry
        // budget were exhausted (counted, tuned) so the wait-free path
        // and its telemetry get exercised under fuzzing.
        if faults::fires(FaultSite::OptimisticRetry) {
            self.fallbacks.fetch_add(1, SeqCst);
            self.note_fallback();
            return Some(calc.compute());
        }
        let mut snap = [0u64; 2 * crate::MAX_THREADS];
        'retry: for attempt in 0..self.budget.load(Relaxed) {
            for tid in 0..n {
                snap[2 * tid] = calc.counter(tid, OpKind::Insert);
                snap[2 * tid + 1] = calc.counter(tid, OpKind::Delete);
            }
            // Verify pass: each counter is monotone, so equality means it
            // held the collected value across the whole gap between the
            // two sweeps — the vector is a snapshot at that instant.
            for tid in 0..n {
                if calc.counter(tid, OpKind::Insert) != snap[2 * tid]
                    || calc.counter(tid, OpKind::Delete) != snap[2 * tid + 1]
                {
                    continue 'retry;
                }
            }
            let total: i64 = snap[..2 * n]
                .chunks_exact(2)
                .map(|p| p[0] as i64 - p[1] as i64)
                .sum();
            debug_assert!(total >= 0, "optimistic size went negative: {total}");
            self.note_success(attempt);
            return Some(total);
        }
        self.fallbacks.fetch_add(1, SeqCst);
        self.note_fallback();
        Some(calc.compute())
    }

    fn calculator(&self) -> Option<&SizeCalculator> {
        Some(self.inner.calc())
    }

    fn tuning(&self) -> Option<SizeTuning> {
        Some(SizeTuning {
            fallbacks: self.fallback_count(),
            retry_budget: self.budget.load(Relaxed) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    fn policy() -> OptimisticSize {
        OptimisticSize::new(8, SizeOpts::default())
    }

    #[test]
    fn sequential_size_never_falls_back() {
        let p = policy();
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(0);
        OptimisticSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        assert_eq!(p.size(), Some(1));
        let d = p.begin_delete(0);
        let won = OptimisticSize::try_claim_delete(&AtomicU64::new(0), d);
        p.commit_delete(won);
        assert_eq!(p.size(), Some(0));
        assert_eq!(p.fallback_count(), 0, "quiescent collects must succeed");
    }

    #[test]
    fn update_protocol_matches_linearizable_semantics() {
        let p = policy();
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(2);
        OptimisticSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        assert_eq!(slot.load(SeqCst), 0, "§7.1 slot clearing must be on");
        p.help_insert(&slot); // idempotent after clear
        assert_eq!(p.size(), Some(1));
    }

    #[test]
    fn claim_race_single_winner() {
        let slot = AtomicU64::new(0);
        let a = crate::size::UpdateInfo { tid: 1, counter: 1 }.pack();
        let b = crate::size::UpdateInfo { tid: 2, counter: 1 }.pack();
        assert_eq!(OptimisticSize::try_claim_delete(&slot, a), a);
        assert_eq!(OptimisticSize::try_claim_delete(&slot, b), a);
    }

    #[test]
    fn concurrent_churn_never_negative_and_fallback_safe() {
        let p = Arc::new(policy());
        let stop = Arc::new(AtomicBool::new(false));
        let churners: Vec<_> = (0..3usize)
            .map(|t| {
                let p = p.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    // Drive the calculator directly with per-thread legal
                    // (insert-then-delete) histories.
                    let mut c = 0u64;
                    while !stop.load(SeqCst) {
                        c += 1;
                        let i = crate::size::UpdateInfo { tid: t, counter: c }.pack();
                        p.inner.calc().update_metadata(i, OpKind::Insert);
                        p.inner.calc().update_metadata(i, OpKind::Delete);
                    }
                })
            })
            .collect();
        for _ in 0..2000 {
            let s = p.size().unwrap();
            assert!((0..=3).contains(&s), "non-linearizable size {s}");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(p.size(), Some(0));
    }

    #[test]
    fn calculator_is_exposed_for_analytics() {
        let p = policy();
        assert!(p.calculator().is_some());
    }

    #[test]
    fn fixed_budget_instances_never_tune() {
        let p = OptimisticSize::with_max_retries(4, SizeOpts::default(), 2);
        assert!(!p.auto_tuned());
        for _ in 0..200 {
            let _ = p.size();
        }
        assert_eq!(p.max_retries(), 2, "fixed budget drifted");
        assert_eq!(p.tuning().unwrap().retry_budget, 2);
    }

    #[test]
    fn auto_tuner_shrinks_on_fallbacks_and_regrows_on_success() {
        let p = policy();
        assert!(p.auto_tuned());
        assert_eq!(p.max_retries(), OPTIMISTIC_MAX_RETRIES);
        // Simulate observed fallbacks: the budget halves toward 1.
        for _ in 0..10 {
            p.note_fallback();
        }
        assert_eq!(p.max_retries(), 1, "halving must floor at 1");
        // A long first-try success streak grows it back, one step per
        // TUNE_GROW_STREAK successes, never past the ceiling.
        for _ in 0..(TUNE_GROW_STREAK * 3) {
            p.note_success(0);
        }
        assert_eq!(p.max_retries(), 4);
        for _ in 0..(TUNE_GROW_STREAK * 10 * OPTIMISTIC_TUNE_MAX as u64) {
            p.note_success(0);
        }
        assert_eq!(p.max_retries(), OPTIMISTIC_TUNE_MAX, "ceiling respected");
        // A retried (non-first-try) success resets the growth streak.
        p.note_success(1);
        assert_eq!(p.streak.load(Relaxed), 0);
    }

    #[test]
    fn quiescent_sizes_keep_budget_and_report_tuning() {
        let p = policy();
        for _ in 0..(TUNE_GROW_STREAK * 2) {
            assert_eq!(p.size(), Some(0));
        }
        let t = p.tuning().unwrap();
        assert_eq!(t.fallbacks, 0);
        assert!(
            t.retry_budget >= OPTIMISTIC_MAX_RETRIES as u64,
            "uncontended instance must not shrink its budget"
        );
    }
}
