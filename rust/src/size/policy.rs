//! Size policies: the compile-time switch between the paper's transformed
//! data structure, the untouched baseline, and the two strawmen the paper
//! argues against (Section 1).
//!
//! Every data structure in this crate is generic over a [`SizePolicy`]:
//!
//! * [`NoSize`] — the baseline. All hooks are no-ops and the per-node info
//!   slots are zero-sized, so the monomorphized structure is bit-identical
//!   to the untransformed algorithm (this is what Figures 7–9 measure
//!   against).
//! * [`LinearizableSize`] — the paper's methodology (Sections 4–7):
//!   operations publish `UpdateInfo`, help dependent operations reach their
//!   metadata linearization point, and `size()` is wait-free O(#threads).
//! * [`NaiveSize`] — Java's `ConcurrentSkipListMap`-style counter updated
//!   *after* the structure update. Non-linearizable: exhibits the Figure 1
//!   (contains/size contradiction) and Figure 2 (negative size) anomalies.
//!   An optional artificial delay widens the race window for the demos.
//! * [`LockSize`] — the coarse global-lock alternative: updates take a read
//!   lock, `size()` takes the write lock. Correct but a scalability
//!   bottleneck (the `ablation_policies` bench quantifies it).
//!
//! The optimized methods of the follow-up synchronization-methods study —
//! [`super::HandshakeSize`] and [`super::OptimisticSize`] — live in their
//! own modules (`handshake.rs`, `optimistic.rs`) and implement the same
//! trait, so every structure gets all six policies generically.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering::SeqCst};
use std::sync::RwLock;
use std::time::Duration;

use crate::pad::CachePadded;

use super::{OpKind, SizeCalculator, SizeOpts};

/// Read-side tuning diagnostics of a policy with an adaptive size path
/// (today: [`super::OptimisticSize`]'s retry-budget auto-tuner). Surfaced
/// through [`super::ArbiterStats`] by every structure's `size_stats()`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SizeTuning {
    /// Times `size()` exhausted its optimistic budget and fell back to
    /// the wait-free path.
    pub fallbacks: u64,
    /// The current retry budget (fixed or auto-tuned).
    pub retry_budget: u64,
}

/// Compile-time hooks a size-aware data structure invokes at the points the
/// paper's transformation prescribes (Fig. 3). `InfoSlot` is the per-node
/// storage for published `UpdateInfo` (zero-sized when untracked).
pub trait SizePolicy: Send + Sync + Sized + 'static {
    /// Per-node storage for packed `UpdateInfo` (insert-info and, for
    /// mark-by-slot structures, delete-info).
    type InfoSlot: Send + Sync + Default;
    /// Held for the duration of every structure operation (only `LockSize`
    /// uses a non-trivial guard).
    type OpGuard<'a>
    where
        Self: 'a;

    /// Whether the linearizable-metadata protocol is active (drives the
    /// tracked-specific branches in the structures; `false` branches
    /// compile away).
    const TRACKED: bool;

    /// Whether [`Self::size`] returns `Some` — lets the arbiter wiring
    /// answer size-less policies without paying for a call.
    const HAS_SIZE: bool;

    fn new(max_threads: usize, opts: SizeOpts) -> Self;

    /// Enter an update operation (Fig. 3 wraps every op; only `LockSize`
    /// and `HandshakeSize` have non-trivial guards).
    fn enter(&self) -> Self::OpGuard<'_>;

    /// Enter a read-only operation (`contains`). Defaults to [`Self::enter`];
    /// `HandshakeSize` overrides it to skip the handshake entirely — only
    /// update drains are load-bearing for size linearizability, since the
    /// structure is frozen during a size's read window and a reader then
    /// observes exactly the counted state.
    fn enter_read(&self) -> Self::OpGuard<'_> {
        self.enter()
    }

    // ---- insert path (Fig. 3 lines 15–26) ----

    /// `createUpdateInfo(INSERT)` — packed info for the upcoming insert.
    fn begin_insert(&self, tid: usize) -> u64;
    /// Store the packed info in a *pre-publication* node (plain store).
    fn stash_insert_info(slot: &Self::InfoSlot, packed: u64);
    /// After the node is linked (the original linearization point): reach
    /// the new linearization point (`updateMetadata`), then clear the slot
    /// (§7.1).
    fn commit_insert(&self, slot: &Self::InfoSlot, packed: u64);
    /// An operation observed an unmarked node it depends on: ensure the
    /// insert that created it is reflected (Fig. 3 lines 9–10, 17–18, 33).
    fn help_insert(&self, slot: &Self::InfoSlot);

    // ---- delete path (Fig. 3 lines 27–38) ----

    /// `createUpdateInfo(DELETE)`.
    fn begin_delete(&self, tid: usize) -> u64;
    /// Race to install delete-info in the node's slot (the *marking* step of
    /// slot-marked structures): returns the winning packed info. Untracked
    /// policies return 0 (their structures mark via pointer bits instead).
    fn try_claim_delete(slot: &Self::InfoSlot, packed: u64) -> u64;
    /// Read the installed delete-info (0 if none).
    fn read_delete_info(slot: &Self::InfoSlot) -> u64;
    /// The delete reached its original linearization point (the mark):
    /// reach the new one (`updateMetadata`). Must run *before* any unlink
    /// attempt (Fig. 3 footnote). Idempotent; helpers call it too.
    fn commit_delete(&self, packed: u64);

    // ---- size ----

    /// The structure's `size()`; `None` when the policy does not provide one.
    fn size(&self) -> Option<i64>;

    /// Access to the underlying calculator (tracked policies only).
    fn calculator(&self) -> Option<&SizeCalculator> {
        None
    }

    /// Read-side tuning diagnostics (`None` unless the policy adapts its
    /// size path — see [`SizeTuning`]).
    fn tuning(&self) -> Option<SizeTuning> {
        None
    }
}

// --------------------------------------------------------------------------
/// Baseline: the untransformed data structure (paper's `SkipList`,
/// `HashTable`, `BST`).
pub struct NoSize;

impl SizePolicy for NoSize {
    type InfoSlot = ();
    type OpGuard<'a> = ();
    const TRACKED: bool = false;
    const HAS_SIZE: bool = false;

    fn new(_: usize, _: SizeOpts) -> Self {
        NoSize
    }
    #[inline(always)]
    fn enter(&self) -> () {}
    #[inline(always)]
    fn begin_insert(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn stash_insert_info(_: &(), _: u64) {}
    #[inline(always)]
    fn commit_insert(&self, _: &(), _: u64) {}
    #[inline(always)]
    fn help_insert(&self, _: &()) {}
    #[inline(always)]
    fn begin_delete(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn try_claim_delete(_: &(), _: u64) -> u64 {
        0
    }
    #[inline(always)]
    fn read_delete_info(_: &()) -> u64 {
        0
    }
    #[inline(always)]
    fn commit_delete(&self, _: u64) {}
    #[inline(always)]
    fn size(&self) -> Option<i64> {
        None
    }
}

// --------------------------------------------------------------------------
/// The paper's methodology: linearizable wait-free size.
pub struct LinearizableSize {
    calc: SizeCalculator,
}

impl LinearizableSize {
    /// Direct calculator access for sibling policies that embed this one
    /// (`OptimisticSize` reuses the whole update-side protocol).
    pub(super) fn calc(&self) -> &SizeCalculator {
        &self.calc
    }
}

impl SizePolicy for LinearizableSize {
    type InfoSlot = AtomicU64;
    type OpGuard<'a> = ();
    const TRACKED: bool = true;
    const HAS_SIZE: bool = true;

    fn new(max_threads: usize, opts: SizeOpts) -> Self {
        Self {
            calc: SizeCalculator::new(max_threads, opts),
        }
    }

    #[inline(always)]
    fn enter(&self) -> () {}

    #[inline]
    fn begin_insert(&self, tid: usize) -> u64 {
        self.calc.create_update_info(OpKind::Insert, tid)
    }

    #[inline]
    fn stash_insert_info(slot: &AtomicU64, packed: u64) {
        // Pre-publication: the node is not yet reachable, a plain store
        // would do; Relaxed keeps it race-free under the memory model.
        slot.store(packed, std::sync::atomic::Ordering::Relaxed);
    }

    #[inline]
    fn commit_insert(&self, slot: &AtomicU64, packed: u64) {
        self.calc.update_metadata(packed, OpKind::Insert);
        if self.calc.opts().clear_insert_info {
            slot.store(0, SeqCst); // §7.1: signal "already reflected"
        }
    }

    #[inline]
    fn help_insert(&self, slot: &AtomicU64) {
        let packed = slot.load(SeqCst);
        if packed != 0 {
            self.calc.update_metadata(packed, OpKind::Insert);
        }
    }

    #[inline]
    fn begin_delete(&self, tid: usize) -> u64 {
        self.calc.create_update_info(OpKind::Delete, tid)
    }

    #[inline]
    fn try_claim_delete(slot: &AtomicU64, packed: u64) -> u64 {
        match slot.compare_exchange(0, packed, SeqCst, SeqCst) {
            Ok(_) => packed,
            Err(winner) => winner,
        }
    }

    #[inline]
    fn read_delete_info(slot: &AtomicU64) -> u64 {
        slot.load(SeqCst)
    }

    #[inline]
    fn commit_delete(&self, packed: u64) {
        if packed != 0 {
            self.calc.update_metadata(packed, OpKind::Delete);
        }
    }

    #[inline]
    fn size(&self) -> Option<i64> {
        Some(self.calc.compute())
    }

    fn calculator(&self) -> Option<&SizeCalculator> {
        Some(&self.calc)
    }
}

// --------------------------------------------------------------------------
/// Java-style non-linearizable size: a shared counter bumped *after* the
/// data-structure update (paper Section 1, Figures 1–2).
pub struct NaiveSize {
    size: CachePadded<AtomicI64>,
    /// Optional artificial delays between the structure update and the
    /// counter update, widening the anomaly windows for demos/tests.
    /// An insert-only window reproduces the paper's Figure 2 interleaving
    /// (T_ins preempted before its increment while T_del's decrement lands).
    insert_window: Option<Duration>,
    delete_window: Option<Duration>,
}

impl NaiveSize {
    /// Set the anomaly-window delay on both op kinds (call before sharing).
    pub fn set_window(&mut self, window: Duration) {
        self.insert_window = Some(window);
        self.delete_window = Some(window);
    }

    /// Delay only the insert's metadata update (the Figure 2 schedule).
    pub fn set_insert_window(&mut self, window: Duration) {
        self.insert_window = Some(window);
    }

    #[inline]
    fn delay(window: Option<Duration>) {
        if let Some(w) = window {
            std::thread::sleep(w);
        }
    }
}

impl SizePolicy for NaiveSize {
    type InfoSlot = ();
    type OpGuard<'a> = ();
    const TRACKED: bool = false;
    const HAS_SIZE: bool = true;

    fn new(_: usize, _: SizeOpts) -> Self {
        Self {
            size: CachePadded::new(AtomicI64::new(0)),
            insert_window: None,
            delete_window: None,
        }
    }

    #[inline(always)]
    fn enter(&self) -> () {}
    #[inline(always)]
    fn begin_insert(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn stash_insert_info(_: &(), _: u64) {}

    #[inline]
    fn commit_insert(&self, _: &(), _: u64) {
        // The separation between the structure update (already visible) and
        // this counter update is exactly the paper's Figure 1/2 bug.
        Self::delay(self.insert_window);
        self.size.fetch_add(1, SeqCst);
    }

    #[inline(always)]
    fn help_insert(&self, _: &()) {}
    #[inline(always)]
    fn begin_delete(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn try_claim_delete(_: &(), _: u64) -> u64 {
        0
    }
    #[inline(always)]
    fn read_delete_info(_: &()) -> u64 {
        0
    }

    #[inline]
    fn commit_delete(&self, _: u64) {
        Self::delay(self.delete_window);
        self.size.fetch_sub(1, SeqCst);
    }

    #[inline]
    fn size(&self) -> Option<i64> {
        Some(self.size.load(SeqCst))
    }
}

// --------------------------------------------------------------------------
/// Coarse-grained global-lock size (paper Section 1, "third alternative").
pub struct LockSize {
    lock: RwLock<()>,
    size: CachePadded<AtomicI64>,
}

impl SizePolicy for LockSize {
    type InfoSlot = ();
    type OpGuard<'a> = std::sync::RwLockReadGuard<'a, ()>;
    const TRACKED: bool = false;
    const HAS_SIZE: bool = true;

    fn new(_: usize, _: SizeOpts) -> Self {
        Self {
            lock: RwLock::new(()),
            size: CachePadded::new(AtomicI64::new(0)),
        }
    }

    #[inline]
    fn enter(&self) -> Self::OpGuard<'_> {
        self.lock.read().unwrap()
    }

    #[inline(always)]
    fn begin_insert(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn stash_insert_info(_: &(), _: u64) {}

    #[inline]
    fn commit_insert(&self, _: &(), _: u64) {
        // Runs while the op's read guard is held: ordered w.r.t. size().
        self.size.fetch_add(1, SeqCst);
    }

    #[inline(always)]
    fn help_insert(&self, _: &()) {}
    #[inline(always)]
    fn begin_delete(&self, _: usize) -> u64 {
        0
    }
    #[inline(always)]
    fn try_claim_delete(_: &(), _: u64) -> u64 {
        0
    }
    #[inline(always)]
    fn read_delete_info(_: &()) -> u64 {
        0
    }

    #[inline]
    fn commit_delete(&self, _: u64) {
        self.size.fetch_sub(1, SeqCst);
    }

    #[inline]
    fn size(&self) -> Option<i64> {
        let _w = self.lock.write().unwrap();
        Some(self.size.load(SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nosize_is_zero_cost_storage() {
        assert_eq!(std::mem::size_of::<<NoSize as SizePolicy>::InfoSlot>(), 0);
    }

    #[test]
    fn linearizable_tracks_commits() {
        let p = LinearizableSize::new(4, SizeOpts::default());
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(0);
        LinearizableSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        assert_eq!(slot.load(SeqCst), 0, "opt 7.1 must clear the slot");
        assert_eq!(p.size(), Some(1));
        let d = p.begin_delete(0);
        let won = LinearizableSize::try_claim_delete(&AtomicU64::new(0), d);
        assert_eq!(won, d);
        p.commit_delete(won);
        assert_eq!(p.size(), Some(0));
    }

    #[test]
    fn claim_delete_race_single_winner() {
        let slot = AtomicU64::new(0);
        let a = crate::size::UpdateInfo { tid: 1, counter: 1 }.pack();
        let b = crate::size::UpdateInfo { tid: 2, counter: 1 }.pack();
        assert_eq!(LinearizableSize::try_claim_delete(&slot, a), a);
        assert_eq!(
            LinearizableSize::try_claim_delete(&slot, b),
            a,
            "loser adopts winner"
        );
        assert_eq!(LinearizableSize::read_delete_info(&slot), a);
    }

    #[test]
    fn helping_twice_counts_once() {
        let p = LinearizableSize::new(2, SizeOpts::NONE); // no slot clearing
        let slot = AtomicU64::new(0);
        let i = p.begin_insert(1);
        LinearizableSize::stash_insert_info(&slot, i);
        p.commit_insert(&slot, i);
        p.help_insert(&slot); // helper after commit: idempotent
        p.help_insert(&slot);
        assert_eq!(p.size(), Some(1));
    }

    #[test]
    fn naive_counts_but_lags() {
        let p = NaiveSize::new(1, SizeOpts::default());
        p.commit_insert(&(), 0);
        p.commit_insert(&(), 0);
        p.commit_delete(0);
        assert_eq!(p.size(), Some(1));
    }

    #[test]
    fn lock_size_is_consistent_under_guard() {
        let p = LockSize::new(1, SizeOpts::default());
        {
            let _g = p.enter();
            p.commit_insert(&(), 0);
        }
        assert_eq!(p.size(), Some(1));
    }
}
