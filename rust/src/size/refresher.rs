//! The background `SizeRefresher` daemon: periodic publication so
//! `size_recent` becomes a truly passive read.
//!
//! The arbiter (`arbiter.rs`) publishes a result only when some caller
//! drives a round, so the first `size_recent` after a quiet spell always
//! pays for a collect — the availability gap ROADMAP's "background size
//! thread" item names. A [`SizeRefresher`] closes it: one owned thread
//! per structure wakes every `period`, checks whether the published
//! result is already fresh enough (a caller-driven round within the
//! period makes the wake a no-op), and otherwise drives one combining
//! round through [`SizeArbiter::exact_for`]. With a daemon running,
//! `size_recent(d)` for any `d ≥ period + collect latency` is served by
//! the published result essentially always — one wait-free EBR-pinned
//! load — while its `SizeView::age ≤ d` bound keeps holding verbatim
//! (staleness enforcement lives in `size_recent` itself and is untouched).
//!
//! ## Ownership
//!
//! The daemon must outlive neither the policy nor the arbiter it drives,
//! so both live in a shared [`SizeCore`] (`Arc`ed by the structure and by
//! the daemon thread). Structures hold the daemon in a [`RefresherSlot`]
//! — interior-mutable so `ConcurrentSet::set_refresh_period` works
//! through `&self` — and dropping the slot (or the structure) signals the
//! thread through a condvar and **joins it**: shutdown is synchronous,
//! no refresh can run after the structure's drop completes.

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::faults::{self, FaultSite};

use super::policy::SizePolicy;
use super::{ArbiterStats, SizeArbiter};

/// Shortest accepted refresh period: below this the daemon would degrade
/// into a busy loop that starves the workload it is meant to serve.
pub const MIN_REFRESH_PERIOD: Duration = Duration::from_micros(50);

/// The shared heart of a size-aware structure: its policy instance plus
/// the combining arbiter in front of it. Structures `Arc` one so the
/// [`SizeRefresher`] thread can keep driving rounds without borrowing the
/// structure itself.
pub struct SizeCore<P: SizePolicy> {
    pub policy: P,
    pub arbiter: SizeArbiter,
}

impl<P: SizePolicy> SizeCore<P> {
    pub fn new(policy: P) -> Self {
        Self {
            policy,
            arbiter: SizeArbiter::new(),
        }
    }

    /// Arbiter stats merged with the policy's [`super::SizeTuning`] and
    /// the given daemon round count — the one `size_stats()` body shared
    /// by all four structures.
    pub fn stats(&self, daemon_rounds: u64) -> ArbiterStats {
        let mut stats = self.arbiter.stats();
        if let Some(tuning) = self.policy.tuning() {
            stats.fallbacks = tuning.fallbacks;
            stats.retry_budget = tuning.retry_budget;
        }
        stats.daemon_rounds = daemon_rounds;
        stats
    }
}

/// Condvar-guarded daemon state (one per running refresher).
struct Shared {
    stop: Mutex<bool>,
    wake: Condvar,
    /// Rounds this daemon actually drove (fresh-enough wakes are skipped).
    rounds: AtomicU64,
}

fn lock_stop(shared: &Shared) -> MutexGuard<'_, bool> {
    shared.stop.lock().unwrap_or_else(|p| p.into_inner())
}

/// An owned background thread that periodically refreshes one structure's
/// published size. Dropping it stops and joins the thread.
pub struct SizeRefresher {
    shared: Arc<Shared>,
    handle: Option<JoinHandle<()>>,
    period: Duration,
}

impl SizeRefresher {
    /// Spawn a daemon driving `core`'s arbiter every `period` (clamped to
    /// [`MIN_REFRESH_PERIOD`]). `None` when the policy has no `size()` —
    /// there is nothing to publish.
    pub fn spawn<P: SizePolicy>(core: Arc<SizeCore<P>>, period: Duration) -> Option<Self> {
        if !P::HAS_SIZE {
            return None;
        }
        let period = period.max(MIN_REFRESH_PERIOD);
        let shared = Arc::new(Shared {
            stop: Mutex::new(false),
            wake: Condvar::new(),
            rounds: AtomicU64::new(0),
        });
        let thread_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("size-refresher".into())
            .spawn(move || Self::run(core, thread_shared, period))
            .expect("failed to spawn size-refresher thread");
        Some(Self {
            shared,
            handle: Some(handle),
            period,
        })
    }

    fn run<P: SizePolicy>(core: Arc<SizeCore<P>>, shared: Arc<Shared>, period: Duration) {
        let mut stopped = lock_stop(&shared);
        loop {
            if *stopped {
                return;
            }
            drop(stopped);
            // A `Delay` here stalls the daemon, exercising the arbiter's
            // stall-detection fallback (`daemon_stalls`).
            faults::jitter(FaultSite::RefresherTick);
            // A caller-driven round within the period makes this wake a
            // no-op — the daemon only fills publication gaps.
            let stale = match core.arbiter.published_age() {
                None => true,
                Some(age) => age >= period,
            };
            if stale {
                // Count only rounds this daemon actually drove: an
                // adopted view means a concurrent caller's round served
                // the refresh (its collect, not ours).
                if let Some(view) = core.arbiter.exact_for(&core.policy) {
                    if !view.shared {
                        shared.rounds.fetch_add(1, SeqCst);
                    }
                }
            }
            stopped = lock_stop(&shared);
            if *stopped {
                return;
            }
            let (guard, _timeout) = shared
                .wake
                .wait_timeout(stopped, period)
                .unwrap_or_else(|p| p.into_inner());
            stopped = guard;
        }
    }

    /// The configured refresh period (post-clamp).
    pub fn period(&self) -> Duration {
        self.period
    }

    /// Rounds this daemon drove so far (skipped fresh wakes not counted).
    pub fn rounds(&self) -> u64 {
        self.shared.rounds.load(SeqCst)
    }
}

impl Drop for SizeRefresher {
    fn drop(&mut self) {
        *lock_stop(&self.shared) = true;
        self.shared.wake.notify_all();
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// A structure's refresher mount point: `set_refresh_period` installs,
/// replaces, or stops the daemon through `&self`, and the daemon round
/// counter survives daemon replacement so `ArbiterStats::daemon_rounds`
/// stays monotone.
#[derive(Default)]
pub struct RefresherSlot {
    slot: Mutex<Option<SizeRefresher>>,
    /// Rounds accumulated by daemons that were since stopped/replaced.
    retired_rounds: AtomicU64,
    /// The running daemon's period in nanos (0 = no daemon): a lock-free
    /// mirror of the slot for the `size_recent` hot path, which consults
    /// it on every call for stall detection and must not contend with a
    /// daemon swap (whose join can take a full collect).
    period_nanos: AtomicU64,
}

impl RefresherSlot {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> MutexGuard<'_, Option<SizeRefresher>> {
        self.slot.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// `Some(period)` (re)starts the daemon at that period, `None` stops
    /// it; the previous daemon — if any — is joined before any new one
    /// spawns. Returns whether a daemon is running after the call
    /// (`false` for `None` and for size-less policies).
    pub fn set<P: SizePolicy>(&self, core: &Arc<SizeCore<P>>, period: Option<Duration>) -> bool {
        // Swap the old daemon out and release the slot lock BEFORE the
        // join: a shutdown can take a full collect (handshake drain), and
        // stats readers share this mutex — they must never block on it.
        let old = self.lock().take();
        self.period_nanos.store(0, SeqCst);
        self.retire(old);
        match period {
            Some(p) => {
                let fresh = SizeRefresher::spawn(core.clone(), p);
                let running = fresh.is_some();
                let nanos = fresh.as_ref().map_or(0, |d| d.period().as_nanos() as u64);
                // Normally a no-op: `displaced` is only Some when another
                // set() raced in between the take above and this store.
                let displaced = std::mem::replace(&mut *self.lock(), fresh);
                self.period_nanos.store(nanos, SeqCst);
                self.retire(displaced);
                running
            }
            None => false,
        }
    }

    /// Stop-and-join a daemon (slot lock NOT held) and fold its rounds
    /// into the cumulative counter — counted after the join, so a round
    /// completing during shutdown is not lost.
    fn retire(&self, daemon: Option<SizeRefresher>) {
        if let Some(daemon) = daemon {
            let shared = Arc::clone(&daemon.shared);
            drop(daemon); // synchronous stop + join
            self.retired_rounds.fetch_add(shared.rounds.load(SeqCst), SeqCst);
        }
    }

    /// Daemon-driven rounds across the current and all previous daemons.
    pub fn rounds(&self) -> u64 {
        let slot = self.lock();
        self.retired_rounds.load(SeqCst) + slot.as_ref().map_or(0, SizeRefresher::rounds)
    }

    /// The running daemon's period, when one is active.
    pub fn period(&self) -> Option<Duration> {
        self.lock().as_ref().map(SizeRefresher::period)
    }

    /// Lock-free view of [`Self::period`] (the `size_recent` hot path's
    /// stall-detection input; may trail a concurrent `set` briefly).
    pub fn active_period(&self) -> Option<Duration> {
        match self.period_nanos.load(SeqCst) {
            0 => None,
            nanos => Some(Duration::from_nanos(nanos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NoSize, SizeOpts};
    use std::time::Instant;

    fn core() -> Arc<SizeCore<LinearizableSize>> {
        Arc::new(SizeCore::new(LinearizableSize::new(8, SizeOpts::default())))
    }

    fn wait_for(mut cond: impl FnMut() -> bool, what: &str) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn refresher_publishes_without_any_caller() {
        let core = core();
        let r = SizeRefresher::spawn(core.clone(), Duration::from_micros(100)).unwrap();
        wait_for(|| core.arbiter.rounds() >= 2, "two daemon rounds");
        assert!(r.rounds() >= 2);
        assert!(core.arbiter.published_view().is_some());
        drop(r);
    }

    #[test]
    fn refresher_stops_on_drop() {
        let core = core();
        let r = SizeRefresher::spawn(core.clone(), Duration::from_micros(100)).unwrap();
        wait_for(|| core.arbiter.rounds() >= 1, "first daemon round");
        drop(r); // joins: no refresh may run past this point
        let rounds = core.arbiter.rounds();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(core.arbiter.rounds(), rounds, "daemon survived drop");
    }

    #[test]
    fn refresher_declines_sizeless_policies() {
        let core = Arc::new(SizeCore::new(NoSize::new(8, SizeOpts::default())));
        assert!(SizeRefresher::spawn(core, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn period_is_clamped_to_minimum() {
        let r = SizeRefresher::spawn(core(), Duration::ZERO).unwrap();
        assert_eq!(r.period(), MIN_REFRESH_PERIOD);
    }

    #[test]
    fn slot_replaces_and_stops_daemons() {
        let core = core();
        let slot = RefresherSlot::new();
        assert!(!slot.set(&core, None), "stopping an empty slot is a no-op");
        assert!(slot.set(&core, Some(Duration::from_micros(100))));
        wait_for(|| slot.rounds() >= 1, "slot daemon round");
        // Replacement keeps the cumulative round counter monotone.
        assert!(slot.set(&core, Some(Duration::from_millis(5))));
        let after_swap = slot.rounds();
        assert!(after_swap >= 1);
        assert_eq!(slot.period(), Some(Duration::from_millis(5)));
        assert_eq!(slot.active_period(), Some(Duration::from_millis(5)));
        assert!(!slot.set(&core, None));
        assert_eq!(slot.period(), None);
        assert_eq!(slot.active_period(), None);
        assert!(slot.rounds() >= after_swap);
    }

    #[test]
    fn core_stats_merges_tuning_and_daemon_rounds() {
        let core = Arc::new(SizeCore::new(crate::size::OptimisticSize::new(
            8,
            SizeOpts::default(),
        )));
        let _ = core.arbiter.exact_for(&core.policy);
        let stats = core.stats(7);
        assert_eq!(stats.rounds, 1);
        assert_eq!(stats.daemon_rounds, 7);
        assert!(stats.retry_budget > 0, "optimistic tuning must surface");
    }
}
