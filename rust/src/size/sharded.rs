//! Sharded (striped) size counters — the NUMA-scale collect layer.
//!
//! The paper's metadata is one cache-padded counter pair per *thread*
//! (`MAX_THREADS` = 64 of them), so every collect — the wait-free
//! snapshot sweep and `OptimisticSize`'s double-collect alike — walks 64
//! cache lines even when only four threads are live. On big multi-socket
//! boxes the sweep cost is pure cross-node traffic. This module adds the
//! scale knob ROADMAP calls "sharded/batched size for NUMA": a striped
//! mirror of the metadata with `shards ≤ MAX_THREADS` cache-padded
//! `[insertions, deletions]` stripes (thread `tid` writes stripe
//! `tid % shards`), kept in sync at the paper protocol's exactly-once
//! point — the winning metadata-counter CAS in
//! [`SizeCalculator::update_metadata`] — so each committed operation
//! bumps its stripe exactly once, no matter how many helpers race.
//!
//! ## The batched reconciliation collect
//!
//! [`ShardedCounters::reconcile`] first tries a bounded optimistic
//! double-collect over the `2 × shards` stripe counters (each stripe
//! counter is monotone, so two identical sweeps pin the whole vector to
//! one instant), and falls back to a single loose sweep when updates keep
//! invalidating it. The result is a **bounded-lag estimate**, not a
//! linearizable size: an operation between its metadata CAS and its
//! stripe bump (or an unhelped pending operation) is missing from the
//! stripes, so the estimate may trail the exact size by up to the number
//! of in-flight operations — and is exact at quiescence. Callers that
//! need linearizability use the policy's own `size()` (or the arbiter);
//! callers that only need a cheap O(shards) probe — monitoring loops, the
//! `kv_server` `SIZE?` endpoint, admission-control heuristics — read the
//! stripes and never touch the snapshot machinery.
//!
//! [`SizeCalculator::update_metadata`]: super::SizeCalculator::update_metadata

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};

use crate::pad::CachePadded;

use super::OpKind;

/// Double-collect attempts before [`ShardedCounters::reconcile`] settles
/// for a loose single sweep.
const RECONCILE_ATTEMPTS: usize = 4;

/// `num_cpus`-style shard-count detection: the machine's available
/// parallelism, clamped to `[1, MAX_THREADS]` (stripes beyond the thread
/// count could never be written). The CLI surfaces expose this as
/// `--size-shards auto`.
pub fn detect_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, crate::MAX_THREADS)
}

/// Cache-padded striped `[insertions, deletions]` counters; thread `tid`
/// records into stripe `tid % shards`. Multi-writer (plain `fetch_add`),
/// monotone per stripe.
pub struct ShardedCounters {
    stripes: Box<[CachePadded<[AtomicU64; 2]>]>,
}

impl ShardedCounters {
    /// Build with `shards` stripes, clamped to `[1, MAX_THREADS]`.
    pub fn new(shards: usize) -> Self {
        let shards = shards.clamp(1, crate::MAX_THREADS);
        Self {
            stripes: (0..shards)
                .map(|_| CachePadded::new([AtomicU64::new(0), AtomicU64::new(0)]))
                .collect(),
        }
    }

    /// Number of stripes.
    #[inline]
    pub fn shards(&self) -> usize {
        self.stripes.len()
    }

    /// The stripe index `tid` maps to.
    #[inline]
    pub fn shard_for(&self, tid: usize) -> usize {
        tid % self.stripes.len()
    }

    /// Record one committed operation of `kind` by thread `tid`. The
    /// caller guarantees exactly-once (the calculator invokes this only
    /// from the winning metadata-counter CAS).
    #[inline]
    pub fn record(&self, tid: usize, kind: OpKind) {
        self.stripes[self.shard_for(tid)][kind as usize].fetch_add(1, SeqCst);
    }

    /// One loose sweep: `(insertions, deletions)` totals. Not an atomic
    /// snapshot — counters may move between stripe reads.
    pub fn collect(&self) -> (u64, u64) {
        let mut ins = 0u64;
        let mut del = 0u64;
        for stripe in self.stripes.iter() {
            ins += stripe[OpKind::Insert as usize].load(SeqCst);
            del += stripe[OpKind::Delete as usize].load(SeqCst);
        }
        (ins, del)
    }

    /// Optimistic double-collect: `Some((ins, del))` when two consecutive
    /// sweeps observe identical stripe vectors — monotonicity then pins
    /// every counter to its value at the instant between the sweeps, so
    /// the totals form an atomic snapshot of the *stripes* (see the
    /// module docs for what that does and does not imply about the set).
    pub fn try_snapshot(&self, attempts: usize) -> Option<(u64, u64)> {
        let n = self.stripes.len();
        debug_assert!(n <= crate::MAX_THREADS);
        let mut snap = [0u64; 2 * crate::MAX_THREADS];
        'retry: for _ in 0..attempts {
            for (i, stripe) in self.stripes.iter().enumerate() {
                snap[2 * i] = stripe[OpKind::Insert as usize].load(SeqCst);
                snap[2 * i + 1] = stripe[OpKind::Delete as usize].load(SeqCst);
            }
            for (i, stripe) in self.stripes.iter().enumerate() {
                if stripe[OpKind::Insert as usize].load(SeqCst) != snap[2 * i]
                    || stripe[OpKind::Delete as usize].load(SeqCst) != snap[2 * i + 1]
                {
                    continue 'retry;
                }
            }
            let (mut ins, mut del) = (0u64, 0u64);
            for pair in snap[..2 * n].chunks_exact(2) {
                ins += pair[0];
                del += pair[1];
            }
            return Some((ins, del));
        }
        None
    }

    /// The batched reconciliation collect: a stable double-collect when
    /// one lands within [`RECONCILE_ATTEMPTS`], a loose sweep otherwise.
    /// Returns the net count (`insertions − deletions`), clamped at zero:
    /// a delete's stripe bump can land while the matching insert's bump is
    /// still in flight on another stripe, so the raw difference may dip
    /// below zero mid-churn even though the set never did.
    pub fn reconcile(&self) -> i64 {
        let (ins, del) = self
            .try_snapshot(RECONCILE_ATTEMPTS)
            .unwrap_or_else(|| self.collect());
        (ins as i64 - del as i64).max(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn detect_shards_is_in_range() {
        let n = detect_shards();
        assert!((1..=crate::MAX_THREADS).contains(&n));
    }

    #[test]
    fn shard_count_is_clamped() {
        assert_eq!(ShardedCounters::new(0).shards(), 1);
        assert_eq!(ShardedCounters::new(3).shards(), 3);
        assert_eq!(
            ShardedCounters::new(crate::MAX_THREADS * 2).shards(),
            crate::MAX_THREADS
        );
    }

    #[test]
    fn threads_stripe_by_modulo() {
        let sh = ShardedCounters::new(4);
        assert_eq!(sh.shard_for(0), 0);
        assert_eq!(sh.shard_for(5), 1);
        assert_eq!(sh.shard_for(63), 3);
    }

    #[test]
    fn sequential_record_and_collect() {
        let sh = ShardedCounters::new(4);
        for tid in 0..10 {
            sh.record(tid, OpKind::Insert);
        }
        for tid in 0..3 {
            sh.record(tid, OpKind::Delete);
        }
        assert_eq!(sh.collect(), (10, 3));
        assert_eq!(sh.try_snapshot(1), Some((10, 3)));
        assert_eq!(sh.reconcile(), 7);
    }

    #[test]
    fn single_stripe_degenerates_to_one_pair() {
        let sh = ShardedCounters::new(1);
        for tid in 0..20 {
            sh.record(tid, OpKind::Insert);
        }
        assert_eq!(sh.shards(), 1);
        assert_eq!(sh.reconcile(), 20);
    }

    #[test]
    fn concurrent_paired_ops_reconcile_to_quiescent_truth() {
        let sh = Arc::new(ShardedCounters::new(4));
        let handles: Vec<_> = (0..4usize)
            .map(|tid| {
                let sh = sh.clone();
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        sh.record(tid, OpKind::Insert);
                        sh.record(tid, OpKind::Delete);
                    }
                    sh.record(tid, OpKind::Insert); // net +1 per thread
                })
            })
            .collect();
        // Concurrent probes: the bounded-lag estimate is clamped at zero
        // and — because each stripe reads insertions before deletions —
        // can never exceed the live net count.
        for _ in 0..200 {
            let est = sh.reconcile();
            assert!((0..=4).contains(&est), "estimate {est} out of bounds");
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sh.reconcile(), 4, "exact at quiescence");
        assert_eq!(sh.try_snapshot(1), Some((4 * 5_000 + 4, 4 * 5_000)));
    }
}
