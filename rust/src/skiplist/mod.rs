//! Lock-free skip-list set, generic over the size policy.
//!
//! Tower-based lock-free skip list (Fraser 2004 / Herlihy–Shavit Ch. 14
//! style, the same family as Java's `ConcurrentSkipListMap` the paper
//! evaluates): each node carries its full `next` tower; logical membership
//! is decided at the bottom level.
//!
//! ## Deletion state machine (paper Section 4)
//!
//! * **Tracked**: the marking step is installing the packed `UpdateInfo`
//!   into `delete_info` (the paper's `ConcurrentSkipListMap` adaptation:
//!   the value field is repointed at the `UpdateInfo` instead of `NULL`).
//!   Metadata is updated (`commit_delete`) before the physical mark/unlink.
//! * **Untracked**: classic scheme — the bottom-level next-pointer mark CAS
//!   is the logical delete.
//!
//! Physical removal: mark every level top-down, then `find` unlinks.

use std::sync::atomic::{AtomicU64, Ordering::SeqCst};
use std::sync::Arc;

use crate::ebr;
use crate::rng::Xoshiro256;
use crate::set_api::{ConcurrentSet, MAX_KEY};
use crate::size::{RefresherSlot, SizeArbiter, SizeCore, SizeOpts, SizePolicy};
use crate::thread_id;

pub(crate) const MAX_LEVEL: usize = 20;
const MARK: u64 = 1;

#[inline]
fn is_marked(w: u64) -> bool {
    w & MARK == MARK
}

#[inline]
fn addr<P: SizePolicy>(w: u64) -> *mut SkipNode<P> {
    (w & !MARK) as *mut SkipNode<P>
}

// Reclamation state word layout (see `maybe_retire`):
//   bits 0..=20   — "linked at level l" (set by the inserter's link CAS)
//   bits 21..=41  — "unlinked at level l" (set by the unlink-CAS winner)
//   bit 62        — inserter finished: no future link can be created
//   bit 63        — retire claimed (exactly-once guard)
const LINKED_SHIFT: u32 = 0;
const UNLINKED_SHIFT: u32 = 21;
const LEVELS_MASK: u64 = (1 << MAX_LEVEL as u32) - 1;
const STATE_DONE: u64 = 1 << 62;
const STATE_CLAIMED: u64 = 1 << 63;

pub(crate) struct SkipNode<P: SizePolicy> {
    key: u64,
    /// Dictionary payload; an upsert over an existing key overwrites it
    /// in place (per-key atomic, not part of the membership protocol).
    value: AtomicU64,
    /// Tower of successor words (low bit = mark); length = node level.
    next: Box<[AtomicU64]>,
    /// Per-level link/unlink accounting for safe reclamation: the node is
    /// EBR-retired only once (a) the inserter can create no further links
    /// and (b) every level that was ever linked has been unlinked — i.e.,
    /// the node is provably unreachable. (A plain "retire at bottom-level
    /// unlink" is unsound: an in-flight inserter may link an upper level
    /// after the bottom unlink, and with equal-key nodes in transition a
    /// single cleanup find() pass can miss the stale upper link.)
    state: AtomicU64,
    insert_info: P::InfoSlot,
    delete_info: P::InfoSlot,
}

impl<P: SizePolicy> SkipNode<P> {
    fn alloc(key: u64, value: u64, level: usize) -> *mut Self {
        Box::into_raw(Box::new(SkipNode {
            key,
            value: AtomicU64::new(value),
            next: (0..level).map(|_| AtomicU64::new(0)).collect(),
            state: AtomicU64::new(0),
            insert_info: P::InfoSlot::default(),
            delete_info: P::InfoSlot::default(),
        }))
    }

    #[inline]
    fn level(&self) -> usize {
        self.next.len()
    }
}

/// Structure-lifetime deferred reclamation for skip-list nodes.
///
/// Multi-level towers admit a subtle resurrection window between an
/// in-flight inserter's upper-level linking and concurrent unlinkers
/// (Java's original leans on the GC here; crossbeam-skiplist carries
/// per-tower reference counting for the same reason). Rather than risk a
/// use-after-free on that window, fully-unlinked towers are parked in a
/// lock-free graveyard owned by the structure and freed at `Drop`, after
/// deduplication against the level-chain walk. Memory growth is bounded by
/// the structure's total deletion count over its lifetime; `list`/`bst`
/// nodes (single incoming link) use full EBR reclamation. Recorded as a
/// substitution in DESIGN.md.
pub(crate) struct Graveyard {
    head: AtomicU64, // Treiber stack of GraveEntry
}

struct GraveEntry {
    node: u64,
    next: u64,
}

impl Graveyard {
    fn new() -> Self {
        Self {
            head: AtomicU64::new(0),
        }
    }

    fn push(&self, node: u64) {
        let entry = Box::into_raw(Box::new(GraveEntry { node, next: 0 }));
        loop {
            let head = self.head.load(SeqCst);
            unsafe { &mut *entry }.next = head;
            if self
                .head
                .compare_exchange(head, entry as u64, SeqCst, SeqCst)
                .is_ok()
            {
                return;
            }
        }
    }

    /// Drain into a list of node pointers (exclusive access).
    fn drain(&self) -> Vec<u64> {
        let mut out = Vec::new();
        let mut e = self.head.swap(0, SeqCst) as *mut GraveEntry;
        while !e.is_null() {
            let entry = unsafe { Box::from_raw(e) };
            out.push(entry.node);
            e = entry.next as *mut GraveEntry;
        }
        out
    }
}

/// Park `node` in the graveyard iff the inserter is done and
/// linked == unlinked (no live chain references remain in steady state).
/// Exactly-once via the CLAIMED bit.
unsafe fn maybe_retire<P: SizePolicy>(node: *mut SkipNode<P>, graveyard: &Graveyard) {
    let state = &unsafe { &*node }.state;
    loop {
        let s = state.load(SeqCst);
        if s & STATE_CLAIMED != 0 || s & STATE_DONE == 0 {
            return;
        }
        let linked = (s >> LINKED_SHIFT) & LEVELS_MASK;
        let unlinked = (s >> UNLINKED_SHIFT) & LEVELS_MASK;
        if linked != unlinked || linked & 1 == 0 {
            return; // still reachable (or never published)
        }
        if state
            .compare_exchange(s, s | STATE_CLAIMED, SeqCst, SeqCst)
            .is_ok()
        {
            graveyard.push(node as u64);
            return;
        }
    }
}

/// Record a successful link of `node` at `lvl` (inserter only).
unsafe fn on_link<P: SizePolicy>(node: *mut SkipNode<P>, lvl: usize, graveyard: &Graveyard) {
    unsafe { &*node }
        .state
        .fetch_or(1 << (LINKED_SHIFT + lvl as u32), SeqCst);
    unsafe { maybe_retire(node, graveyard) };
}

/// Record a successful unlink of `node` at `lvl` (unlink-CAS winner only).
unsafe fn on_unlink<P: SizePolicy>(node: *mut SkipNode<P>, lvl: usize, graveyard: &Graveyard) {
    unsafe { &*node }
        .state
        .fetch_or(1 << (UNLINKED_SHIFT + lvl as u32), SeqCst);
    unsafe { maybe_retire(node, graveyard) };
}

/// The inserter finished (or abandoned) its linking phase.
unsafe fn on_inserter_done<P: SizePolicy>(node: *mut SkipNode<P>, graveyard: &Graveyard) {
    unsafe { &*node }.state.fetch_or(STATE_DONE, SeqCst);
    unsafe { maybe_retire(node, graveyard) };
}

/// Debug forensics: any pointer stored into a level-`lvl` chain slot must
/// reference a node tall enough to participate in that level.
#[inline]
fn debug_check_chain_value<P: SizePolicy>(w: u64, lvl: usize, site: &str) {
    #[cfg(debug_assertions)]
    {
        let p = addr::<P>(w);
        if !p.is_null() {
            let h = unsafe { &*p }.level();
            assert!(
                h > lvl,
                "{site}: writing node {:#x} (h={h}) into level-{lvl} slot",
                p as usize
            );
        }
    }
    #[cfg(not(debug_assertions))]
    {
        let _ = (w, lvl, site);
    }
}

/// Logical-deletion check; mirrors `list::deletion_state`.
#[inline]
fn deletion_state<P: SizePolicy>(node: &SkipNode<P>) -> (bool, u64) {
    if P::TRACKED {
        let dinfo = P::read_delete_info(&node.delete_info);
        if dinfo != 0 {
            return (true, dinfo);
        }
        if is_marked(node.next[0].load(SeqCst)) {
            return (true, P::read_delete_info(&node.delete_info));
        }
        (false, 0)
    } else {
        (is_marked(node.next[0].load(SeqCst)), 0)
    }
}

/// Mark every level of the tower, top-down; returns the bottom pre-mark
/// word. The bottom-level mark is the physical-deletion lock; for untracked
/// policies its CAS also decides the logical winner (`bottom_won`).
struct MarkOutcome {
    /// This call performed the bottom-level mark CAS.
    bottom_won: bool,
}

fn mark_tower<P: SizePolicy>(node: &SkipNode<P>) -> MarkOutcome {
    for lvl in (1..node.level()).rev() {
        let mut w = node.next[lvl].load(SeqCst);
        while !is_marked(w) {
            match node.next[lvl].compare_exchange(w, w | MARK, SeqCst, SeqCst) {
                Ok(_) => break,
                Err(cur) => w = cur,
            }
        }
    }
    let mut w = node.next[0].load(SeqCst);
    loop {
        if is_marked(w) {
            return MarkOutcome { bottom_won: false };
        }
        match node.next[0].compare_exchange(w, w | MARK, SeqCst, SeqCst) {
            Ok(_) => return MarkOutcome { bottom_won: true },
            Err(cur) => w = cur,
        }
    }
}

thread_local! {
    static LEVEL_RNG: std::cell::RefCell<Xoshiro256> = std::cell::RefCell::new(
        Xoshiro256::new(0x5EED ^ (thread_id::current() as u64) << 32)
    );
}

/// Geometric tower height (p = 1/2), capped at [`MAX_LEVEL`].
fn random_level() -> usize {
    LEVEL_RNG.with(|r| {
        let bits = r.borrow_mut().next_u64();
        ((bits.trailing_ones() as usize) + 1).min(MAX_LEVEL)
    })
}

pub struct SkipListSet<P: SizePolicy> {
    /// Sentinel head tower (key conceptually −∞; never compared).
    head: Box<[AtomicU64; MAX_LEVEL]>,
    /// Policy + arbiter, shared with the optional refresher daemon.
    core: Arc<SizeCore<P>>,
    /// Deferred-reclamation parking lot (see [`Graveyard`]).
    graveyard: Graveyard,
    refresher: RefresherSlot,
}

unsafe impl<P: SizePolicy> Send for SkipListSet<P> {}
unsafe impl<P: SizePolicy> Sync for SkipListSet<P> {}

impl<P: SizePolicy> SkipListSet<P> {
    pub fn new(max_threads: usize) -> Self {
        Self::with_opts(max_threads, SizeOpts::default())
    }

    pub fn with_opts(max_threads: usize, opts: SizeOpts) -> Self {
        Self::with_policy(P::new(max_threads, opts))
    }

    pub fn with_policy(policy: P) -> Self {
        Self {
            head: Box::new(std::array::from_fn(|_| AtomicU64::new(0))),
            core: Arc::new(SizeCore::new(policy)),
            graveyard: Graveyard::new(),
            refresher: RefresherSlot::new(),
        }
    }

    pub fn policy(&self) -> &P {
        &self.core.policy
    }

    /// The combining size arbiter behind `size_exact` / `size_recent`.
    pub fn arbiter(&self) -> &SizeArbiter {
        &self.core.arbiter
    }

    #[inline]
    fn head_next(&self, lvl: usize) -> &AtomicU64 {
        &self.head[lvl]
    }

    #[inline]
    fn next_ref<'a>(&'a self, pred: *mut SkipNode<P>, lvl: usize) -> &'a AtomicU64 {
        if pred.is_null() {
            self.head_next(lvl)
        } else {
            unsafe { &(*pred).next[lvl] }
        }
    }

    /// Standard lock-free `find`: per-level `(pred, succ)` pairs with
    /// physical unlinking of logically-deleted nodes — each preceded by its
    /// metadata commit (Fig. 3 footnote). Returns the bottom-level match.
    ///
    /// Caller must hold an EBR pin.
    fn find(
        &self,
        k: u64,
        preds: &mut [*mut SkipNode<P>; MAX_LEVEL],
        succs: &mut [u64; MAX_LEVEL],
    ) -> Option<*mut SkipNode<P>> {
        'retry: loop {
            let mut pred: *mut SkipNode<P> = std::ptr::null_mut();
            for lvl in (0..MAX_LEVEL).rev() {
                loop {
                    let pred_next = self.next_ref(pred, lvl);
                    let curr_w = pred_next.load(SeqCst);
                    if is_marked(curr_w) {
                        continue 'retry; // pred deleted under us
                    }
                    let curr = addr::<P>(curr_w);
                    if curr.is_null() {
                        preds[lvl] = pred;
                        succs[lvl] = 0;
                        break;
                    }
                    let curr_ref = unsafe { &*curr };
                    let (deleted, dinfo) = deletion_state(curr_ref);
                    if deleted {
                        if P::TRACKED {
                            self.core.policy.commit_delete(dinfo); // before unlink
                        }
                        mark_tower(curr_ref);
                        let succ_w = curr_ref.next[lvl].load(SeqCst) & !MARK;
                        debug_check_chain_value::<P>(succ_w, lvl, "find-unlink");
                        match pred_next.compare_exchange(curr_w, succ_w, SeqCst, SeqCst) {
                            Ok(_) => {
                                unsafe { on_unlink(curr, lvl, &self.graveyard) };
                                continue;
                            }
                            Err(_) => continue 'retry,
                        }
                    }
                    if curr_ref.key >= k {
                        debug_check_chain_value::<P>(curr_w, lvl, "find-succ");
                        preds[lvl] = pred;
                        succs[lvl] = curr_w;
                        break;
                    }
                    pred = curr;
                }
            }
            let found = addr::<P>(succs[0]);
            if !found.is_null() && unsafe { &*found }.key == k {
                return Some(found);
            }
            return None;
        }
    }

    /// Copy the keys of all live bottom-level nodes, in order. This is the
    /// O(n) "snapshot copy of the base level" the Petrank–Timnat
    /// [`crate::snapshot::SnapshotSkipList`] competitor pays for on every
    /// `size()` (paper Section 9).
    pub fn collect_keys(&self) -> Vec<u64> {
        let _g = ebr::pin();
        let mut keys = Vec::new();
        let mut curr = addr::<P>(self.head_next(0).load(SeqCst));
        while !curr.is_null() {
            let c = unsafe { &*curr };
            if !deletion_state(c).0 {
                keys.push(c.key);
            }
            curr = addr::<P>(c.next[0].load(SeqCst));
        }
        keys
    }

    /// Quiescent full count at the bottom level (tests).
    pub fn quiescent_count(&self) -> usize {
        let _g = ebr::pin();
        let mut n = 0;
        let mut curr = addr::<P>(self.head_next(0).load(SeqCst));
        while !curr.is_null() {
            let c = unsafe { &*curr };
            if !deletion_state(c).0 {
                n += 1;
            }
            curr = addr::<P>(c.next[0].load(SeqCst));
        }
        n
    }

    /// Bottom-level range collect: push every live `(key, value)` with
    /// `lo <= key <= hi` onto `out`, in key order, after a wait-free
    /// upper-level descent to the range start. Helps pending inserts and
    /// commits observed deletes so any tracked update the traversal could
    /// half-see bumps a counter and invalidates the surrounding
    /// double-collect. Caller must hold an EBR pin.
    fn collect_range(&self, lo: u64, hi: u64, out: &mut Vec<(u64, u64)>) {
        let mut pred: *mut SkipNode<P> = std::ptr::null_mut();
        for lvl in (0..MAX_LEVEL).rev() {
            loop {
                let w = self.next_ref(pred, lvl).load(SeqCst);
                let curr = addr::<P>(w);
                if curr.is_null() {
                    break;
                }
                let curr_ref = unsafe { &*curr };
                if curr_ref.key < lo {
                    pred = curr;
                } else {
                    break;
                }
            }
        }
        let mut curr = addr::<P>(self.next_ref(pred, 0).load(SeqCst));
        while !curr.is_null() {
            let curr_ref = unsafe { &*curr };
            if curr_ref.key > hi {
                return;
            }
            let next = addr::<P>(curr_ref.next[0].load(SeqCst));
            if curr_ref.key >= lo {
                let (deleted, dinfo) = deletion_state(curr_ref);
                if deleted {
                    if P::TRACKED {
                        self.core.policy.commit_delete(dinfo);
                    }
                } else {
                    self.core.policy.help_insert(&curr_ref.insert_info);
                    out.push((curr_ref.key, curr_ref.value.load(SeqCst)));
                }
            }
            curr = next;
        }
    }

    /// Upsert engine shared by `insert` (`v = 0`, no overwrite) and `put`
    /// (overwrite): the original lock-free insert, with a value payload
    /// published with the node.
    fn put_with(&self, k: u64, v: u64, overwrite: bool) -> bool {
        debug_assert!(k <= MAX_KEY);
        let _guard = ebr::pin();
        let _op = self.core.policy.enter();
        let tid = thread_id::current();

        let packed = self.core.policy.begin_insert(tid);
        let mut preds = [std::ptr::null_mut(); MAX_LEVEL];
        let mut succs = [0u64; MAX_LEVEL];
        let mut new_node: *mut SkipNode<P> = std::ptr::null_mut();
        let level = random_level();

        loop {
            if let Some(found) = self.find(k, &mut preds, &mut succs) {
                // Present in an unmarked node: help, fail (Fig. 3 ll.16–18).
                self.core.policy.help_insert(unsafe { &(*found).insert_info });
                if overwrite {
                    unsafe { &*found }.value.store(v, SeqCst);
                }
                if !new_node.is_null() {
                    drop(unsafe { Box::from_raw(new_node) });
                }
                return false;
            }
            if new_node.is_null() {
                new_node = SkipNode::<P>::alloc(k, v, level);
                P::stash_insert_info(unsafe { &(*new_node).insert_info }, packed);
            }
            let new_ref = unsafe { &*new_node };
            for lvl in 0..level {
                debug_check_chain_value::<P>(succs[lvl], lvl, "insert-init");
                new_ref.next[lvl].store(succs[lvl], SeqCst);
            }
            // Bottom-level link = the original linearization point.
            let pred_next = self.next_ref(preds[0], 0);
            if pred_next
                .compare_exchange(succs[0], new_node as u64, SeqCst, SeqCst)
                .is_err()
            {
                continue; // retry with the allocated node
            }
            unsafe { on_link(new_node, 0, &self.graveyard) };
            // Reach the new linearization point before anything else
            // (Fig. 3 line 25).
            self.core.policy.commit_insert(&new_ref.insert_info, packed);

            // Link upper levels (best effort; abandoned if node is deleted).
            'link: for lvl in 1..level {
                loop {
                    let cur_succ = new_ref.next[lvl].load(SeqCst);
                    if is_marked(cur_succ) {
                        break 'link; // concurrently deleted
                    }
                    let pred_next = self.next_ref(preds[lvl], lvl);
                    if pred_next
                        .compare_exchange(succs[lvl], new_node as u64, SeqCst, SeqCst)
                        .is_ok()
                    {
                        unsafe { on_link(new_node, lvl, &self.graveyard) };
                        break;
                    }
                    // CAS failed: refresh preds/succs and re-point the new
                    // node's successor at this level before retrying.
                    match self.find(k, &mut preds, &mut succs) {
                        Some(f) if f == new_node => {}
                        _ => break 'link, // deleted (and possibly replaced)
                    }
                    if cur_succ != succs[lvl] {
                        debug_check_chain_value::<P>(succs[lvl], lvl, "insert-repoint");
                        if new_ref.next[lvl]
                            .compare_exchange(cur_succ, succs[lvl], SeqCst, SeqCst)
                            .is_err()
                            && is_marked(new_ref.next[lvl].load(SeqCst))
                        {
                            break 'link; // lost to the marker: stop linking
                        }
                    }
                }
            }
            // Reclamation (see `state`): if the node was deleted while we
            // were linking, help unlink promptly; correctness only needs the
            // link/unlink accounting plus the DONE bit below.
            if deletion_state(new_ref).0 {
                self.find(k, &mut preds, &mut succs);
            }
            unsafe { on_inserter_done(new_node, &self.graveyard) };
            return true;
        }
    }
}

impl<P: SizePolicy> ConcurrentSet for SkipListSet<P> {
    fn insert(&self, k: u64) -> bool {
        self.put_with(k, 0, false)
    }

    fn put(&self, k: u64, v: u64) -> bool {
        self.put_with(k, v, true)
    }

    fn get(&self, k: u64) -> Option<u64> {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter_read();

        // Wait-free traversal (no unlinking), as `contains`.
        let mut pred: *mut SkipNode<P> = std::ptr::null_mut();
        for lvl in (0..MAX_LEVEL).rev() {
            loop {
                let w = self.next_ref(pred, lvl).load(SeqCst);
                let curr = addr::<P>(w);
                if curr.is_null() {
                    break;
                }
                let curr_ref = unsafe { &*curr };
                if curr_ref.key < k {
                    pred = curr;
                } else {
                    break;
                }
            }
        }
        let mut curr = addr::<P>(self.next_ref(pred, 0).load(SeqCst));
        while !curr.is_null() {
            let curr_ref = unsafe { &*curr };
            if curr_ref.key >= k {
                break;
            }
            curr = addr::<P>(curr_ref.next[0].load(SeqCst));
        }
        if curr.is_null() {
            return None;
        }
        let node = unsafe { &*curr };
        if node.key != k {
            return None;
        }
        let (deleted, dinfo) = deletion_state(node);
        if deleted {
            if P::TRACKED {
                self.core.policy.commit_delete(dinfo);
            }
            return None;
        }
        self.core.policy.help_insert(&node.insert_info);
        Some(node.value.load(SeqCst))
    }

    fn scan(&self, lo: u64, hi: u64) -> Option<Vec<(u64, u64)>> {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter_read();
        let (pairs, _validated) =
            crate::size::validated_collect(self.core.policy.calculator(), || {
                let mut out = Vec::new();
                self.collect_range(lo, hi, &mut out);
                out
            });
        Some(pairs)
    }

    fn delete(&self, k: u64) -> bool {
        let _guard = ebr::pin();
        let _op = self.core.policy.enter();
        let tid = thread_id::current();

        let mut preds = [std::ptr::null_mut(); MAX_LEVEL];
        let mut succs = [0u64; MAX_LEVEL];

        loop {
            let found = match self.find(k, &mut preds, &mut succs) {
                None => return false, // Fig. 3 line 29
                Some(f) => f,
            };
            let node = unsafe { &*found };

            if P::TRACKED {
                self.core.policy.help_insert(&node.insert_info); // line 33
                let packed = self.core.policy.begin_delete(tid); // line 34
                // Line 35: the marking step = installing delete-info.
                let winner = P::try_claim_delete(&node.delete_info, packed);
                self.core.policy.commit_delete(winner); // line 36: before unlink
                mark_tower(node);
                // Physical unlink via find (also retires the node).
                self.find(k, &mut preds, &mut succs);
                return winner == packed;
            } else {
                let outcome = mark_tower(node);
                if outcome.bottom_won {
                    self.core.policy.commit_delete(0); // naive/lock counter bump
                    self.find(k, &mut preds, &mut succs); // physical unlink
                    return true;
                }
                return false; // concurrent delete won
            }
        }
    }

    fn contains(&self, k: u64) -> bool {
        // The wait-free helping traversal lives in `get` (Fig. 3 ll.6–13).
        self.get(k).is_some()
    }

    crate::size::impl_size_surface!();

    fn name(&self) -> String {
        format!(
            "SkipList<{}>",
            std::any::type_name::<P>().rsplit("::").next().unwrap()
        )
    }
}

impl<P: SizePolicy> Drop for SkipListSet<P> {
    fn drop(&mut self) {
        // Free every node exactly once: the union of (a) nodes reachable
        // from any level chain (live nodes + deleted-but-uncleaned towers)
        // and (b) the graveyard of fully-unlinked towers. Deduplicated so
        // a parked tower that is somehow still chained is freed once.
        let mut seen = std::collections::HashSet::new();
        for lvl in 0..MAX_LEVEL {
            let mut curr = addr::<P>(self.head_next(lvl).load(SeqCst));
            while !curr.is_null() {
                if !seen.insert(curr as usize) {
                    // already collected via another level
                }
                let c = unsafe { &*curr };
                if lvl >= c.level() {
                    break; // corrupted chain would stop here (defensive)
                }
                curr = addr::<P>(c.next[lvl].load(SeqCst));
            }
        }
        for node in self.graveyard.drain() {
            seen.insert(node as usize);
        }
        for &p in &seen {
            drop(unsafe { Box::from_raw(p as *mut SkipNode<P>) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::size::{LinearizableSize, NoSize};
    use std::sync::Arc;

    fn sl() -> SkipListSet<LinearizableSize> {
        SkipListSet::new(crate::MAX_THREADS)
    }

    #[test]
    fn basic_ops() {
        let s = sl();
        assert!(!s.contains(3));
        assert!(s.insert(3));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(s.delete(3));
        assert!(!s.delete(3));
        assert!(!s.contains(3));
        assert_eq!(s.size(), Some(0));
    }

    #[test]
    fn many_sequential_keys() {
        let s = sl();
        for k in 0..2000u64 {
            assert!(s.insert(k));
        }
        assert_eq!(s.size(), Some(2000));
        for k in (0..2000u64).step_by(2) {
            assert!(s.delete(k));
        }
        assert_eq!(s.size(), Some(1000));
        for k in 0..2000u64 {
            assert_eq!(s.contains(k), k % 2 == 1, "key {k}");
        }
        assert_eq!(s.quiescent_count(), 1000);
    }

    #[test]
    fn dictionary_scan_is_ordered_and_bounded() {
        let s = sl();
        let mut rng = crate::rng::Xoshiro256::new(23);
        let mut model = std::collections::BTreeMap::new();
        for _ in 0..400 {
            let k = rng.gen_range(1_000);
            let v = rng.next_u64() >> 1;
            assert_eq!(s.put(k, v), model.insert(k, v).is_none());
        }
        assert_eq!(s.get(999_999), None);
        for (&k, &v) in model.iter().take(10) {
            assert_eq!(s.get(k), Some(v));
        }
        let want: Vec<_> = model
            .range(100..=700)
            .map(|(&k, &v)| (k, v))
            .collect();
        assert_eq!(s.scan(100, 700), Some(want));
        assert_eq!(
            s.count_range(100, 700),
            Some(model.range(100..=700).count() as i64)
        );
        assert_eq!(s.scan(701, 100), Some(vec![]), "inverted range is empty");
    }

    #[test]
    fn random_order_inserts_are_sorted() {
        let s = sl();
        let mut rng = crate::rng::Xoshiro256::new(11);
        let mut keys = std::collections::BTreeSet::new();
        for _ in 0..500 {
            let k = rng.gen_range(10_000);
            assert_eq!(s.insert(k), keys.insert(k));
        }
        assert_eq!(s.size(), Some(keys.len() as i64));
        for k in keys {
            assert!(s.contains(k));
        }
    }

    #[test]
    fn baseline_skiplist_without_size() {
        let s: SkipListSet<NoSize> = SkipListSet::new(crate::MAX_THREADS);
        assert!(s.insert(1));
        assert!(s.contains(1));
        assert_eq!(s.size(), None);
        assert!(s.delete(1));
        assert_eq!(s.quiescent_count(), 0);
    }

    #[test]
    fn concurrent_inserts_disjoint() {
        let s = Arc::new(sl());
        let hs: Vec<_> = (0..4u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for k in (t * 10_000)..(t * 10_000 + 500) {
                        assert!(s.insert(k));
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(s.size(), Some(2000));
        assert_eq!(s.quiescent_count(), 2000);
    }

    #[test]
    fn concurrent_same_key_races() {
        for round in 0..30 {
            let s = Arc::new(sl());
            let ins: Vec<_> = (0..3)
                .map(|_| {
                    let s = s.clone();
                    std::thread::spawn(move || s.insert(9) as usize)
                })
                .collect();
            let wins: usize = ins.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(wins, 1, "round {round}: one insert must win");
            let dels: Vec<_> = (0..3)
                .map(|_| {
                    let s = s.clone();
                    std::thread::spawn(move || s.delete(9) as usize)
                })
                .collect();
            let wins: usize = dels.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(wins, 1, "round {round}: one delete must win");
            assert_eq!(s.size(), Some(0));
        }
    }

    #[test]
    fn churn_size_bounds_and_quiescent_match() {
        let s = Arc::new(sl());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..4u64)
            .map(|t| {
                let s = s.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(t + 5);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(128);
                        if rng.gen_bool(0.5) {
                            s.insert(k);
                        } else {
                            s.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..800 {
            let sz = s.size().unwrap();
            assert!((0..=128).contains(&sz), "size {sz} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(s.size().unwrap() as usize, s.quiescent_count());
    }

    #[test]
    fn reinsert_after_delete_many_rounds() {
        let s = sl();
        for _ in 0..200 {
            assert!(s.insert(77));
            assert!(s.contains(77));
            assert!(s.delete(77));
            assert!(!s.contains(77));
        }
        assert_eq!(s.size(), Some(0));
    }
}
