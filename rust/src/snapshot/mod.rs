//! Snapshot-based size competitor #1: Petrank–Timnat snap-collector
//! (`SnapshotSkipList` in the paper's evaluation, Section 9).
//!
//! `size()` here is implemented the way the paper's competitor does it:
//! announce a snap collector, produce a **full copy of the skip list's base
//! level** (O(n) traversal + allocation), merge the reports of concurrent
//! updaters, and count — the cost the size methodology is designed to avoid.
//!
//! Faithfulness note (recorded in DESIGN.md): we implement the protocol's
//! *structure* — active-collector announcement, per-thread update reports,
//! traversal collection, merge — with a simplified merge rule (traversed ∪
//! insert-reports − delete-reports). The paper's full report semantics add
//! constant-factor bookkeeping on the same O(n) spine, so the performance
//! *shape* (Figures 10–12) is preserved; exactness holds at quiescence and
//! under single-writer interleavings, which the tests check.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicPtr, Ordering::SeqCst};
use std::sync::Mutex;

use crate::ebr;
use crate::set_api::ConcurrentSet;
use crate::size::NoSize;
use crate::skiplist::SkipListSet;
use crate::thread_id;
use crate::MAX_THREADS;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ReportKind {
    Insert,
    Delete,
}

/// One active snapshot collection: updaters report concurrent operations so
/// the scanner does not miss them.
struct SnapCollector {
    active: AtomicBool,
    reports: Box<[Mutex<Vec<(ReportKind, u64)>>]>,
}

impl SnapCollector {
    fn new() -> Self {
        Self {
            active: AtomicBool::new(true),
            reports: (0..MAX_THREADS).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    fn report(&self, tid: usize, kind: ReportKind, key: u64) {
        if self.active.load(SeqCst) {
            self.reports[tid].lock().unwrap().push((kind, key));
        }
    }

    fn deactivate(&self) {
        self.active.store(false, SeqCst);
    }
}

/// Skip list with a Petrank–Timnat-style snapshot; `size()` = snapshot and
/// count (the paper's `SnapshotSkipList` baseline).
pub struct SnapshotSkipList {
    inner: SkipListSet<NoSize>,
    collector: AtomicPtr<SnapCollector>,
}

unsafe impl Send for SnapshotSkipList {}
unsafe impl Sync for SnapshotSkipList {}

impl SnapshotSkipList {
    pub fn new(max_threads: usize) -> Self {
        Self {
            inner: SkipListSet::new(max_threads),
            collector: AtomicPtr::new(std::ptr::null_mut()),
        }
    }

    fn report(&self, kind: ReportKind, key: u64) {
        let _g = ebr::pin();
        let c = self.collector.load(SeqCst);
        if !c.is_null() {
            unsafe { &*c }.report(thread_id::current(), kind, key);
        }
    }

    /// Take a full snapshot of the set's keys (the expensive path).
    pub fn snapshot(&self) -> Vec<u64> {
        let _g = ebr::pin();
        // Announce a collector (single scanner at a time: competing scanners
        // share the announced one, as in the original).
        let fresh = Box::into_raw(Box::new(SnapCollector::new()));
        let collector = match self
            .collector
            .compare_exchange(std::ptr::null_mut(), fresh, SeqCst, SeqCst)
        {
            Ok(_) => fresh,
            Err(active) => {
                drop(unsafe { Box::from_raw(fresh) });
                active
            }
        };
        let col = unsafe { &*collector };

        // O(n): copy the base level.
        let traversed = self.inner.collect_keys();

        col.deactivate();
        // Merge reports into the traversal.
        let mut live: HashSet<u64> = traversed.into_iter().collect();
        for slot in col.reports.iter() {
            for &(kind, key) in slot.lock().unwrap().iter() {
                match kind {
                    ReportKind::Insert => {
                        live.insert(key);
                    }
                    ReportKind::Delete => {
                        live.remove(&key);
                    }
                }
            }
        }

        // Retire the collector if we are the scanner that owns it.
        if self
            .collector
            .compare_exchange(collector, std::ptr::null_mut(), SeqCst, SeqCst)
            .is_ok()
        {
            unsafe { ebr::retire(collector) };
        }

        let mut keys: Vec<u64> = live.into_iter().collect();
        keys.sort_unstable();
        keys
    }
}

impl ConcurrentSet for SnapshotSkipList {
    fn insert(&self, k: u64) -> bool {
        let ok = self.inner.insert(k);
        if ok {
            self.report(ReportKind::Insert, k);
        }
        ok
    }

    fn delete(&self, k: u64) -> bool {
        let ok = self.inner.delete(k);
        if ok {
            self.report(ReportKind::Delete, k);
        }
        ok
    }

    fn contains(&self, k: u64) -> bool {
        self.inner.contains(k)
    }

    /// Snapshot-then-count: O(n) per call.
    fn size(&self) -> Option<i64> {
        Some(self.snapshot().len() as i64)
    }

    fn name(&self) -> String {
        "SnapshotSkipList".into()
    }
}

impl Drop for SnapshotSkipList {
    fn drop(&mut self) {
        let c = *self.collector.get_mut();
        if !c.is_null() {
            drop(unsafe { Box::from_raw(c) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn quiescent_size_is_exact() {
        let s = SnapshotSkipList::new(MAX_THREADS);
        for k in 0..500 {
            assert!(s.insert(k));
        }
        for k in 0..100 {
            assert!(s.delete(k * 5));
        }
        assert_eq!(s.size(), Some(400));
        assert_eq!(s.snapshot().len(), 400);
    }

    #[test]
    fn snapshot_is_sorted_keys() {
        let s = SnapshotSkipList::new(MAX_THREADS);
        for k in [9u64, 1, 5, 3] {
            s.insert(k);
        }
        assert_eq!(s.snapshot(), vec![1, 3, 5, 9]);
    }

    #[test]
    fn membership_ops_delegate() {
        let s = SnapshotSkipList::new(MAX_THREADS);
        assert!(s.insert(2));
        assert!(!s.insert(2));
        assert!(s.contains(2));
        assert!(s.delete(2));
        assert!(!s.contains(2));
    }

    #[test]
    fn size_bounded_under_churn() {
        let s = Arc::new(SnapshotSkipList::new(MAX_THREADS));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..3u64)
            .map(|t| {
                let s = s.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(t);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(64);
                        if rng.gen_bool(0.5) {
                            s.insert(k);
                        } else {
                            s.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..50 {
            let sz = s.size().unwrap();
            assert!((0..=64).contains(&sz), "size {sz} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        assert_eq!(s.size().unwrap() as usize, s.snapshot().len());
    }
}
