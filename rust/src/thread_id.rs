//! Thread slot registry: the paper's `threadID`.
//!
//! The size metadata is an array with one (insertion, deletion) counter pair
//! per thread (paper Section 5), indexed by a dense thread id in
//! `0..MAX_THREADS`. Threads acquire a slot lazily on first data-structure
//! operation and release it when they exit, so ids are recycled — exactly
//! like a thread-local `threadID` variable in the Java original, but safe
//! for short-lived threads.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::MAX_THREADS;

static SLOTS: [AtomicBool; MAX_THREADS] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const FREE: AtomicBool = AtomicBool::new(false);
    [FREE; MAX_THREADS]
};

/// RAII slot ownership; stored in a thread-local so `current()` is a cached
/// load after the first call on each thread.
struct SlotOwner {
    tid: usize,
}

impl Drop for SlotOwner {
    fn drop(&mut self) {
        crate::ebr::on_thread_exit(self.tid);
        SLOTS[self.tid].store(false, Ordering::Release);
    }
}

thread_local! {
    static OWNER: SlotOwner = SlotOwner { tid: acquire_slot() };
}

fn acquire_slot() -> usize {
    for (tid, slot) in SLOTS.iter().enumerate() {
        if slot
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            return tid;
        }
    }
    panic!("thread_id: more than MAX_THREADS={MAX_THREADS} live threads");
}

/// Dense id of the calling thread (registers it on first use).
#[inline]
pub fn current() -> usize {
    OWNER.with(|o| o.tid)
}

/// Number of slots the registry can hand out.
#[inline]
pub const fn capacity() -> usize {
    MAX_THREADS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn current_is_stable_within_a_thread() {
        assert_eq!(current(), current());
    }

    #[test]
    fn ids_are_in_range() {
        assert!(current() < MAX_THREADS);
    }

    #[test]
    fn distinct_live_threads_get_distinct_ids() {
        let mine = current();
        let theirs = std::thread::spawn(current).join().unwrap();
        assert_ne!(mine, theirs);
    }

    #[test]
    fn slots_are_recycled_after_thread_exit() {
        let a = std::thread::spawn(current).join().unwrap();
        // The previous thread has fully exited after join; its slot is free
        // again, so a new thread can grab some slot (possibly the same one).
        let b = std::thread::spawn(current).join().unwrap();
        assert!(a < MAX_THREADS && b < MAX_THREADS);
    }

    #[test]
    fn many_sequential_threads_do_not_exhaust_slots() {
        for _ in 0..(MAX_THREADS * 4) {
            std::thread::spawn(current).join().unwrap();
        }
    }
}
