//! Snapshot-based size competitor #2: a versioned-CAS structure in the
//! style of `VcasBST-64` (Wei, Ben-David, Blelloch, Fatourou, Ruppert, Sun,
//! PPoPP 2021), as used in the paper's evaluation.
//!
//! The competitor's essential cost model (what Figures 10–12 compare
//! against) is:
//!
//! * point operations pay O(1) extra to maintain **per-leaf version lists**
//!   of `(timestamp, element-count)` records;
//! * `size()` advances a global timestamp and then traverses **every
//!   batched leaf** (64 keys per leaf), reading each leaf's element count
//!   at that timestamp — O(n / 64) work that grows with the data-structure
//!   size, but much cheaper than a full element copy.
//!
//! Faithfulness note (recorded in DESIGN.md): the original is a balanced
//! external BST with batched leaves; we model the identical cost profile
//! with a hashed array of 64-key chunks (each chunk = one "batched leaf":
//! a lock-free list + a version list). Point-op and size() asymptotics —
//! and hence the benchmark shape — match; rebalancing is irrelevant to the
//! size-throughput comparison.

use std::sync::atomic::{AtomicPtr, AtomicU64, Ordering::SeqCst};

use crate::ebr;
use crate::list;
use crate::set_api::ConcurrentSet;
use crate::size::{NoSize, SizeOpts, SizePolicy};

/// Keys per batched leaf (the "-64" in VcasBST-64).
pub const LEAF_BATCH: usize = 64;

/// One version record: the chunk contained `count` elements from timestamp
/// `ts` onward (until the next record).
struct VersionNode {
    ts: u64,
    count: i64,
    prev: *mut VersionNode,
}

/// A batched leaf: a small lock-free list plus its version history.
struct Chunk {
    head: AtomicU64,
    versions: AtomicPtr<VersionNode>,
}

impl Chunk {
    fn new() -> Self {
        let genesis = Box::into_raw(Box::new(VersionNode {
            ts: 0,
            count: 0,
            prev: std::ptr::null_mut(),
        }));
        Self {
            head: AtomicU64::new(0),
            versions: AtomicPtr::new(genesis),
        }
    }

    /// Append a version with `delta` applied, stamped with the current
    /// global timestamp (vCAS-style: writes between two size() timestamps
    /// all carry a stamp greater than the earlier one).
    fn push_version(&self, global_ts: &AtomicU64, delta: i64) {
        loop {
            let headp = self.versions.load(SeqCst);
            let head = unsafe { &*headp };
            let node = Box::into_raw(Box::new(VersionNode {
                ts: global_ts.load(SeqCst),
                count: head.count + delta,
                prev: headp,
            }));
            if self
                .versions
                .compare_exchange(headp, node, SeqCst, SeqCst)
                .is_ok()
            {
                return;
            }
            drop(unsafe { Box::from_raw(node) });
        }
    }

    /// Element count at timestamp `ts` (latest version with `v.ts <= ts`).
    fn count_at(&self, ts: u64) -> i64 {
        let _g = ebr::pin();
        let mut v = self.versions.load(SeqCst);
        loop {
            let node = unsafe { &*v };
            if node.ts <= ts || node.prev.is_null() {
                return node.count;
            }
            v = node.prev;
        }
    }
}

/// The versioned chunked set: `VcasBST-64`'s cost model.
pub struct VcasSet {
    chunks: Box<[Chunk]>,
    mask: u64,
    global_ts: AtomicU64,
    policy: NoSize,
}

unsafe impl Send for VcasSet {}
unsafe impl Sync for VcasSet {}

impl VcasSet {
    /// `expected_elements` sizes the leaf array at ~[`LEAF_BATCH`] keys per
    /// leaf, like the original's batched leaves.
    pub fn new(max_threads: usize, expected_elements: usize) -> Self {
        let n_chunks = (expected_elements / LEAF_BATCH).max(1).next_power_of_two();
        Self {
            chunks: (0..n_chunks).map(|_| Chunk::new()).collect(),
            mask: n_chunks as u64 - 1,
            global_ts: AtomicU64::new(1),
            policy: NoSize::new(max_threads, SizeOpts::default()),
        }
    }

    #[inline]
    fn chunk(&self, k: u64) -> &Chunk {
        let h = k.wrapping_mul(0x9E3779B97F4A7C15) >> 17;
        &self.chunks[(h & self.mask) as usize]
    }

    /// Number of batched leaves (the size() traversal length).
    pub fn leaves(&self) -> usize {
        self.chunks.len()
    }

    /// The timestamped size: advance the global timestamp, then read every
    /// leaf's element count at that timestamp.
    pub fn size_at_timestamp(&self) -> i64 {
        // Advance the timestamp: updates at/before `ts` are included.
        let ts = self.global_ts.fetch_add(1, SeqCst);
        self.chunks.iter().map(|c| c.count_at(ts)).sum()
    }
}

impl ConcurrentSet for VcasSet {
    fn insert(&self, k: u64) -> bool {
        let c = self.chunk(k);
        let ok = list::insert_at(&self.policy, &c.head, k);
        if ok {
            c.push_version(&self.global_ts, 1);
        }
        ok
    }

    fn delete(&self, k: u64) -> bool {
        let c = self.chunk(k);
        let ok = list::delete_at(&self.policy, &c.head, k);
        if ok {
            c.push_version(&self.global_ts, -1);
        }
        ok
    }

    fn contains(&self, k: u64) -> bool {
        list::contains_at(&self.policy, &self.chunk(k).head, k)
    }

    fn size(&self) -> Option<i64> {
        Some(self.size_at_timestamp())
    }

    fn name(&self) -> String {
        format!("VcasSet-{LEAF_BATCH}")
    }
}

impl Drop for VcasSet {
    fn drop(&mut self) {
        for c in self.chunks.iter() {
            unsafe { list::drop_chain::<NoSize>(&c.head) };
            let mut v = c.versions.load(SeqCst);
            while !v.is_null() {
                let prev = unsafe { &*v }.prev;
                drop(unsafe { Box::from_raw(v) });
                v = prev;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn quiescent_size_is_exact() {
        let s = VcasSet::new(crate::MAX_THREADS, 1024);
        for k in 0..800 {
            assert!(s.insert(k));
        }
        for k in 0..200 {
            assert!(s.delete(k * 4));
        }
        assert_eq!(s.size(), Some(600));
    }

    #[test]
    fn membership_ops() {
        let s = VcasSet::new(crate::MAX_THREADS, 64);
        assert!(s.insert(5));
        assert!(!s.insert(5));
        assert!(s.contains(5));
        assert!(s.delete(5));
        assert!(!s.contains(5));
        assert_eq!(s.size(), Some(0));
    }

    #[test]
    fn leaf_count_scales_with_capacity() {
        let small = VcasSet::new(4, 1_000);
        let large = VcasSet::new(4, 100_000);
        assert!(large.leaves() > small.leaves() * 50);
    }

    #[test]
    fn version_history_answers_old_timestamps() {
        let s = VcasSet::new(4, 64);
        s.insert(1);
        // size() consumes timestamp 1 and advances the clock, so later
        // writes are stamped > 1.
        assert_eq!(s.size(), Some(1));
        s.insert(2);
        s.insert(3);
        // Count at the consumed timestamp must not include later inserts.
        let old: i64 = s.chunks.iter().map(|c| c.count_at(1)).sum();
        assert_eq!(old, 1);
        assert_eq!(s.size(), Some(3));
    }

    #[test]
    fn size_bounded_under_churn() {
        let s = Arc::new(VcasSet::new(crate::MAX_THREADS, 256));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let churners: Vec<_> = (0..3u64)
            .map(|t| {
                let s = s.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = crate::rng::Xoshiro256::new(t + 7);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range(100);
                        if rng.gen_bool(0.5) {
                            s.insert(k);
                        } else {
                            s.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..300 {
            let sz = s.size().unwrap();
            assert!((0..=100).contains(&sz), "size {sz} out of bounds");
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        let census: usize = s
            .chunks
            .iter()
            .map(|c| list::quiescent_count_at::<NoSize>(&c.head))
            .sum();
        assert_eq!(s.size().unwrap(), census as i64);
    }
}
