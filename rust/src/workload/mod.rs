//! YCSB-style workload generation (paper Section 9, *Methodology*).
//!
//! Two mixes, exactly the paper's:
//! * **update-heavy** — 30% insert / 20% delete / 50% contains;
//! * **read-heavy**   —  3% insert /  2% delete / 95% contains.
//!
//! Keys are drawn uniformly from `[1, r]` with `r = n·(i+d)/i`, the choice
//! that keeps the structure's size stable around its initial fill `n`.

use crate::rng::Xoshiro256;
use crate::set_api::ConcurrentSet;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpType {
    Insert = 0,
    Delete = 1,
    Contains = 2,
}

/// An operation mix (percentages; contains = remainder).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mix {
    pub insert_pct: u32,
    pub delete_pct: u32,
}

impl Mix {
    pub const fn contains_pct(&self) -> u32 {
        100 - self.insert_pct - self.delete_pct
    }

    pub fn label(&self) -> &'static str {
        if *self == UPDATE_HEAVY {
            "update-heavy"
        } else if *self == READ_HEAVY {
            "read-heavy"
        } else {
            "custom"
        }
    }
}

/// Paper: 30% insert, 20% delete, 50% contains.
pub const UPDATE_HEAVY: Mix = Mix {
    insert_pct: 30,
    delete_pct: 20,
};

/// Paper: 3% insert, 2% delete, 95% contains.
pub const READ_HEAVY: Mix = Mix {
    insert_pct: 3,
    delete_pct: 2,
};

/// `r = n·(i+d)/i` (paper Section 9) — the key range that keeps the
/// structure around `n` live elements under `mix`.
pub fn key_range(initial_size: u64, mix: Mix) -> u64 {
    let i = mix.insert_pct as u64;
    let d = mix.delete_pct as u64;
    (initial_size * (i + d) / i).max(1)
}

/// Per-thread deterministic stream of operations.
pub struct OpStream {
    rng: Xoshiro256,
    mix: Mix,
    key_range: u64,
}

impl OpStream {
    pub fn new(seed: u64, mix: Mix, key_range: u64) -> Self {
        Self {
            rng: Xoshiro256::new(seed),
            mix,
            key_range,
        }
    }

    /// Next `(op, key)`; key uniform in `[1, key_range]`.
    #[inline]
    pub fn next(&mut self) -> (OpType, u64) {
        let p = self.rng.gen_range(100) as u32;
        let op = if p < self.mix.insert_pct {
            OpType::Insert
        } else if p < self.mix.insert_pct + self.mix.delete_pct {
            OpType::Delete
        } else {
            OpType::Contains
        };
        (op, self.rng.gen_range_incl(1, self.key_range))
    }

    /// Next key only (for fixed-type phases, Fig. 13 mode).
    #[inline]
    pub fn next_key(&mut self) -> u64 {
        self.rng.gen_range_incl(1, self.key_range)
    }
}

/// Fill `set` with exactly `n` distinct uniform keys from `[1, key_range]`
/// (paper: "we fill the data structure with ... items" before each run).
pub fn prefill(set: &dyn ConcurrentSet, n: u64, key_range: u64, seed: u64) {
    assert!(
        key_range >= n,
        "prefill: cannot place {n} distinct keys in [1, {key_range}]"
    );
    let mut rng = Xoshiro256::new(seed);
    let mut inserted = 0;
    while inserted < n {
        if set.insert(rng.gen_range_incl(1, key_range)) {
            inserted += 1;
        }
    }
}

/// Apply one op to `set`; returns whether it was "successful" in the
/// set-semantics sense.
#[inline]
pub fn apply(set: &dyn ConcurrentSet, op: OpType, key: u64) -> bool {
    match op {
        OpType::Insert => set.insert(key),
        OpType::Delete => set.delete(key),
        OpType::Contains => set.contains(key),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashtable::HashTableSet;
    use crate::size::LinearizableSize;

    #[test]
    fn key_range_matches_paper_formula() {
        // Paper's example: n = 1M, 30% ins / 20% del => r ≈ 1.67M.
        assert_eq!(key_range(1_000_000, UPDATE_HEAVY), 1_666_666);
        assert_eq!(key_range(1_000_000, READ_HEAVY), 1_666_666);
    }

    #[test]
    fn mixes_sum_to_100() {
        assert_eq!(UPDATE_HEAVY.contains_pct(), 50);
        assert_eq!(READ_HEAVY.contains_pct(), 95);
    }

    #[test]
    fn op_stream_respects_mix() {
        let mut s = OpStream::new(1, UPDATE_HEAVY, 1000);
        let mut counts = [0u32; 3];
        for _ in 0..100_000 {
            let (op, k) = s.next();
            counts[op as usize] += 1;
            assert!((1..=1000).contains(&k));
        }
        let ins = counts[0] as f64 / 1000.0;
        let del = counts[1] as f64 / 1000.0;
        assert!((28.0..32.0).contains(&ins), "insert% {ins}");
        assert!((18.0..22.0).contains(&del), "delete% {del}");
    }

    #[test]
    fn prefill_reaches_exact_size() {
        let t: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 2048);
        prefill(&t, 1500, key_range(1500, UPDATE_HEAVY), 7);
        assert_eq!(t.size(), Some(1500));
    }

    #[test]
    fn streams_are_deterministic() {
        let mut a = OpStream::new(5, READ_HEAVY, 100);
        let mut b = OpStream::new(5, READ_HEAVY, 100);
        for _ in 0..1000 {
            assert_eq!(a.next(), b.next());
        }
    }
}
