//! YCSB-style workload generation (paper Section 9, *Methodology*).
//!
//! Two mixes, exactly the paper's:
//! * **update-heavy** — 30% insert / 20% delete / 50% contains;
//! * **read-heavy**   —  3% insert /  2% delete / 95% contains.
//!
//! Keys are drawn from `[1, r]` with `r = n·(i+d)/i`, the choice that
//! keeps the structure's size stable around its initial fill `n` —
//! uniformly by default, or zipfian ([`KeyDist::Zipf`], YCSB's skewed
//! "hot keys" access pattern) for the sharded-store hot-shard scenarios.

use crate::rng::Xoshiro256;
use crate::set_api::ConcurrentSet;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpType {
    Insert = 0,
    Delete = 1,
    Contains = 2,
}

/// An operation mix (percentages; contains = remainder).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mix {
    pub insert_pct: u32,
    pub delete_pct: u32,
}

impl Mix {
    pub const fn contains_pct(&self) -> u32 {
        100 - self.insert_pct - self.delete_pct
    }

    pub fn label(&self) -> &'static str {
        if *self == UPDATE_HEAVY {
            "update-heavy"
        } else if *self == READ_HEAVY {
            "read-heavy"
        } else {
            "custom"
        }
    }
}

/// Paper: 30% insert, 20% delete, 50% contains.
pub const UPDATE_HEAVY: Mix = Mix {
    insert_pct: 30,
    delete_pct: 20,
};

/// Paper: 3% insert, 2% delete, 95% contains.
pub const READ_HEAVY: Mix = Mix {
    insert_pct: 3,
    delete_pct: 2,
};

/// `r = n·(i+d)/i` (paper Section 9) — the key range that keeps the
/// structure around `n` live elements under `mix`.
pub fn key_range(initial_size: u64, mix: Mix) -> u64 {
    let i = mix.insert_pct as u64;
    let d = mix.delete_pct as u64;
    (initial_size * (i + d) / i).max(1)
}

/// How keys are drawn from `[1, key_range]`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KeyDist {
    /// Every key equally likely (the paper's methodology).
    Uniform,
    /// Zipf-skewed ranks with exponent `theta` in `(0, 1)` (YCSB's
    /// `ZipfianGenerator`; `0.99` is its default "hot keys" skew). Rank 0
    /// is the hottest key; rank maps to key `rank + 1`.
    Zipf(f64),
}

impl KeyDist {
    /// Parse the CLI surface form: `uniform` or `zipf:<theta>` with
    /// `theta` in `(0, 1)` exclusive (the YCSB approximation's domain).
    pub fn parse(s: &str) -> Option<KeyDist> {
        if s == "uniform" {
            return Some(KeyDist::Uniform);
        }
        let theta = s.strip_prefix("zipf:")?.parse::<f64>().ok()?;
        (theta > 0.0 && theta < 1.0).then_some(KeyDist::Zipf(theta))
    }

    /// The surface form back (`uniform` / `zipf:0.99`) for bench records.
    pub fn label(&self) -> String {
        match self {
            KeyDist::Uniform => "uniform".to_string(),
            KeyDist::Zipf(theta) => format!("zipf:{theta}"),
        }
    }
}

/// Zipfian rank sampler over `[0, n)` — the YCSB `ZipfianGenerator`
/// approximation (Gray et al., "Quickly generating billion-record
/// synthetic databases"): one O(n) harmonic precomputation, then O(1)
/// deterministic draws from the caller's RNG.
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
}

impl ZipfSampler {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "zipf over an empty range");
        assert!(
            theta > 0.0 && theta < 1.0,
            "zipf theta must be in (0, 1), got {theta}"
        );
        let zeta = |count: u64| (1..=count).map(|i| 1.0 / (i as f64).powf(theta)).sum::<f64>();
        let zetan = zeta(n);
        let zeta2 = zeta(2.min(n));
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Self {
            n,
            theta,
            alpha: 1.0 / (1.0 - theta),
            zetan,
            eta,
        }
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most probable.
    #[inline]
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        let u = rng.gen_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1.min(self.n - 1);
        }
        let rank = (self.n as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64;
        rank.min(self.n - 1)
    }
}

/// Per-thread deterministic stream of operations.
pub struct OpStream {
    rng: Xoshiro256,
    mix: Mix,
    key_range: u64,
    zipf: Option<ZipfSampler>,
}

impl OpStream {
    /// Uniform keys (the paper's default).
    pub fn new(seed: u64, mix: Mix, key_range: u64) -> Self {
        Self::with_dist(seed, mix, key_range, KeyDist::Uniform)
    }

    /// Explicit key distribution (`--key-dist uniform|zipf:<theta>`).
    pub fn with_dist(seed: u64, mix: Mix, key_range: u64, dist: KeyDist) -> Self {
        let zipf = match dist {
            KeyDist::Uniform => None,
            KeyDist::Zipf(theta) => Some(ZipfSampler::new(key_range, theta)),
        };
        Self {
            rng: Xoshiro256::new(seed),
            mix,
            key_range,
            zipf,
        }
    }

    /// Next `(op, key)`; key in `[1, key_range]` per the distribution.
    #[inline]
    pub fn next(&mut self) -> (OpType, u64) {
        let p = self.rng.gen_range(100) as u32;
        let op = if p < self.mix.insert_pct {
            OpType::Insert
        } else if p < self.mix.insert_pct + self.mix.delete_pct {
            OpType::Delete
        } else {
            OpType::Contains
        };
        (op, self.next_key())
    }

    /// Next key only (for fixed-type phases, Fig. 13 mode).
    #[inline]
    pub fn next_key(&mut self) -> u64 {
        match &self.zipf {
            None => self.rng.gen_range_incl(1, self.key_range),
            Some(zipf) => zipf.sample(&mut self.rng) + 1,
        }
    }
}

/// Fill `set` with exactly `n` distinct uniform keys from `[1, key_range]`
/// (paper: "we fill the data structure with ... items" before each run).
pub fn prefill(set: &dyn ConcurrentSet, n: u64, key_range: u64, seed: u64) {
    assert!(
        key_range >= n,
        "prefill: cannot place {n} distinct keys in [1, {key_range}]"
    );
    let mut rng = Xoshiro256::new(seed);
    let mut inserted = 0;
    while inserted < n {
        if set.insert(rng.gen_range_incl(1, key_range)) {
            inserted += 1;
        }
    }
}

/// Apply one op to `set`; returns whether it was "successful" in the
/// set-semantics sense.
#[inline]
pub fn apply(set: &dyn ConcurrentSet, op: OpType, key: u64) -> bool {
    match op {
        OpType::Insert => set.insert(key),
        OpType::Delete => set.delete(key),
        OpType::Contains => set.contains(key),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashtable::HashTableSet;
    use crate::size::LinearizableSize;

    #[test]
    fn key_range_matches_paper_formula() {
        // Paper's example: n = 1M, 30% ins / 20% del => r ≈ 1.67M.
        assert_eq!(key_range(1_000_000, UPDATE_HEAVY), 1_666_666);
        assert_eq!(key_range(1_000_000, READ_HEAVY), 1_666_666);
    }

    #[test]
    fn mixes_sum_to_100() {
        assert_eq!(UPDATE_HEAVY.contains_pct(), 50);
        assert_eq!(READ_HEAVY.contains_pct(), 95);
    }

    #[test]
    fn op_stream_respects_mix() {
        let mut s = OpStream::new(1, UPDATE_HEAVY, 1000);
        let mut counts = [0u32; 3];
        for _ in 0..100_000 {
            let (op, k) = s.next();
            counts[op as usize] += 1;
            assert!((1..=1000).contains(&k));
        }
        let ins = counts[0] as f64 / 1000.0;
        let del = counts[1] as f64 / 1000.0;
        assert!((28.0..32.0).contains(&ins), "insert% {ins}");
        assert!((18.0..22.0).contains(&del), "delete% {del}");
    }

    #[test]
    fn prefill_reaches_exact_size() {
        let t: HashTableSet<LinearizableSize> = HashTableSet::new(crate::MAX_THREADS, 2048);
        prefill(&t, 1500, key_range(1500, UPDATE_HEAVY), 7);
        assert_eq!(t.size(), Some(1500));
    }

    #[test]
    fn streams_are_deterministic() {
        let mut a = OpStream::new(5, READ_HEAVY, 100);
        let mut b = OpStream::new(5, READ_HEAVY, 100);
        for _ in 0..1000 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn key_dist_parses_the_cli_surface() {
        assert_eq!(KeyDist::parse("uniform"), Some(KeyDist::Uniform));
        assert_eq!(KeyDist::parse("zipf:0.99"), Some(KeyDist::Zipf(0.99)));
        assert_eq!(
            KeyDist::parse("zipf:0.5").map(|d| d.label()),
            Some("zipf:0.5".into())
        );
        for bad in ["zipf", "zipf:", "zipf:0", "zipf:1", "zipf:1.5", "zipf:x", "pareto"] {
            assert_eq!(KeyDist::parse(bad), None, "{bad} must be rejected");
        }
        assert_eq!(KeyDist::Uniform.label(), "uniform");
    }

    #[test]
    fn zipf_stream_stays_in_range_and_skews_to_the_head() {
        let mut s = OpStream::with_dist(9, UPDATE_HEAVY, 1000, KeyDist::Zipf(0.99));
        let mut head = 0u32;
        const DRAWS: u32 = 20_000;
        for _ in 0..DRAWS {
            let (_, k) = s.next();
            assert!((1..=1000).contains(&k), "zipf key {k} out of range");
            if k <= 10 {
                head += 1;
            }
        }
        // Under uniform, keys 1..=10 get ~1% of draws; zipf(0.99) puts the
        // majority of probability mass on the head ranks.
        assert!(
            head > DRAWS / 4,
            "zipf head got only {head}/{DRAWS} draws — not skewed"
        );
    }

    #[test]
    fn zipf_streams_are_deterministic() {
        let mut a = OpStream::with_dist(5, READ_HEAVY, 500, KeyDist::Zipf(0.7));
        let mut b = OpStream::with_dist(5, READ_HEAVY, 500, KeyDist::Zipf(0.7));
        for _ in 0..1000 {
            assert_eq!(a.next(), b.next());
        }
    }
}
