//! Chaos-plane integration tests (run with `--features faults`; the
//! whole file compiles away without it): seed-determinism of the
//! injection plane, forced optimistic fallbacks end to end, and the
//! pinned-seed chaos smoke over the server's self-healing surface —
//! stalls time out, poisons are contained, idle connections are reaped,
//! and the sampled in-server monitor stays violation-free on an honest
//! linearizable store.
#![cfg(feature = "faults")]

use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::make_set_opts;
use concurrent_size::cli::PolicyKind;
use concurrent_size::faults::{self, FaultAction, FaultPlane, FaultSite};
use concurrent_size::server::{BlockingClient, Server, ServerConfig};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::SizeOpts;

/// Same seed, same thread, same plane => the same fire/skip sequence.
/// (Decisions hash the seed, site, spec, thread and per-site hit count —
/// nothing wall-clock.)
#[test]
fn fault_decisions_are_seed_deterministic() {
    assert!(faults::COMPILED);
    let plane = FaultPlane::new(0xD5).with(FaultSite::OptimisticRetry, 2, FaultAction::Fire);
    let sequence = |plane: FaultPlane| -> Vec<bool> {
        let _guard = faults::install(plane);
        (0..64).map(|_| faults::fires(FaultSite::OptimisticRetry)).collect()
    };
    let first = sequence(plane.clone());
    let second = sequence(plane);
    assert_eq!(first, second, "same seed must replay the same schedule");
    assert!(
        first.iter().any(|&b| b),
        "a one-in-2 site never fired in 64 hits"
    );
    assert!(
        first.iter().any(|&b| !b),
        "a one-in-2 site fired on every hit"
    );
}

/// A firing `OptimisticRetry` forces the wait-free fallback path: every
/// `size()` lands in the fallback and the `fallbacks` gauge counts it,
/// while the returned value stays exact.
#[test]
fn forced_optimistic_fallbacks_raise_the_gauge() {
    let _guard = faults::install(FaultPlane::new(0xFA11).with(
        FaultSite::OptimisticRetry,
        1,
        FaultAction::Fire,
    ));
    let set = make_set_opts("hashtable", PolicyKind::Optimistic, 64, SizeOpts::default()).unwrap();
    for k in 1..=30u64 {
        set.insert(k);
    }
    for _ in 0..5 {
        assert_eq!(set.size(), Some(30), "forced fallback must stay exact");
    }
    let stats = set.size_stats().expect("optimistic policy has stats");
    assert!(
        stats.fallbacks >= 5,
        "only {} fallbacks after 5 forced sizes",
        stats.fallbacks
    );
}

/// The acceptance smoke: a pinned-seed chaos plane (jitter everywhere —
/// accept handoffs included — short writes on conn and coalesced-reply
/// flushes, random handler panics) plus a targeted stall and poison
/// key, against a **two-reactor** server with every self-healing knob
/// on. Stalled requests time out and their slots recover, poisons
/// answer `ERR PANIC` without killing the pool, idle connections are
/// reaped, the sampled monitor reports zero violations, and the server
/// still serves.
#[test]
fn chaos_smoke_server_heals_and_stays_linearizable() {
    const STALL: u64 = 888_888_888_888;
    const POISON: u64 = 777_777_777_777;
    let _guard = faults::install(
        FaultPlane::chaos(0xC1A05)
            .with_stall_key(STALL, Duration::from_millis(300))
            .with_poison_key(POISON),
    );

    let store: Arc<dyn ConcurrentSet> = Arc::from(
        make_set_opts(
            "hashtable",
            PolicyKind::Linearizable,
            1 << 10,
            SizeOpts::default(),
        )
        .unwrap(),
    );
    let config = ServerConfig {
        handlers: 3,
        reactors: 2,
        request_timeout: Some(Duration::from_millis(50)),
        conn_idle: Some(Duration::from_millis(250)),
        monitor_sample: 4,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store, config).expect("bind");
    let addr = server.local_addr();

    // Stalls (300ms) far exceed the deadline (50ms): each PUT gets
    // `ERR TIMEOUT` unless a random chaos panic beats the stall hook.
    let mut driver = BlockingClient::connect(addr);
    let mut timeouts_seen = 0;
    for _ in 0..3 {
        match driver.cmd(format!("PUT {STALL}")).as_str() {
            "ERR TIMEOUT" => timeouts_seen += 1,
            "ERR PANIC" => {}
            other => panic!("stalled PUT answered {other:?}"),
        }
    }
    assert!(timeouts_seen >= 1, "no stalled request ever timed out");

    // Let the stalled handlers drain so the poison phase dispatches
    // instantly instead of timing out behind them in the queue; the
    // 250ms idle reaper collects `driver` meanwhile — healing too.
    std::thread::sleep(Duration::from_millis(400));
    drop(driver);

    // Poisons panic in the handler; `catch_unwind` turns every one into
    // a served `ERR PANIC` (so does a random chaos panic).
    let mut active = BlockingClient::connect(addr);
    for _ in 0..3 {
        assert_eq!(active.cmd(format!("PUT {POISON}")), "ERR PANIC");
    }

    // Self-healing under load: an idle connection is reaped while the
    // active one (chaos-tolerant) keeps making protocol progress.
    let mut idle = TcpStream::connect(addr).expect("idle connect");
    idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    for k in 1..=12u64 {
        for cmd in [format!("PUT {k}"), format!("HAS {k}")] {
            let reply = active.cmd(cmd);
            assert!(
                ["1", "0", "ERR PANIC", "ERR TIMEOUT"].contains(&reply.as_str()),
                "unexpected reply {reply:?}"
            );
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut buf = [0u8; 8];
    assert_eq!(
        idle.read(&mut buf).expect("reaped socket"),
        0,
        "idle conn not reaped"
    );

    // STATS is reactor-inline (immune to pool chaos): the gauges must
    // show the healing that just happened and a clean monitor.
    let stats = concurrent_size::server::parse_stats(&active.cmd("STATS")).expect("STATS parses");
    assert!(stats["timeouts"] >= 1, "timeouts gauge never moved");
    assert!(
        stats["panics"] >= 3,
        "panics gauge below the 3 poisons: {}",
        stats["panics"]
    );
    assert!(stats["reaped"] >= 1, "reaped gauge never moved");
    assert_eq!(
        stats["monitor_violations"],
        0,
        "monitor flagged an honest linearizable store"
    );

    // The server still serves: SIZE eventually answers numerically.
    let size = (0..20)
        .find_map(|_| active.cmd("SIZE").parse::<i64>().ok())
        .expect("SIZE never answered numerically under chaos");
    assert!(size >= 0, "negative size {size}");
}

/// The two multi-reactor fault sites, targeted. A panicking accept
/// handoff drops exactly the socket being handed off (the acceptor's
/// per-handoff `catch_unwind` keeps it accepting), and an always-firing
/// reply-coalesce short write fragments every flush without corrupting
/// pipelined reply order.
#[test]
fn handoff_panic_drops_one_socket_and_short_writes_keep_order() {
    let store: Arc<dyn ConcurrentSet> = Arc::from(
        make_set_opts(
            "hashtable",
            PolicyKind::Linearizable,
            64,
            SizeOpts::default(),
        )
        .unwrap(),
    );
    let config = ServerConfig {
        reactors: 2,
        ..Default::default()
    };
    {
        let plane = FaultPlane::new(0xACC3).with(FaultSite::AcceptHandoff, 1, FaultAction::Panic);
        let _guard = faults::install(plane);
        let server = Server::bind("127.0.0.1:0", store.clone(), config).expect("bind");
        let mut dropped = TcpStream::connect(server.local_addr()).expect("connect");
        dropped.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 8];
        assert_eq!(
            dropped.read(&mut buf).expect("dropped socket"),
            0,
            "a panicking handoff must drop the socket (EOF), not wedge it"
        );
    }
    let plane =
        FaultPlane::new(0xC0A7).with(FaultSite::ReplyCoalesce, 1, FaultAction::ShortWrite(1));
    let _guard = faults::install(plane);
    let server = Server::bind("127.0.0.1:0", store, config).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    for k in 0..32u64 {
        client.send(format!("PUT {k}"));
    }
    for i in 0..32 {
        assert_eq!(
            client.recv().expect("pipelined reply"),
            "1",
            "reply {i} corrupted under 1-byte reply flushes"
        );
    }
}
