//! Cross-module integration tests: every structure × policy combination
//! under concurrent stress, linearizability probes, and the full
//! Rust → PJRT analytics pipeline.
//!
//! Requires `make artifacts` (the `make test` flow guarantees it).

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;

use concurrent_size::analytics::{analyze, EpochRecorder};
use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies};
use concurrent_size::bst::BstSet;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::history;
use concurrent_size::list::LinkedListSet;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::runtime::Artifacts;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{HandshakeSize, LinearizableSize, LockSize, OptimisticSize, SizePolicy};
use concurrent_size::skiplist::SkipListSet;
use concurrent_size::snapshot::SnapshotSkipList;
use concurrent_size::vcas::VcasSet;
use concurrent_size::workload::{self, key_range, UPDATE_HEAVY};
use concurrent_size::MAX_THREADS;

fn all_sized_sets() -> Vec<Box<dyn ConcurrentSet>> {
    vec![
        Box::new(HashTableSet::<LinearizableSize>::new(MAX_THREADS, 4096)),
        Box::new(SkipListSet::<LinearizableSize>::new(MAX_THREADS)),
        Box::new(BstSet::<LinearizableSize>::new(MAX_THREADS)),
        Box::new(LinkedListSet::<LinearizableSize>::new(MAX_THREADS)),
        Box::new(HashTableSet::<LockSize>::new(MAX_THREADS, 4096)),
        Box::new(HashTableSet::<OptimisticSize>::new(MAX_THREADS, 4096)),
        Box::new(SkipListSet::<HandshakeSize>::new(MAX_THREADS)),
        Box::new(BstSet::<OptimisticSize>::new(MAX_THREADS)),
        Box::new(LinkedListSet::<HandshakeSize>::new(MAX_THREADS)),
        Box::new(SnapshotSkipList::new(MAX_THREADS)),
        Box::new(VcasSet::new(MAX_THREADS, 4096)),
    ]
}

/// Sequential model check: every structure agrees with a BTreeSet oracle.
#[test]
fn all_structures_match_sequential_model() {
    for set in all_sized_sets() {
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0xAB);
        for _ in 0..3000 {
            let k = rng.gen_range_incl(1, 200);
            match rng.gen_range(3) {
                0 => assert_eq!(set.insert(k), model.insert(k), "{} insert {k}", set.name()),
                1 => assert_eq!(set.delete(k), model.remove(&k), "{} delete {k}", set.name()),
                _ => assert_eq!(
                    set.contains(k),
                    model.contains(&k),
                    "{} contains {k}",
                    set.name()
                ),
            }
            if model.len() % 97 == 0 {
                assert_eq!(set.size(), Some(model.len() as i64), "{} size", set.name());
            }
        }
        assert_eq!(set.size(), Some(model.len() as i64), "{} final", set.name());
    }
}

/// Concurrent churn: sizes stay within the live-key bound and match the
/// model at quiescence (for the linearizable structures).
#[test]
fn concurrent_churn_bounds_all_structures() {
    for set in all_sized_sets() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(set);
        let stop = Arc::new(AtomicBool::new(false));
        let key_space = 96u64;
        let churners: Vec<_> = (0..4u64)
            .map(|t| {
                let set = set.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut rng = Xoshiro256::new(t + 1);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range_incl(1, key_space);
                        if rng.gen_bool(0.5) {
                            set.insert(k);
                        } else {
                            set.delete(k);
                        }
                    }
                })
            })
            .collect();
        for _ in 0..200 {
            let s = set.size().unwrap();
            assert!(
                (0..=key_space as i64).contains(&s),
                "{}: size {s} outside [0, {key_space}]",
                set.name()
            );
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        // Quiescent cross-check against a fresh count by membership probing.
        let live = (1..=key_space).filter(|&k| set.contains(k)).count();
        assert_eq!(set.size(), Some(live as i64), "{} quiescent", set.name());
    }
}

/// Paper Figures 1–2: the linearizable policy never exhibits the anomalies,
/// on any structure.
#[test]
fn methodology_has_no_anomalies() {
    let skip: SkipListSet<LinearizableSize> = SkipListSet::new(MAX_THREADS);
    assert_eq!(fig1_anomalies(&skip, 300), 0);
    assert_eq!(fig2_anomalies(&skip, 100), 0);
    let bst: BstSet<LinearizableSize> = BstSet::new(MAX_THREADS);
    assert_eq!(fig1_anomalies(&bst, 300), 0);
    assert_eq!(fig2_anomalies(&bst, 100), 0);
    let ht: HashTableSet<LinearizableSize> = HashTableSet::new(MAX_THREADS, 1024);
    assert_eq!(fig1_anomalies(&ht, 300), 0);
    assert_eq!(fig2_anomalies(&ht, 100), 0);
}

/// Size thread racing a prefd workload: every observation in bounds, and
/// the harness path (the exact code the figure benches run) stays sane.
#[test]
fn harness_roundtrip_with_size_thread() {
    use concurrent_size::harness::{run, RunConfig};
    let set: SkipListSet<LinearizableSize> = SkipListSet::new(MAX_THREADS);
    let range = key_range(2000, UPDATE_HEAVY);
    workload::prefill(&set, 2000, range, 9);
    let mut cfg = RunConfig::new(3, 1, UPDATE_HEAVY, range);
    cfg.duration = std::time::Duration::from_millis(300);
    let res = run(&set, &cfg);
    assert!(res.workload_ops > 0 && res.size_ops > 0);
    // Quiescent: linearizable size equals a membership census.
    let live = (1..=range).filter(|&k| set.contains(k)).count();
    assert_eq!(set.size(), Some(live as i64));
}

/// Full three-layer pipeline: workload → epoch sampling → PJRT kernels.
/// Skips when the PJRT runtime (the `pjrt` feature + artifacts) is absent.
#[test]
fn pipeline_end_to_end_exact_at_quiescence() {
    let artifacts = match Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping PJRT pipeline test: {e}");
            return;
        }
    };
    let set: Arc<SkipListSet<LinearizableSize>> = Arc::new(SkipListSet::new(MAX_THREADS));
    workload::prefill(set.as_ref(), 1000, 2000, 11);

    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..2u64)
        .map(|t| {
            let set = set.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut stream = workload::OpStream::new(t, UPDATE_HEAVY, 2000);
                while !stop.load(SeqCst) {
                    let (op, k) = stream.next();
                    workload::apply(set.as_ref(), op, k);
                }
            })
        })
        .collect();

    let calc = set.policy().calculator().unwrap();
    let mut rec = EpochRecorder::new();
    for _ in 0..20 {
        std::thread::sleep(std::time::Duration::from_millis(10));
        rec.record(calc);
    }
    stop.store(true, SeqCst);
    for w in workers {
        w.join().unwrap();
    }
    rec.record(calc); // quiescent

    let report = analyze(&artifacts, &rec).unwrap();
    assert!(report.final_exact(), "quiescent Pallas size must be exact");
    assert_eq!(
        *report.linearizable_sizes.last().unwrap(),
        set.size().unwrap()
    );
}

/// The Pallas history pipeline agrees with the Rust oracle on random logs.
/// Skips when the PJRT runtime (the `pjrt` feature + artifacts) is absent.
#[test]
fn pallas_history_matches_oracle_on_random_logs() {
    let artifacts = match Artifacts::load_default() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("skipping PJRT oracle cross-check: {e}");
            return;
        }
    };
    let mut rng = Xoshiro256::new(0xD1CE);
    for _ in 0..10 {
        let n = rng.gen_range(3000) as usize + 1;
        let deltas: Vec<i64> = (0..n).map(|_| rng.gen_range(3) as i64 - 1).collect();
        let (p_run, p_stats) = artifacts.validate_history(&deltas).unwrap();
        let (r_run, r_stats) = history::validate(&deltas);
        assert_eq!(p_run, r_run);
        assert_eq!(p_stats, r_stats);
    }
}

/// EBR memory accounting: long churn must not leak retired nodes
/// unboundedly (retired ≈ freed after flush).
#[test]
fn ebr_reclaims_under_structure_churn() {
    {
        let set: SkipListSet<LinearizableSize> = SkipListSet::new(MAX_THREADS);
        for round in 0..50 {
            for k in 0..100u64 {
                set.insert(k + round * 13 % 256);
            }
            for k in 0..100u64 {
                set.delete(k + round * 13 % 256);
            }
        }
    }
    concurrent_size::ebr::flush(64);
    let (retired, freed) = concurrent_size::ebr::stats();
    assert!(retired > 0, "churn must retire nodes");
    assert!(
        freed + 1024 >= retired,
        "leak suspicion: retired={retired} freed={freed}"
    );
}

/// Thread slots recycle cleanly across many short-lived workers touching
/// shared structures.
#[test]
fn thread_slot_recycling_under_structure_use() {
    let set: Arc<HashTableSet<LinearizableSize>> = Arc::new(HashTableSet::new(MAX_THREADS, 256));
    for wave in 0..8 {
        let hs: Vec<_> = (0..8u64)
            .map(|t| {
                let set = set.clone();
                std::thread::spawn(move || {
                    for k in 0..50u64 {
                        set.insert(wave * 1000 + t * 100 + k);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }
    assert_eq!(set.size(), Some(8 * 8 * 50));
}
