//! Seeded interleaving linearizability monitor: timestamped op/size
//! histories across all six policies × four structures, verified with
//! `history::monitor` — every `size()` return must be justified by some
//! linearization of the recorded history (ISSUE 4 satellite; the
//! aggressive generalization of the DeltaLog spot checks, after
//! arXiv 2509.17795's online-monitoring framing). Scanner threads ride
//! the same schedule: every `scan`/`count_range` return is checked
//! against the keyed history's per-key membership bounds, for **every**
//! policy — the interval criterion accepts the un-validated fallback
//! scans too, so a scan violation always means a torn collect.

use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{make_set, STRUCTURES};
use concurrent_size::cli::PolicyKind;
use concurrent_size::history::monitor::{Monitor, Report, ScanReport};
use concurrent_size::list::LinkedListSet;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{NaiveSize, SizeOpts, SizePolicy};
use concurrent_size::MAX_THREADS;

const UPDATERS: usize = 3;
const SIZERS: usize = 2;
const SCANNERS: usize = 2;
const OPS_PER_UPDATER: usize = 1_500;
const SIZES_PER_SIZER: usize = 250;
const SCANS_PER_SCANNER: usize = 150;
const KEY_SPACE: u64 = 48;

/// Drive one structure/policy combination with seeded updater and sizer
/// threads, recording everything into a monitor.
fn drive(structure: &str, policy: PolicyKind, seed: u64) -> (Report, ScanReport) {
    let set: Arc<dyn ConcurrentSet> = Arc::from(make_set(structure, policy, 128).unwrap());
    let monitor = Monitor::new();
    std::thread::scope(|scope| {
        for t in 0..UPDATERS as u64 {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ ((t + 1) * 0x9E37));
                for _ in 0..OPS_PER_UPDATER {
                    let k = rng.gen_range_incl(1, KEY_SPACE);
                    match rng.gen_range(3) {
                        0 => {
                            let timer = monitor.begin();
                            if set.insert(k) {
                                monitor.commit_keyed_update(timer, k, 1);
                            }
                        }
                        1 => {
                            let timer = monitor.begin();
                            if set.delete(k) {
                                monitor.commit_keyed_update(timer, k, -1);
                            }
                        }
                        _ => {
                            set.contains(k); // moves no size: not recorded
                        }
                    }
                }
            });
        }
        // Scanners run under EVERY policy: structures always answer
        // range reads (validated double-collect when the policy has
        // counters, per-key-justified traversal otherwise).
        for t in 0..SCANNERS as u64 {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(seed ^ ((t + 5) * 0x5CA4));
                for i in 0..SCANS_PER_SCANNER {
                    let lo = rng.gen_range_incl(1, KEY_SPACE);
                    let hi = (lo + rng.gen_range(16)).min(KEY_SPACE);
                    if i % 2 == 0 {
                        let timer = monitor.begin();
                        let pairs = set.scan(lo, hi).expect("structures answer scans");
                        monitor.commit_scan(
                            timer,
                            lo,
                            hi,
                            pairs.into_iter().map(|(k, _)| k).collect(),
                        );
                    } else {
                        let timer = monitor.begin();
                        let n = set.count_range(lo, hi).expect("structures answer counts");
                        monitor.commit_count(timer, lo, hi, n);
                    }
                    if rng.gen_bool(0.25) {
                        std::thread::yield_now();
                    }
                }
            });
        }
        if policy.provides_size() {
            for t in 0..SIZERS as u64 {
                let set = set.clone();
                let monitor = &monitor;
                scope.spawn(move || {
                    let mut rng = Xoshiro256::new(seed ^ ((t + 77) * 0xC0FF));
                    for _ in 0..SIZES_PER_SIZER {
                        match rng.gen_range(3) {
                            0 => {
                                let timer = monitor.begin();
                                let v = set.size().expect("policy provides size");
                                monitor.commit_size(timer, v);
                            }
                            1 => {
                                let timer = monitor.begin();
                                let v = set.size_exact().expect("policy provides size");
                                monitor.commit_size(timer, v.value);
                            }
                            _ => {
                                // Stale reads are justified within a
                                // window widened by their reported age.
                                let timer = monitor.begin();
                                let bound = Duration::from_micros(rng.gen_range_incl(1, 800));
                                let v = set.size_recent(bound).expect("policy provides size");
                                assert!(v.age <= bound, "age above the requested bound");
                                monitor.commit_size_with_slack(timer, v.value, v.age);
                            }
                        }
                        if rng.gen_bool(0.25) {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        }
    });
    let report = monitor.verify();
    // The monitor saw every successful update, so its net must equal the
    // structure's quiescent size (when the policy reports one).
    if let Some(size) = set.size() {
        assert_eq!(
            size, report.final_net,
            "{structure}/{policy:?}: quiescent size vs monitor net"
        );
    }
    (report, monitor.verify_scans())
}

/// The acceptance sweep: six policies × four structures. Every
/// linearizable policy must produce an unjustifiable-value-free history;
/// `NaiveSize` is *documented* non-linearizable, so its (rare, racy)
/// violations are reported but not failed on.
#[test]
fn monitor_passes_all_policies_on_all_structures() {
    for (i, structure) in STRUCTURES.iter().enumerate() {
        for policy in PolicyKind::ALL {
            let (report, scan_report) = drive(
                structure,
                policy,
                0x5EED ^ ((i as u64) << 8) ^ policy as u64,
            );
            assert!(report.updates > 0, "{structure}/{policy:?}: no updates");
            // Scan/count justification is policy-independent: the
            // interval bound accepts even naive's fallback scans, so any
            // violation means a torn collect — a failure everywhere.
            assert!(
                scan_report.is_ok(),
                "{structure}/{policy:?}: unjustified scans {:?}",
                scan_report.violations
            );
            assert_eq!(
                scan_report.scans_checked + scan_report.counts_checked,
                SCANNERS * SCANS_PER_SCANNER,
                "{structure}/{policy:?}: dropped scan observations"
            );
            match policy {
                PolicyKind::Naive => {
                    // Non-linearizable by design: the monitor may catch
                    // it; that is the monitor working, not a regression.
                    if !report.is_ok() {
                        eprintln!(
                            "note: monitor caught {} expected naive-policy \
                             anomalies on {structure}",
                            report.violations.len()
                        );
                    }
                }
                _ => {
                    assert!(
                        report.is_ok(),
                        "{structure}/{policy:?}: unjustified sizes {:?}",
                        report.violations
                    );
                    if policy.provides_size() {
                        assert_eq!(
                            report.sizes_checked,
                            SIZERS * SIZES_PER_SIZER,
                            "{structure}/{policy:?}: dropped size observations"
                        );
                    }
                }
            }
        }
    }
}

/// The monitor has teeth: with `NaiveSize`'s anomaly window widened, the
/// paper's Figure 2 schedule (a delete's decrement landing before its
/// insert's delayed increment) produces a negative size, which no
/// linearization justifies — the monitor must flag it.
#[test]
fn monitor_flags_the_naive_negative_size_anomaly() {
    use std::sync::atomic::{AtomicBool, Ordering::SeqCst};

    let mut policy = NaiveSize::new(MAX_THREADS, SizeOpts::default());
    policy.set_insert_window(Duration::from_micros(800));
    let set = Arc::new(LinkedListSet::<NaiveSize>::with_policy(policy));
    let monitor = Monitor::new();
    let negative_seen = AtomicBool::new(false);
    for k in 1..=600u64 {
        std::thread::scope(|scope| {
            let inserter = set.clone();
            scope.spawn(move || {
                inserter.insert(k); // increments only after the window
            });
            scope.spawn(|| {
                let timer = monitor.begin();
                while !set.delete(k) {
                    std::hint::spin_loop();
                }
                monitor.commit_update(timer, -1);
            });
            scope.spawn(|| {
                for _ in 0..32 {
                    let timer = monitor.begin();
                    let v = set.size().unwrap();
                    monitor.commit_size(timer, v);
                    if v < 0 {
                        negative_seen.store(true, SeqCst);
                        break;
                    }
                }
            });
        });
        // The insert is only recorded once it completed (window and
        // all), mirroring what an online monitor can actually know.
        let timer = monitor.begin();
        monitor.commit_update(timer, 1);
        if negative_seen.load(SeqCst) {
            break;
        }
    }
    assert!(
        negative_seen.load(SeqCst),
        "naive policy never exposed a negative size (widen the window?)"
    );
    let report = monitor.verify();
    assert!(
        !report.is_ok(),
        "monitor failed to flag a recorded negative size"
    );
    assert!(report
        .violations
        .iter()
        .any(|v| v.event.value < 0 && v.low >= 0));
}
