//! Randomized property tests (proptest_lite) over the size mechanism's
//! invariants — the Rust-side counterpart of the paper's Section 8 claims.

use concurrent_size::proptest_lite;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::size::{OpKind, SizeCalculator, SizeOpts, UpdateInfo};
use concurrent_size::{bst::BstSet, hashtable::HashTableSet, skiplist::SkipListSet};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::prop_assert;

/// Claim: update_metadata is idempotent and order-insensitive across
/// helpers — any interleaving of duplicate updates yields the same final
/// counters and size.
#[test]
fn prop_metadata_updates_idempotent_under_duplication() {
    proptest_lite::run("metadata idempotent", |rng: &mut Xoshiro256| {
        let nthreads = rng.gen_range(8) as usize + 1;
        let sc = SizeCalculator::new(nthreads, SizeOpts::default());
        let mut per_thread = vec![(0u64, 0u64); nthreads]; // (ins, del)
        let ops = rng.gen_range(200) + 1;
        let mut expected = 0i64;
        for _ in 0..ops {
            let tid = rng.gen_range(nthreads as u64) as usize;
            let is_insert = {
                // deletes only if the thread has spare inserts (legal set history)
                let (ins, del) = per_thread[tid];
                ins == del || rng.gen_bool(0.6)
            };
            let (ins, del) = &mut per_thread[tid];
            let (kind, counter) = if is_insert {
                *ins += 1;
                expected += 1;
                (OpKind::Insert, *ins)
            } else {
                *del += 1;
                expected -= 1;
                (OpKind::Delete, *del)
            };
            let packed = UpdateInfo { tid, counter }.pack();
            // The initiator plus a random number of helpers all update.
            for _ in 0..(1 + rng.gen_range(3)) {
                sc.update_metadata(packed, kind);
            }
        }
        let size = sc.compute();
        prop_assert!(size == expected, "size {size} != expected {expected}");
        // Counters must match the per-thread tallies exactly.
        for (tid, &(ins, del)) in per_thread.iter().enumerate() {
            prop_assert!(sc.counter(tid, OpKind::Insert) == ins);
            prop_assert!(sc.counter(tid, OpKind::Delete) == del);
        }
        Ok(())
    });
}

/// Claim: `create_update_info` always targets current+1 (the c-th op of a
/// thread publishes counter value c).
#[test]
fn prop_create_update_info_monotone() {
    proptest_lite::run("update info monotone", |rng| {
        let sc = SizeCalculator::new(4, SizeOpts::default());
        let mut counters = [0u64; 4];
        for _ in 0..rng.gen_range(100) + 1 {
            let tid = rng.gen_range(4) as usize;
            let packed = sc.create_update_info(OpKind::Insert, tid);
            let info = UpdateInfo::unpack(packed);
            prop_assert!(info.tid == tid);
            prop_assert!(info.counter == counters[tid] + 1, "non-monotone info");
            sc.update_metadata(packed, OpKind::Insert);
            counters[tid] += 1;
        }
        Ok(())
    });
}

/// Claim: under random interleaved single-thread workloads, every
/// structure's size() tracks a sequential model exactly (linearizability
/// degenerates to sequential correctness here; concurrent interleavings
/// are covered by the stress tests).
#[test]
fn prop_structures_match_model_with_random_ops() {
    proptest_lite::run_with(
        "structures vs model",
        proptest_lite::Config {
            cases: 16,
            seed: 0x512E,
        },
        |rng| {
            let sets: Vec<Box<dyn ConcurrentSet>> = vec![
                Box::new(HashTableSet::<LinearizableSize>::new(64, 512)),
                Box::new(SkipListSet::<LinearizableSize>::new(64)),
                Box::new(BstSet::<LinearizableSize>::new(64)),
            ];
            let mut model = std::collections::BTreeSet::new();
            for _ in 0..rng.gen_range(1200) + 1 {
                let k = rng.gen_range_incl(1, 64);
                match rng.gen_range(3) {
                    0 => {
                        let want = model.insert(k);
                        for s in &sets {
                            prop_assert!(s.insert(k) == want, "{} insert({k})", s.name());
                        }
                    }
                    1 => {
                        let want = model.remove(&k);
                        for s in &sets {
                            prop_assert!(s.delete(k) == want, "{} delete({k})", s.name());
                        }
                    }
                    _ => {
                        let want = model.contains(&k);
                        for s in &sets {
                            prop_assert!(s.contains(k) == want, "{} contains({k})", s.name());
                        }
                    }
                }
            }
            for s in &sets {
                prop_assert!(
                    s.size() == Some(model.len() as i64),
                    "{} size != model {}",
                    s.name(),
                    model.len()
                );
            }
            Ok(())
        },
    );
}
