//! Integration tests for the background `SizeRefresher` daemon: the
//! bounded-staleness contract under arbitrary refresh periods (proptest),
//! monotone consistency of published values with applied deltas, clean
//! start/retune/stop through the `ConcurrentSet` surface, and the
//! HandshakeSize stress regression guarding the PR 3 deadlock fixes
//! under the new daemon.

use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::Arc;
use std::time::{Duration, Instant};

use concurrent_size::bench_util::{make_set, STRUCTURES};
use concurrent_size::cli::PolicyKind;
use concurrent_size::list::LinkedListSet;
use concurrent_size::prop_assert;
use concurrent_size::proptest_lite;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{HandshakeSize, SizePolicy};
use concurrent_size::MAX_THREADS;

fn wait_until(mut cond: impl FnMut() -> bool, what: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// The daemon publishes on its own: with no size caller at all, rounds
/// accumulate and a later `size_recent` is served from the publication —
/// on every structure.
#[test]
fn daemon_turns_size_recent_into_a_passive_read() {
    for structure in STRUCTURES {
        let set = make_set(structure, PolicyKind::Linearizable, 64).unwrap();
        for k in 1..=17u64 {
            set.insert(k);
        }
        assert!(set.set_refresh_period(Some(Duration::from_micros(200))));
        wait_until(
            || set.size_stats().unwrap().daemon_rounds >= 2,
            "daemon rounds",
        );
        let v = set.size_recent(Duration::from_secs(60)).unwrap();
        assert_eq!(v.value, 17, "{structure}: published value");
        assert!(v.shared, "{structure}: must hit the publication");
        let stats = set.size_stats().unwrap();
        assert!(stats.recent_hits >= 1, "{structure}: no passive hit");
        assert!(!set.set_refresh_period(None));
        let rounds = set.size_stats().unwrap().daemon_rounds;
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(
            set.size_stats().unwrap().daemon_rounds,
            rounds,
            "{structure}: daemon survived stop"
        );
    }
}

/// Structures without a size (baseline policy) refuse the daemon.
#[test]
fn sizeless_policies_refuse_the_daemon() {
    let set = make_set("hashtable", PolicyKind::Baseline, 64).unwrap();
    assert!(!set.set_refresh_period(Some(Duration::from_millis(1))));
    assert_eq!(set.size_stats().unwrap().daemon_rounds, 0);
}

/// ISSUE 4 satellite — the staleness contract, propertized: for random
/// refresh periods and random staleness bounds, every `size_recent(d)`
/// served while a refresher runs satisfies `age <= d`, values are always
/// sizes the set actually passed through (monotone phases force this),
/// and the published stream is consistent with the applied deltas.
#[test]
fn prop_refresher_staleness_contract() {
    proptest_lite::run_with(
        "refresher staleness contract",
        proptest_lite::Config {
            cases: 6,
            seed: 0xD43,
        },
        |rng| {
            let policy = if rng.gen_bool(0.5) {
                PolicyKind::Linearizable
            } else {
                PolicyKind::Optimistic
            };
            let set = make_set("list", policy, 64).unwrap();
            let period = Duration::from_micros(100 + rng.gen_range(2_000));
            prop_assert!(
                set.set_refresh_period(Some(period)),
                "daemon must start (period {period:?})"
            );
            let total = 40 + rng.gen_range(60);

            // Phase 1: insert-only. Published values may lag but can only
            // grow, and never past the applied count.
            let mut last = 0i64;
            for k in 1..=total {
                set.insert(k);
                let bound = Duration::from_micros(1 + rng.gen_range(3_000));
                let v = set.size_recent(bound).unwrap();
                prop_assert!(v.age <= bound, "age {:?} above bound {bound:?}", v.age);
                prop_assert!(
                    (0..=k as i64).contains(&v.value),
                    "insert phase: size {} outside [0, {k}]",
                    v.value
                );
                prop_assert!(
                    v.value >= last,
                    "insert-only published stream regressed: {} < {last}",
                    v.value
                );
                last = v.value;
            }

            // Boundary pin: force a fresh publication at exactly `total`.
            // Without it a stale phase-1 publication could be served
            // first and a later (fresh) read could legitimately report a
            // LARGER value, breaking the mirrored monotonicity check
            // below. After this read, every round the phase-2 stream can
            // serve was collected with all inserts applied.
            let v = set.size_recent(Duration::ZERO).unwrap();
            prop_assert!(
                v.value == total as i64,
                "boundary exact read {} != {total}",
                v.value
            );

            // Phase 2: delete-only. The same argument, mirrored.
            let mut last = total as i64;
            for k in 1..=total {
                set.delete(k);
                let bound = Duration::from_micros(1 + rng.gen_range(3_000));
                let v = set.size_recent(bound).unwrap();
                prop_assert!(v.age <= bound, "age {:?} above bound {bound:?}", v.age);
                prop_assert!(
                    (0..=total as i64).contains(&v.value),
                    "delete phase: impossible size {}",
                    v.value
                );
                prop_assert!(
                    v.value <= last,
                    "delete-only published stream grew: {} > {last}",
                    v.value
                );
                last = v.value;
            }

            // Quiescent: any fresh-enough read converges to the truth.
            let v = set.size_recent(Duration::ZERO).unwrap();
            prop_assert!(v.value == 0, "quiescent zero-staleness read {}", v.value);
            set.set_refresh_period(None);
            Ok(())
        },
    );
}

/// ISSUE 4 satellite — stress regression: a refresher daemon (whose
/// combiner freezes the structure via the handshake), combining
/// `size_exact` callers, and guard-holding updaters — some calling the
/// policy's raw `size()` *while holding their op guard* (the PR 3
/// deadlock schedules) — must all make progress concurrently. The test
/// completing is the assertion; a deadlock hangs it.
#[test]
fn handshake_daemon_combiners_and_guard_holders_make_progress() {
    let set = Arc::new(LinkedListSet::<HandshakeSize>::new(MAX_THREADS));
    assert!(set.set_refresh_period(Some(Duration::from_micros(200))));
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| {
        // Combining exact callers (no guards held: arbiter contract).
        for _ in 0..2 {
            let set = set.clone();
            scope.spawn(move || {
                for _ in 0..400 {
                    let v = set.size_exact().unwrap();
                    assert!(v.value >= 0);
                }
            });
        }
        // Guard-holding updaters; every 16th op calls raw size() under
        // its own guard (self- and cross-deadlock regression paths).
        let updaters: Vec<_> = (0..2)
            .map(|_| {
                let set = set.clone();
                scope.spawn(move || {
                    let policy = set.policy();
                    for i in 0..800u64 {
                        {
                            let _g = policy.enter();
                            policy.commit_insert(&(), 0);
                            if i % 16 == 0 {
                                assert!(policy.size().unwrap() >= 0);
                            }
                        }
                        {
                            let _g = policy.enter();
                            policy.commit_delete(0);
                        }
                    }
                })
            })
            .collect();
        // A background churn thread through the set API proper.
        {
            let set = set.clone();
            let stop = stop.clone();
            scope.spawn(move || {
                let mut k = 0u64;
                while !stop.load(SeqCst) {
                    k += 1;
                    set.insert(k % 32);
                    set.delete(k % 32);
                }
            });
        }
        for u in updaters {
            u.join().unwrap();
        }
        stop.store(true, SeqCst);
    });

    set.set_refresh_period(None);
    assert_eq!(
        set.size_exact().unwrap().value,
        0,
        "paired ops must cancel out"
    );
    let stats = set.size_stats().unwrap();
    assert!(stats.daemon_rounds > 0, "daemon starved");
    assert!(stats.rounds > 0);
}

/// Retuning replaces the daemon atomically and keeps the cumulative
/// daemon-round counter monotone across generations.
#[test]
fn retuning_the_period_replaces_the_daemon() {
    let set = make_set("skiplist", PolicyKind::Optimistic, 64).unwrap();
    set.insert(1);
    assert!(set.set_refresh_period(Some(Duration::from_micros(100))));
    wait_until(
        || set.size_stats().unwrap().daemon_rounds >= 1,
        "first generation round",
    );
    let before = set.size_stats().unwrap().daemon_rounds;
    assert!(set.set_refresh_period(Some(Duration::from_micros(150))));
    wait_until(
        || set.size_stats().unwrap().daemon_rounds > before,
        "second generation round",
    );
    assert!(set.size_stats().unwrap().daemon_rounds >= before);
    set.set_refresh_period(None);
}
