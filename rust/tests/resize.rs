//! Incremental-resize acceptance tests: a BTreeMap oracle driven across
//! several growth phases, the linearizability monitor racing `size` /
//! `size_exact` / `scan` against live bucket migration, and (under
//! `--features faults`) a chaos pass where the `ResizeMigrate` site
//! panics mid-quantum and the table self-repairs — the mover mutex
//! poison is absorbed, the straggler sweep finishes the bucket, and no
//! key or counter is lost.

use std::sync::Arc;

use concurrent_size::hashtable::HashTableSet;
use concurrent_size::history::monitor::Monitor;
use concurrent_size::proptest_lite;
use concurrent_size::prop_assert;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::LinearizableSize;
use concurrent_size::MAX_THREADS;

/// Claim: across arbitrary interleavings of put/insert/delete/get/scan
/// and several table doublings, the hashtable stays exactly a
/// `BTreeMap` — membership, values, size, range scans, and the
/// post-migration physical count all match the oracle.
#[test]
fn prop_growth_matches_btreemap_oracle() {
    proptest_lite::run_with(
        "resize vs BTreeMap oracle",
        proptest_lite::Config {
            cases: 12,
            seed: 0x2E512E,
        },
        |rng: &mut Xoshiro256| {
            // Deliberately tiny: the op stream must cross the load-factor
            // trigger several times to exercise growth, not steady state.
            let set = HashTableSet::<LinearizableSize>::new(MAX_THREADS, 4);
            let initial_capacity = set.capacity();
            let mut oracle = std::collections::BTreeMap::new();
            let key_space = 300 + rng.gen_range(300);
            for _ in 0..1_500 {
                let k = rng.gen_range_incl(1, key_space);
                match rng.gen_range(6) {
                    // Insert-biased so the table actually grows.
                    0 | 1 => {
                        let v = rng.gen_range(1 << 20);
                        let fresh = set.put(k, v);
                        let want = oracle.insert(k, v).is_none();
                        prop_assert!(fresh == want, "put({k}) fresh {fresh} != {want}");
                    }
                    2 => {
                        let fresh = set.insert(k);
                        // `insert` is put(k, 0): an existing key keeps its
                        // value, a fresh one gets 0.
                        let want = if oracle.contains_key(&k) {
                            false
                        } else {
                            oracle.insert(k, 0);
                            true
                        };
                        prop_assert!(fresh == want, "insert({k}) {fresh} != {want}");
                    }
                    3 => {
                        let got = set.delete(k);
                        let want = oracle.remove(&k).is_some();
                        prop_assert!(got == want, "delete({k}) {got} != {want}");
                    }
                    4 => {
                        let got = set.get(k);
                        let want = oracle.get(&k).copied();
                        prop_assert!(got == want, "get({k}) {got:?} != {want:?}");
                    }
                    _ => {
                        let lo = rng.gen_range_incl(1, key_space);
                        let hi = (lo + rng.gen_range(48)).min(key_space);
                        let got = set.scan(lo, hi).expect("hashtable answers scans");
                        let want: Vec<(u64, u64)> =
                            oracle.range(lo..=hi).map(|(&k, &v)| (k, v)).collect();
                        prop_assert!(
                            got == want,
                            "scan({lo},{hi}) {} pairs != oracle {}",
                            got.len(),
                            want.len()
                        );
                    }
                }
                let size = set.size().expect("policy provides size");
                prop_assert!(
                    size == oracle.len() as i64,
                    "size {size} != oracle {}",
                    oracle.len()
                );
            }
            prop_assert!(
                set.resizes() >= 1,
                "op stream never crossed the load-factor trigger"
            );
            prop_assert!(
                set.capacity() > initial_capacity,
                "resize never doubled the bucket array"
            );
            set.finish_migration();
            prop_assert!(
                set.migration_pending() == 0,
                "migration debt after finish_migration"
            );
            prop_assert!(
                set.quiescent_count() == oracle.len(),
                "physical count {} != oracle {}",
                set.quiescent_count(),
                oracle.len()
            );
            Ok(())
        },
    );
}

/// Seeded size/scan calls racing live migration, checked by the history
/// monitor: insert-heavy updaters drag a 16-bucket table through
/// several doublings while sizers and scanners observe mid-quantum —
/// every returned size and scan key set must still be justified by a
/// linearization of the recorded history.
#[test]
fn monitor_justifies_sizes_and_scans_racing_migration() {
    const UPDATERS: u64 = 3;
    const SIZERS: u64 = 2;
    const OPS_PER_UPDATER: usize = 1_200;
    const SIZES_PER_SIZER: usize = 250;
    const SCANS: usize = 150;
    const KEY_SPACE: u64 = 600;
    const SEED: u64 = 0x9E512E;

    let set = Arc::new(HashTableSet::<LinearizableSize>::new(MAX_THREADS, 16));
    let monitor = Monitor::new();
    std::thread::scope(|scope| {
        for t in 0..UPDATERS {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(SEED ^ ((t + 1) * 0x9E37));
                for _ in 0..OPS_PER_UPDATER {
                    let k = rng.gen_range_incl(1, KEY_SPACE);
                    // Insert-biased (3:1) so live occupancy climbs
                    // through the trigger repeatedly.
                    if rng.gen_range(4) < 3 {
                        let timer = monitor.begin();
                        if set.insert(k) {
                            monitor.commit_keyed_update(timer, k, 1);
                        }
                    } else {
                        let timer = monitor.begin();
                        if set.delete(k) {
                            monitor.commit_keyed_update(timer, k, -1);
                        }
                    }
                }
            });
        }
        for t in 0..SIZERS {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(SEED ^ ((t + 9) * 0xC0FF));
                for _ in 0..SIZES_PER_SIZER {
                    if rng.gen_bool(0.5) {
                        let timer = monitor.begin();
                        let v = set.size().expect("policy provides size");
                        monitor.commit_size(timer, v);
                    } else {
                        let timer = monitor.begin();
                        let v = set.size_exact().expect("policy provides size");
                        monitor.commit_size(timer, v.value);
                    }
                }
            });
        }
        {
            let set = set.clone();
            let monitor = &monitor;
            scope.spawn(move || {
                let mut rng = Xoshiro256::new(SEED ^ 0x5CA4);
                for i in 0..SCANS {
                    let lo = rng.gen_range_incl(1, KEY_SPACE);
                    let hi = (lo + rng.gen_range(32)).min(KEY_SPACE);
                    if i % 2 == 0 {
                        let timer = monitor.begin();
                        let pairs = set.scan(lo, hi).expect("hashtable answers scans");
                        monitor.commit_scan(
                            timer,
                            lo,
                            hi,
                            pairs.into_iter().map(|(k, _)| k).collect(),
                        );
                    } else {
                        let timer = monitor.begin();
                        let n = set.count_range(lo, hi).expect("hashtable answers counts");
                        monitor.commit_count(timer, lo, hi, n);
                    }
                }
            });
        }
    });

    assert!(set.resizes() >= 1, "workload never triggered a resize");
    set.finish_migration();
    assert_eq!(set.migration_pending(), 0, "migration debt left behind");

    let report = monitor.verify();
    assert!(
        report.is_ok(),
        "unjustified sizes racing migration: {:?}",
        report.violations
    );
    assert_eq!(
        report.sizes_checked,
        (SIZERS as usize) * SIZES_PER_SIZER,
        "dropped size observations"
    );
    assert_eq!(
        set.size(),
        Some(report.final_net),
        "quiescent size vs monitor net"
    );
    let scan_report = monitor.verify_scans();
    assert!(
        scan_report.is_ok(),
        "unjustified scans racing migration: {:?}",
        scan_report.violations
    );
    assert_eq!(
        scan_report.scans_checked + scan_report.counts_checked,
        SCANS,
        "dropped scan observations"
    );
}

/// Chaos pass: every `ResizeMigrate` hit panics mid-quantum (after the
/// chain freeze, before the copy), poisoning the mover mutex with a
/// bucket half-migrated. The panics are caught at the op boundary;
/// once the plane is disarmed the next mover must absorb the poison,
/// recount the migration debt, finish every bucket, and end with the
/// exact oracle membership — self-repair, not a wedge.
#[cfg(feature = "faults")]
#[test]
fn resize_migrate_panic_mid_quantum_self_repairs() {
    use std::panic::{catch_unwind, AssertUnwindSafe};

    use concurrent_size::faults::{self, FaultAction, FaultPlane, FaultSite};

    const KEYS: u64 = 200;

    let set = HashTableSet::<LinearizableSize>::new(MAX_THREADS, 8);
    let mut panics = 0;
    {
        let _guard = faults::install(FaultPlane::new(0xDEAD512E).with(
            FaultSite::ResizeMigrate,
            1,
            FaultAction::Panic,
        ));
        // Every migrate attempt dies at the injection site, so each
        // op that lends a hand unwinds; the op's own logical
        // insert/delete never committed when it does (the panic site
        // precedes the routing retry), so the oracle is simply "every
        // key we successfully put".
        for k in 1..=KEYS {
            if catch_unwind(AssertUnwindSafe(|| set.put(k, k * 10))).is_err() {
                panics += 1;
            }
        }
        assert!(panics >= 1, "armed ResizeMigrate panic never fired");
        assert!(
            set.resizes() >= 1,
            "insert flood never crossed the trigger"
        );
    }

    // Plane disarmed: re-put the whole key set (upsert is idempotent),
    // then force the migration to drain. The first mover to take the
    // mutex absorbs the poison and repairs the half-migrated bucket.
    for k in 1..=KEYS {
        set.put(k, k * 10);
    }
    set.finish_migration();
    assert_eq!(set.migration_pending(), 0, "self-repair left migration debt");
    assert_eq!(set.size(), Some(KEYS as i64), "lost keys across the panic");
    assert_eq!(set.quiescent_count(), KEYS as usize, "physical/logical drift");
    for k in 1..=KEYS {
        assert_eq!(set.get(k), Some(k * 10), "key {k} lost or value torn");
    }

    // And the table still grows afterwards: the poisoned-and-repaired
    // mover keeps working for later resizes.
    let before = set.resizes();
    for k in KEYS + 1..=KEYS * 4 {
        set.put(k, 1);
    }
    set.finish_migration();
    assert!(set.resizes() > before, "table stopped growing after repair");
    assert_eq!(set.size(), Some((KEYS * 4) as i64));
}

/// The growth phase must not leak: every retired table generation and
/// migrated-out-of node goes through EBR, so a grow-then-drop cycle
/// under an epoch flush stays balanced (smoke for the Drop path that
/// frees both generations).
#[test]
fn grow_and_drop_reclaims_cleanly() {
    for round in 0..8u64 {
        let set = HashTableSet::<LinearizableSize>::new(MAX_THREADS, 4);
        for k in 1..=150u64 {
            set.put(k, round);
        }
        // Drop with a migration deliberately in flight on some rounds.
        if round % 2 == 0 {
            set.finish_migration();
        }
        drop(set);
        concurrent_size::ebr::collect();
    }
}

/// `Duration`-free sanity on the public resize surface: counters are
/// monotone and consistent through a growth phase.
#[test]
fn resize_stats_surface_is_consistent() {
    let set = HashTableSet::<LinearizableSize>::new(MAX_THREADS, 8);
    let stats0 = set.resize_stats().expect("hashtable reports resize stats");
    assert_eq!(stats0.resizes, 0);
    assert_eq!(stats0.occupancy, 0);
    for k in 1..=120u64 {
        set.insert(k);
    }
    set.finish_migration();
    let stats = set.resize_stats().expect("hashtable reports resize stats");
    assert!(stats.resizes >= 1, "no resize in 120 inserts from 8 buckets");
    assert_eq!(stats.occupancy, 120);
    assert_eq!(stats.migration_pending, 0);
    assert!(stats.capacity > stats0.capacity);
    assert!(
        (stats.load_factor - 120.0 / stats.capacity as f64).abs() < 1e-9,
        "load factor inconsistent with occupancy/capacity"
    );
    // Quiet period: nothing should move.
    assert_eq!(set.resize_stats(), Some(stats), "stats moved at quiescence");
}
