//! Scan-aware test tier (ISSUE 9): the dictionary upgrade's range reads
//! checked three ways —
//!
//! * **model**: quiescent `put`/`insert`/`delete`/`get`/`scan`/
//!   `count_range` sequences against a `BTreeMap` oracle, swept over
//!   random structure × policy picks (single-threaded, so scans must be
//!   *exact*, not merely justified);
//! * **wire**: pipelined `SCAN`/`COUNT` bursts mixed into update streams
//!   and cut at random TCP segment boundaries — multi-line scan replies
//!   must reassemble in command order through the 2-reactor server;
//! * **teeth**: the `history::monitor` scan checker must flag a
//!   deliberately torn scan record and an out-of-bounds count while
//!   accepting the honest versions of both.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{make_set, STRUCTURES};
use concurrent_size::cli::PolicyKind;
use concurrent_size::history::monitor::Monitor;
use concurrent_size::prop_assert;
use concurrent_size::proptest_lite;
use concurrent_size::server::{BlockingClient, Server, ServerConfig};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::shardstore::make_shard_store;
use concurrent_size::size::SizeOpts;

/// Quiescent dictionary semantics against a `BTreeMap` oracle: fresh
/// `put` vs overwrite, `insert` never clobbering a stored value, `get`
/// round-trips, and every `scan`/`count_range` exactly equal to the
/// model's range — across random structure × policy picks.
#[test]
fn scan_matches_btreemap_model_quiescently() {
    proptest_lite::run("quiescent scans equal the model range", |rng| {
        let structure = STRUCTURES[rng.gen_range(STRUCTURES.len() as u64) as usize];
        let policy = PolicyKind::ALL[rng.gen_range(PolicyKind::ALL.len() as u64) as usize];
        let set = make_set(structure, policy, 64).expect("known structure");
        let mut model: BTreeMap<u64, u64> = BTreeMap::new();
        for _ in 0..250 {
            let k = 1 + rng.gen_range(40);
            match rng.gen_range(4) {
                0 => {
                    let v = rng.gen_range(1000);
                    let fresh = set.put(k, v);
                    prop_assert!(
                        fresh == model.insert(k, v).is_none(),
                        "{structure}/{policy:?}: put({k}, {v}) freshness"
                    );
                }
                1 => {
                    // A set-flavored insert must not clobber a value.
                    let fresh = set.insert(k);
                    let model_fresh = !model.contains_key(&k);
                    if model_fresh {
                        model.insert(k, 0);
                    }
                    prop_assert!(
                        fresh == model_fresh,
                        "{structure}/{policy:?}: insert({k}) freshness"
                    );
                }
                2 => {
                    prop_assert!(
                        set.delete(k) == model.remove(&k).is_some(),
                        "{structure}/{policy:?}: delete({k})"
                    );
                }
                _ => {
                    prop_assert!(
                        set.get(k) == model.get(&k).copied(),
                        "{structure}/{policy:?}: get({k})"
                    );
                }
            }
        }
        // Range reads at quiescence are exact, window by window.
        for _ in 0..8 {
            let lo = 1 + rng.gen_range(40);
            let hi = lo + rng.gen_range(12);
            let want: Vec<(u64, u64)> = model.range(lo..=hi).map(|(&k, &v)| (k, v)).collect();
            let got = set.scan(lo, hi).expect("structures answer scans");
            prop_assert!(
                got == want,
                "{structure}/{policy:?}: scan({lo}, {hi}) = {got:?}, want {want:?}"
            );
            let n = set.count_range(lo, hi).expect("structures answer counts");
            prop_assert!(
                n == want.len() as i64,
                "{structure}/{policy:?}: count({lo}, {hi}) = {n}, want {}",
                want.len()
            );
        }
        prop_assert!(
            set.scan(40, 1) == Some(vec![]),
            "{structure}/{policy:?}: inverted range must be empty"
        );
        Ok(())
    });
}

/// Property: multi-line SCAN replies hold their place in pipelined reply
/// order no matter how the command stream is segmented on the wire —
/// random cut points over bursts mixing PUT/DEL/HAS/GET/SCAN/COUNT,
/// against a 2-reactor server with a small batch depth so bursts
/// straddle dispatch boundaries too.
#[test]
fn pipelined_scan_bursts_survive_random_wire_segmentation() {
    use std::sync::atomic::{AtomicU64, Ordering};
    let config = ServerConfig {
        reactors: 2,
        pipeline_depth: 4,
        ..Default::default()
    };
    let store: Arc<dyn ConcurrentSet> = Arc::from(
        make_set("hashtable", PolicyKind::Linearizable, 1 << 10).expect("hashtable"),
    );
    let server = Server::bind("127.0.0.1:0", store, config).expect("bind");
    let addr = server.local_addr();
    let case = AtomicU64::new(0);
    proptest_lite::run("segmented scan bursts reassemble in order", |rng| {
        // Disjoint key block per case: the store outlives the cases.
        let base = 1 + case.fetch_add(1, Ordering::Relaxed) * 100;
        let mut model: BTreeMap<u64, u64> = BTreeMap::new();
        let mut wire = Vec::new();
        let mut expected: Vec<String> = Vec::new();
        for _ in 0..30 {
            let key = base + rng.gen_range(8);
            match rng.gen_range(6) {
                0 => {
                    let v = rng.gen_range(100);
                    wire.extend_from_slice(format!("PUT {key} {v}\n").as_bytes());
                    expected.push(u64::from(model.insert(key, v).is_none()).to_string());
                }
                1 => {
                    wire.extend_from_slice(format!("DEL {key}\n").as_bytes());
                    expected.push(u64::from(model.remove(&key).is_some()).to_string());
                }
                2 => {
                    wire.extend_from_slice(format!("HAS {key}\n").as_bytes());
                    expected.push(u64::from(model.contains_key(&key)).to_string());
                }
                3 => {
                    wire.extend_from_slice(format!("GET {key}\n").as_bytes());
                    expected.push(
                        model
                            .get(&key)
                            .map_or_else(|| "NIL".to_string(), u64::to_string),
                    );
                }
                4 => {
                    // Occasionally inverted: `END 0`, not an error.
                    let (lo, hi) = if rng.gen_range(4) == 0 {
                        (base + 7, base)
                    } else {
                        (base, base + rng.gen_range(8))
                    };
                    wire.extend_from_slice(format!("SCAN {lo} {hi}\n").as_bytes());
                    let mut n = 0usize;
                    if lo <= hi {
                        for (&k, &v) in model.range(lo..=hi) {
                            expected.push(format!("{k} {v}"));
                            n += 1;
                        }
                    }
                    expected.push(format!("END {n}"));
                }
                _ => {
                    let (lo, hi) = (base, base + rng.gen_range(8));
                    wire.extend_from_slice(format!("COUNT {lo} {hi}\n").as_bytes());
                    expected.push(model.range(lo..=hi).count().to_string());
                }
            }
        }
        let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .map_err(|e| e.to_string())?;
        let mut out = stream.try_clone().map_err(|e| e.to_string())?;
        let mut reader = BufReader::new(stream);
        let mut sent = 0usize;
        while sent < wire.len() {
            let seg = 1 + rng.gen_range((wire.len() - sent) as u64) as usize;
            out.write_all(&wire[sent..sent + seg])
                .map_err(|e| e.to_string())?;
            sent += seg;
            if rng.gen_range(4) == 0 {
                std::thread::yield_now();
            }
        }
        for (i, want) in expected.iter().enumerate() {
            let mut line = String::new();
            let n = reader.read_line(&mut line).map_err(|e| e.to_string())?;
            prop_assert!(n > 0, "EOF at reply line {i}");
            prop_assert!(
                line.trim_end() == want,
                "reply line {i}: got {:?}, want {want:?}",
                line.trim_end()
            );
        }
        Ok(())
    });
}

/// End to end through `--store-shards`: SCAN against a server mounted on
/// a 4-shard store returns the cross-shard merge in key order, COUNT
/// agrees, and values stored on one shard come back through GET.
#[test]
fn sharded_server_scans_merge_across_store_shards() {
    let store: Arc<dyn ConcurrentSet> = Arc::from(
        make_shard_store(PolicyKind::Linearizable, 4, 1 << 10, SizeOpts::default())
            .expect("shard store factory"),
    );
    let server = Server::bind("127.0.0.1:0", store, ServerConfig::default()).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    // Reversed insertion order: key order in replies comes from the
    // merge, not from insertion accidents.
    for k in (1..=200u64).rev() {
        assert_eq!(client.cmd(format!("PUT {k} {}", k + 5000)), "1");
    }
    let pairs = client.scan(50, 99).expect("sharded SCAN");
    let want: Vec<(u64, u64)> = (50..=99).map(|k| (k, k + 5000)).collect();
    assert_eq!(pairs, want, "cross-shard merge must be key-ordered");
    assert_eq!(client.cmd("COUNT 1 200"), "200");
    assert_eq!(client.cmd("COUNT 201 500"), "0");
    assert_eq!(client.cmd("GET 137"), "5137");
    assert_eq!(client.cmd("GET 999"), "NIL");
    assert_eq!(client.cmd("DEL 137"), "1");
    assert_eq!(client.cmd("GET 137"), "NIL");
    assert_eq!(client.cmd("COUNT 1 200"), "199");
    assert_eq!(client.cmd("SCAN 99 50"), "END 0", "inverted range");
}

/// The scan checker itself has teeth: an honest quiescent record passes,
/// a scan missing a pinned key fails, and a count outside the justified
/// band fails — each flagged with the offending key/value.
#[test]
fn scan_checker_flags_torn_scans_and_bad_counts() {
    let honest = Monitor::new();
    let torn = Monitor::new();
    let miscount = Monitor::new();
    for m in [&honest, &torn, &miscount] {
        for k in 1..=10u64 {
            let timer = m.begin();
            m.commit_keyed_update(timer, k, 1);
        }
    }
    let keys: Vec<u64> = (1..=10).collect();

    let timer = honest.begin();
    honest.commit_scan(timer, 1, 10, keys.clone());
    let timer = honest.begin();
    honest.commit_count(timer, 1, 10, 10);
    assert!(honest.verify_scans().is_ok(), "honest record must pass");

    // Torn scan: drop key 4, which was pinned present before the scan.
    let timer = torn.begin();
    let mut missing: Vec<u64> = keys.clone();
    missing.retain(|&k| k != 4);
    torn.commit_scan(timer, 1, 10, missing);
    let report = torn.verify_scans();
    assert!(!report.is_ok(), "dropped pinned key must be flagged");
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.key == Some(4) && !v.reported),
        "violation must name the dropped key: {:?}",
        report.violations
    );

    // Count above any possible membership sum for the window.
    let timer = miscount.begin();
    miscount.commit_count(timer, 1, 10, 11);
    let report = miscount.verify_scans();
    assert!(!report.is_ok(), "impossible count must be flagged");
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.key.is_none() && v.value == 11 && v.high == 10),
        "violation must carry the count and its bound: {:?}",
        report.violations
    );
}
