//! Integration tests for the server subsystem (`rust/src/server/`): the
//! nonblocking reactor's concurrency claims (single- and multi-shard),
//! command pipelining under arbitrary TCP segmentation, size-driven
//! admission control end to end, the clamped-estimate contract, and
//! STATS under a running `SizeRefresher` daemon.

use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use concurrent_size::bench_util::make_set_opts;
use concurrent_size::cli::PolicyKind;
use concurrent_size::harness::{client_swarm, SwarmConfig};
use concurrent_size::prop_assert;
use concurrent_size::proptest_lite;
use concurrent_size::server::{
    Admission, BlockingClient, OVERLOAD_REPLY, Server, ServerConfig, Watermarks,
};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::SizeOpts;
use concurrent_size::thread_id;
use concurrent_size::workload::UPDATE_HEAVY;

/// A linearizable hashtable store with a `shards`-stripe mirror (the
/// estimate admission control consults).
fn store(shards: usize) -> Arc<dyn ConcurrentSet> {
    let opts = SizeOpts::default().with_shards(shards);
    Arc::from(make_set_opts("hashtable", PolicyKind::Linearizable, 1 << 12, opts).unwrap())
}

/// Library [`concurrent_size::server::parse_stats`], unwrapped: in these
/// tests a malformed STATS line is itself the failure.
fn parse_stats(line: &str) -> HashMap<String, u64> {
    concurrent_size::server::parse_stats(line).expect("STATS must parse")
}

/// The acceptance-criteria claim: the reactor serves ≥ 256 concurrent
/// connections — all provably open at the same time, far past the old
/// 64-slot `acquire_slot` panic threshold — while the handler pool stays
/// within the thread-slot budget.
#[test]
fn reactor_serves_256_concurrent_connections_with_bounded_pool() {
    let config = ServerConfig {
        handlers: 4,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    assert_eq!(server.handler_threads(), 4);
    assert!(server.handler_threads() <= thread_id::capacity());
    let addr = server.local_addr();

    const CONNS: usize = 300;
    let mut clients: Vec<BlockingClient> =
        (0..CONNS).map(|_| BlockingClient::connect(addr)).collect();
    // Write on every connection before reading any reply: all 300 are
    // open simultaneously and the server must multiplex them.
    for (i, client) in clients.iter_mut().enumerate() {
        client.send(format!("PUT {i}"));
    }
    for client in clients.iter_mut() {
        assert_eq!(client.recv().expect("PUT reply"), "1");
    }
    // Nothing has QUIT: the server is holding every connection live on
    // exactly 4 handler threads + 1 reactor.
    let stats = server.stats();
    assert!(
        stats.live_conns >= CONNS,
        "live {} < {CONNS}",
        stats.live_conns
    );
    assert!(stats.peak_conns >= CONNS);
    assert_eq!(stats.handlers, 4);

    // The store really took all 300 distinct keys.
    assert_eq!(clients[0].cmd("SIZE"), "300");
    assert_eq!(clients[0].cmd("SIZE?"), "300", "mirror exact at quiescence");

    // Pipelined commands on one connection come back in order.
    clients[1].send("PUT 1000");
    clients[1].send("HAS 1000");
    clients[1].send("DEL 1000");
    for step in ["PUT", "HAS", "DEL"] {
        assert_eq!(
            clients[1].recv().expect("pipelined reply"),
            "1",
            "{step} out of order"
        );
    }
}

/// Tentpole acceptance: 4 reactor shards serve 300 concurrent
/// connections, each holding a pipelined command burst — every reply in
/// per-connection order — while the acceptor's least-loaded handoff
/// spreads the connection tables and the merged STATS gauges stay
/// truthful (counters add, gauges max: the `ArbiterStats::merge`
/// convention).
#[test]
fn four_reactors_serve_300_pipelined_connections_in_order() {
    let config = ServerConfig {
        handlers: 4,
        reactors: 4,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    assert_eq!(server.reactor_count(), 4);
    let addr = server.local_addr();

    const CONNS: usize = 300;
    let mut clients: Vec<BlockingClient> =
        (0..CONNS).map(|_| BlockingClient::connect(addr)).collect();
    // Pipeline three commands on every connection before reading any
    // reply: all 300 connections hold in-flight batches at once, spread
    // over 4 disjoint shard tables feeding one handler pool.
    for (i, client) in clients.iter_mut().enumerate() {
        client.send(format!("PUT {i}"));
        client.send(format!("HAS {i}"));
        client.send(format!("DEL {i}"));
    }
    for (i, client) in clients.iter_mut().enumerate() {
        for step in ["PUT", "HAS", "DEL"] {
            assert_eq!(
                client.recv().expect("pipelined reply"),
                "1",
                "conn {i}: {step} reply out of order"
            );
        }
    }
    let stats = server.stats();
    assert_eq!(stats.reactors, 4);
    assert!(
        stats.live_conns >= CONNS,
        "live {} < {CONNS}",
        stats.live_conns
    );
    assert!(stats.peak_conns >= CONNS, "merged peak lost the high water");
    let loads = server.reactor_loads();
    assert_eq!(loads.len(), 4);
    assert_eq!(
        loads.iter().sum::<usize>(),
        stats.live_conns,
        "per-shard tables disagree with the merged live gauge: {loads:?}"
    );
    assert!(
        loads.iter().filter(|&&load| load > 0).count() >= 2,
        "acceptor parked every connection on one shard: {loads:?}"
    );
    // Every DEL landed: both size paths see an empty store, and the
    // dispatch queue drained symmetrically.
    assert_eq!(clients[0].cmd("SIZE"), "0");
    assert_eq!(clients[0].cmd("SIZE?"), "0");
    let wire_stats = parse_stats(&clients[0].cmd("STATS"));
    assert_eq!(wire_stats["reactors"], 4);
    assert_eq!(wire_stats["queue"], 0, "queue must drain at quiescence");
}

/// The admission state is genuinely shared across reactor shards:
/// alternating a PUT burst between connections on different shards
/// still admits exactly the high watermark's worth before shedding —
/// one gate, not one per shard — and STATS aggregates the shed count.
#[test]
fn admission_watermarks_are_shared_across_reactor_shards() {
    let config = ServerConfig {
        handlers: 2,
        reactors: 2,
        admission: Some(Watermarks::new(50, 20)),
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    let addr = server.local_addr();
    let mut first = BlockingClient::connect(addr);
    let mut second = BlockingClient::connect(addr);
    let (mut admitted, mut shed) = (0, 0);
    for k in 0..200u64 {
        let client = if k % 2 == 0 { &mut first } else { &mut second };
        match client.cmd(format!("PUT {k}")).as_str() {
            "1" => admitted += 1,
            OVERLOAD_REPLY => shed += 1,
            other => panic!("unexpected PUT reply {other:?}"),
        }
    }
    assert_eq!(admitted, 50, "one shared gate, not one per shard");
    assert_eq!(shed, 150);
    let stats = parse_stats(&first.cmd("STATS"));
    assert_eq!(stats["shed"], 150);
    assert_eq!(stats["reactors"], 2);
}

/// Pipelining torture over a raw socket: many commands in one TCP
/// segment, one command dribbled across several segments (split
/// mid-token and mid-key), and an overlong line interleaved mid-burst —
/// one reply per command, in order, with `ERR TOOLONG` resync between.
#[test]
fn pipelined_segments_reassemble_and_resync_in_order() {
    use std::io::Write;
    let config = ServerConfig {
        reactors: 2,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(0), config).expect("bind");
    let stream = TcpStream::connect(server.local_addr()).expect("connect");
    stream.set_nodelay(true).expect("nodelay");
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut out = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    let mut expect = |want: &[&str]| {
        for (i, reply) in want.iter().enumerate() {
            let mut line = String::new();
            assert!(
                reader.read_line(&mut line).expect("reply") > 0,
                "EOF at reply {i}"
            );
            assert_eq!(line.trim_end(), *reply, "reply {i} out of order");
        }
    };
    // (a) Five commands in one segment: one batch dispatch serves them
    // all and the replies come back coalesced, still one per line.
    out.write_all(b"PUT 1\nPUT 2\nHAS 1\nDEL 2\nHAS 2\n").unwrap();
    expect(&["1", "1", "1", "1", "0"]);
    // (b) Two commands over four segments: the line buffer reassembles
    // across reads, whatever the cut points.
    for chunk in [&b"PU"[..], b"T 4", b"2\nHAS", b" 42\n"] {
        out.write_all(chunk).unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    expect(&["1", "1"]);
    // (c) An overlong line mid-burst costs exactly one in-order
    // `ERR TOOLONG`; parsing resyncs at its newline and the burst
    // continues. Keys 1, 42, 7 are live at the end.
    let mut burst = Vec::new();
    burst.extend_from_slice(b"PUT 7\n");
    burst.extend_from_slice("x".repeat(300).as_bytes());
    burst.extend_from_slice(b"\nHAS 7\nSIZE\n");
    out.write_all(&burst).unwrap();
    expect(&["1", "ERR TOOLONG", "1", "3"]);
}

/// Property: replies always match command order against a model set, no
/// matter how the command stream is segmented — random cut points over
/// the whole wire image, against a 2-reactor server with a small batch
/// depth so bursts straddle batch boundaries too.
#[test]
fn reply_order_matches_command_order_under_random_segmentation() {
    use std::collections::HashSet;
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};
    let config = ServerConfig {
        reactors: 2,
        pipeline_depth: 4,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(0), config).expect("bind");
    let addr = server.local_addr();
    let case = AtomicU64::new(0);
    proptest_lite::run("segmentation preserves reply order", |rng| {
        // Disjoint key block per case: the store outlives the cases.
        let base = case.fetch_add(1, Ordering::Relaxed) * 100;
        let mut model: HashSet<u64> = HashSet::new();
        let mut wire = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..40 {
            let key = base + rng.gen_range(8);
            match rng.gen_range(3) {
                0 => {
                    wire.extend_from_slice(format!("PUT {key}\n").as_bytes());
                    expected.push(u64::from(model.insert(key)).to_string());
                }
                1 => {
                    wire.extend_from_slice(format!("DEL {key}\n").as_bytes());
                    expected.push(u64::from(model.remove(&key)).to_string());
                }
                _ => {
                    wire.extend_from_slice(format!("HAS {key}\n").as_bytes());
                    expected.push(u64::from(model.contains(&key)).to_string());
                }
            }
        }
        let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .map_err(|e| e.to_string())?;
        let mut out = stream.try_clone().map_err(|e| e.to_string())?;
        let mut reader = BufReader::new(stream);
        let mut sent = 0usize;
        while sent < wire.len() {
            let seg = 1 + rng.gen_range((wire.len() - sent) as u64) as usize;
            out.write_all(&wire[sent..sent + seg])
                .map_err(|e| e.to_string())?;
            sent += seg;
            if rng.gen_range(4) == 0 {
                std::thread::yield_now();
            }
        }
        for (i, want) in expected.iter().enumerate() {
            let mut line = String::new();
            let n = reader.read_line(&mut line).map_err(|e| e.to_string())?;
            prop_assert!(n > 0, "EOF at reply {i}");
            prop_assert!(
                line.trim_end() == want,
                "reply {i}: got {:?}, want {want:?}",
                line.trim_end()
            );
        }
        Ok(())
    });
}

/// Admission end to end: an overload burst gets `ERR OVERLOAD` while
/// `SIZE?` (served inline by the reactor) keeps answering, deletes stay
/// admitted, and hysteresis readmits only below the low watermark.
#[test]
fn overload_burst_sheds_puts_while_size_estimate_keeps_answering() {
    let config = ServerConfig {
        handlers: 2,
        admission: Some(Watermarks::new(50, 20)),
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    let addr = server.local_addr();
    let mut client = BlockingClient::connect(addr);
    let mut probe = BlockingClient::connect(addr);

    // Burst PUTs well past the high watermark: the first sheds appear
    // once the estimate reaches 50, and everything after stays shed.
    let (mut admitted, mut shed) = (0, 0);
    let mut first_shed_at = None;
    for k in 0..200u64 {
        match client.cmd(format!("PUT {k}")).as_str() {
            "1" => admitted += 1,
            OVERLOAD_REPLY => {
                shed += 1;
                first_shed_at.get_or_insert(k);
                // Mid-shed, the cheap probe keeps answering on another
                // connection (it is reactor-inline, not pool-queued).
                if shed == 1 {
                    let estimate: i64 = probe.cmd("SIZE?").parse().expect("numeric SIZE?");
                    assert!(estimate >= 50, "shed below the high watermark: {estimate}");
                }
            }
            other => panic!("unexpected PUT reply {other:?}"),
        }
    }
    assert_eq!(admitted, 50, "exactly the high watermark's worth admitted");
    assert_eq!(shed, 150, "everything past the watermark shed");
    assert_eq!(first_shed_at, Some(50));

    let stats = parse_stats(&probe.cmd("STATS"));
    assert_eq!(stats["shed"], 150);
    assert_eq!(stats["admitting"], 0, "gate must report shedding");

    // Hysteresis: drain into the band (estimate 35, between low 20 and
    // high 50) — deletes are always admitted, PUTs still shed.
    for k in 0..15u64 {
        assert_eq!(client.cmd(format!("DEL {k}")), "1");
    }
    assert_eq!(client.cmd("SIZE?"), "35");
    assert_eq!(
        client.cmd("PUT 900"),
        OVERLOAD_REPLY,
        "band must stay shedding"
    );

    // Drain to the low watermark: readmitted.
    for k in 15..30u64 {
        assert_eq!(client.cmd(format!("DEL {k}")), "1");
    }
    assert_eq!(client.cmd("SIZE?"), "20");
    assert_eq!(
        client.cmd("PUT 900"),
        "1",
        "at the low watermark PUTs readmit"
    );
    let stats = parse_stats(&probe.cmd("STATS"));
    assert_eq!(stats["admitting"], 1);

    // SIZE (exact, pool-served) agrees at quiescence: 50 - 30 + 1.
    assert_eq!(client.cmd("SIZE"), "21");
}

/// The clamped-estimate contract, both layers. Layer 1: a real sharded
/// store never reports a negative (or impossibly large) estimate at
/// quiescence, under random op sequences and shard counts. Layer 2: the
/// admission gate clamps arbitrary (even adversarial) raw readings and
/// its hysteresis follows the reference state machine.
#[test]
fn shed_decisions_never_observe_negative_or_absurd_estimates() {
    proptest_lite::run("store estimates stay clamped", |rng| {
        let shards = 1 + rng.gen_range(7) as usize;
        let set = store(shards);
        let mut live = 0i64;
        for _ in 0..rng.gen_range(200) {
            let key = rng.gen_range(64);
            if rng.gen_range(2) == 0 {
                live += i64::from(set.insert(key));
            } else {
                live -= i64::from(set.delete(key));
            }
            let est = set.size_estimate().expect("mirror configured");
            prop_assert!(est >= 0, "negative estimate {est}");
            prop_assert!(est <= 64, "estimate {est} beyond the touched key space");
        }
        let est = set.size_estimate().unwrap();
        prop_assert!(est == live, "quiescent estimate {est} != live {live}");
        Ok(())
    });

    proptest_lite::run("admission clamps and follows the reference", |rng| {
        let high = rng.gen_range(100) as i64;
        let low = rng.gen_range(high as u64 + 1) as i64;
        let gate = Admission::new(Watermarks::new(high, low));
        let mut ref_shedding = false;
        for _ in 0..100 {
            // Adversarial readings: absent mirrors, negatives, huge.
            let raw = match rng.gen_range(4) {
                0 => None,
                1 => Some(-(rng.gen_range(1 << 40) as i64)),
                2 => Some(rng.gen_range(1 << 40) as i64),
                _ => Some(rng.gen_range(150) as i64),
            };
            let clamped = Admission::clamp(raw);
            prop_assert!(clamped >= 0, "clamp let {raw:?} through as {clamped}");
            let admitted = gate.admit(raw);
            ref_shedding = if ref_shedding { clamped > low } else { clamped >= high };
            prop_assert!(
                admitted == !ref_shedding,
                "gate diverged from reference at reading {raw:?} (high={high} low={low})"
            );
            prop_assert!(gate.shedding() == ref_shedding, "exposed state diverged");
        }
        Ok(())
    });
}

/// Regression: `STATS` must parse — and keep parsing — while the
/// `SizeRefresher` daemon is concurrently driving arbiter rounds, and the
/// daemon's progress must show up in its `daemon_rounds` field.
#[test]
fn stats_parses_while_refresher_daemon_runs() {
    let set = store(2);
    assert!(set.set_refresh_period(Some(Duration::from_millis(1))));
    let server = Server::bind("127.0.0.1:0", set.clone(), ServerConfig::default()).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    for k in 0..32u64 {
        assert_eq!(client.cmd(format!("PUT {k}")), "1");
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        // Every sample must parse into numeric key=value pairs, whatever
        // the daemon is doing at that instant.
        let stats = parse_stats(&client.cmd("STATS"));
        for key in [
            "conns",
            "peak",
            "queue",
            "handlers",
            "reactors",
            "accepted",
            "shed",
            "admitting",
            "store_shards",
            "shard_shed",
            "faults",
            "timeouts",
            "panics",
            "reaped",
            "monitor_violations",
            "rounds",
            "adoptions",
            "recent_hits",
            "recent_refreshes",
            "daemon_rounds",
            "daemon_stalls",
            "fallbacks",
            "retry_budget",
        ] {
            assert!(stats.contains_key(key), "STATS missing {key}");
        }
        if stats["daemon_rounds"] > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "daemon drove no rounds in 10s");
        std::thread::sleep(Duration::from_millis(2));
    }
    // The published read the daemon maintains serves SIZE~ passively.
    let recent: i64 = client.cmd("SIZE~ 1000").parse().expect("numeric SIZE~");
    assert_eq!(recent, 32);
    set.set_refresh_period(None);
}

/// The harness's server-path load mode: a swarm far wider than the
/// thread-slot capacity (clients hold sockets, not slots) completes with
/// a reply per command and no protocol errors.
#[test]
fn client_swarm_drives_the_server_path() {
    let server = Server::bind("127.0.0.1:0", store(2), ServerConfig::default()).expect("bind");
    let swarm = client_swarm(
        server.local_addr(),
        SwarmConfig::new(8, 400, UPDATE_HEAVY, 2048, 7),
    )
    .expect("swarm");
    assert_eq!(swarm.ops, 8 * 400);
    assert_eq!(swarm.overloads, 0, "no admission gate configured");
    assert_eq!(swarm.errors, 0);
    assert!(swarm.throughput() > 0.0);
    let stats = server.stats();
    assert!(stats.accepted >= 8);
    assert_eq!(stats.queue_depth, 0, "queue must drain at quiescence");
}

/// Backpressure: a client that pipelines thousands of commands before
/// reading a single reply is served completely — the reactor gates reads
/// on the per-connection queue caps instead of buffering without bound,
/// and every reply still arrives in order.
#[test]
fn pipelined_flood_is_served_in_order_under_backpressure() {
    let server = Server::bind("127.0.0.1:0", store(0), ServerConfig::default()).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    const FLOOD: usize = 5000;
    for i in 0..FLOOD {
        client.send(format!("PUT {}", i % 16));
    }
    // Keys repeat mod 16: the first occurrence of each key answers "1",
    // every later one "0" — exact in-order bookkeeping over the flood.
    for i in 0..FLOOD {
        let want = if i < 16 { "1" } else { "0" };
        assert_eq!(
            client.recv().expect("flood reply"),
            want,
            "reply {i} out of order"
        );
    }
    assert_eq!(client.cmd("SIZE"), "16");
}

/// Protocol robustness on one connection: malformed input answers in
/// order without killing the connection; QUIT closes it.
#[test]
fn protocol_errors_answer_in_order_and_quit_closes() {
    let server = Server::bind("127.0.0.1:0", store(0), ServerConfig::default()).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    // Pipeline a valid, an invalid, and a valid command: replies must
    // come back in exactly that order.
    client.send("PUT 5");
    client.send("PUT notakey");
    client.send("HAS 5");
    assert_eq!(client.recv().expect("reply 1"), "1");
    assert_eq!(client.recv().expect("reply 2"), "ERR bad key");
    assert_eq!(client.recv().expect("reply 3"), "1");
    assert_eq!(client.cmd("SIZE~ bogus"), "ERR bad staleness");
    assert_eq!(client.cmd("WHAT"), "ERR unknown command");
    // Mirror disabled (0 shards): the estimate declines gracefully.
    assert!(client.cmd("SIZE?").starts_with("ERR"));
    client.send("QUIT");
    assert_eq!(
        client.recv(),
        None,
        "QUIT must close the connection without a reply"
    );
    // The server survives and serves fresh connections.
    let mut fresh = BlockingClient::connect(server.local_addr());
    assert_eq!(fresh.cmd("HAS 5"), "1");
}

/// An overlong line answers `ERR TOOLONG` in order and the connection
/// survives: parsing resyncs at the next newline (it used to close the
/// session, costing a fat-fingered client every pipelined command).
#[test]
fn overlong_line_answers_toolong_and_resyncs() {
    let server = Server::bind("127.0.0.1:0", store(0), ServerConfig::default()).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    assert_eq!(client.cmd("PUT 3"), "1");
    client.send(format!("PUT {}", "9".repeat(400)));
    client.send("HAS 3");
    assert_eq!(client.recv().expect("toolong reply"), "ERR TOOLONG");
    assert_eq!(client.recv().expect("follow-up reply"), "1");
    // Several overlong lines cost one in-order error each, nothing more.
    for _ in 0..3 {
        client.send("x".repeat(300));
    }
    client.send("SIZE");
    for i in 0..3 {
        assert_eq!(
            client.recv().expect("toolong burst reply"),
            "ERR TOOLONG",
            "line {i}"
        );
    }
    assert_eq!(client.recv().expect("size reply"), "1");
}

/// Idle reaping under `--conn-idle-ms`: connections with no *protocol*
/// progress are dropped — including a slowloris client dripping bytes
/// that never complete a line — while an active one on the same server
/// stays untouched.
#[test]
fn idle_and_slowloris_connections_are_reaped() {
    let config =
        ServerConfig {
            conn_idle: Some(Duration::from_millis(250)),
            ..Default::default()
        };
    let server = Server::bind("127.0.0.1:0", store(0), config).expect("bind");
    let addr = server.local_addr();
    let mut active = BlockingClient::connect(addr);
    let mut idle = BlockingClient::connect(addr);
    assert_eq!(idle.cmd("PUT 1"), "1");
    let mut slow = TcpStream::connect(addr).expect("slow connect");
    slow.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    for i in 0..10 {
        assert_eq!(active.cmd(format!("PUT {}", 100 + i)), "1");
        // The drip: one byte of a line that never ends.
        let _ = std::io::Write::write_all(&mut slow, b"x");
        std::thread::sleep(Duration::from_millis(50));
    }
    // ~500ms elapsed: `idle` (quiet since its one command) and `slow`
    // (bytes but never a line) are gone; `active` survived throughout.
    let stats = server.stats();
    assert!(stats.reaped >= 2, "reaped {} < 2", stats.reaped);
    assert_eq!(active.cmd("HAS 1"), "1");
    let mut line = String::new();
    let n = BufReader::new(&slow).read_line(&mut line).unwrap_or(0);
    assert_eq!(n, 0, "slowloris socket must be closed");
    assert_eq!(idle.recv(), None, "idle connection must be closed");
}

/// Satellite: admission under a stalled estimate pipeline. A wedged
/// refresher delays readings, but the gate carries no state beyond its
/// hysteresis bit — it must track the reference model on whatever stale
/// reading it is fed, and the moment drained readings arrive it must
/// readmit. `admitting=false` can never stick.
#[test]
fn admission_with_stale_estimates_never_wedges() {
    proptest_lite::run("stale estimates cannot wedge admission", |rng| {
        let high = 1 + rng.gen_range(100) as i64;
        let low = rng.gen_range(high as u64) as i64;
        let gate = Admission::new(Watermarks::new(high, low));
        // True size trace: a random walk clamped at empty.
        let steps = 200 + rng.gen_range(200) as usize;
        let mut truth = Vec::with_capacity(steps);
        let mut cur = 0i64;
        for _ in 0..steps {
            cur = (cur + rng.gen_range(7) as i64 - 3).max(0);
            truth.push(cur);
        }
        let mut ref_shedding = false;
        for i in 0..steps {
            // Stale delivery: the gate sees the estimate from up to 31
            // steps ago (a stalled refresher republishing old values),
            // with the lag itself jittering over time.
            let lag = rng.gen_range(1 + i.min(31) as u64) as usize;
            let seen = truth[i - lag];
            let admitted = gate.admit(Some(seen));
            ref_shedding = if ref_shedding { seen > low } else { seen >= high };
            prop_assert!(
                admitted == !ref_shedding,
                "diverged at step {i}: saw {seen} (high={high} low={low})"
            );
            prop_assert!(
                gate.shedding() == ref_shedding,
                "exposed state diverged at {i}"
            );
        }
        // Recovery: the store drained and fresh readings resume.
        let _ = gate.admit(Some(0));
        prop_assert!(!gate.shedding(), "gate wedged shut after drain");
        prop_assert!(gate.admit(Some(0)), "PUT still shed after drain");
        Ok(())
    });
}

/// Satellite (fault plane): a burst of poisoned PUTs — each one panicking
/// its handler mid-request — must not reduce healthy-connection service:
/// every panic costs its own client one `ERR PANIC`, the pool never
/// drains, and concurrent healthy clients complete every round trip.
#[cfg(feature = "faults")]
#[test]
fn poisoned_put_burst_does_not_starve_healthy_connections() {
    use concurrent_size::faults::{self, FaultPlane};
    const POISON: u64 = 777_777_777_777;
    const BURSTS: u64 = 25;
    let _guard = faults::install(FaultPlane::new(0xBAD).with_poison_key(POISON));
    let config = ServerConfig {
        handlers: 3,
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    let addr = server.local_addr();

    let poisoner = std::thread::spawn(move || {
        let mut client = BlockingClient::connect(addr);
        for _ in 0..BURSTS {
            assert_eq!(client.cmd(format!("PUT {POISON}")), "ERR PANIC");
        }
    });
    let mut healthy: Vec<BlockingClient> =
        (0..4).map(|_| BlockingClient::connect(addr)).collect();
    for round in 0..200u64 {
        for (c, client) in healthy.iter_mut().enumerate() {
            let key = 1000 * (c as u64 + 1) + round;
            assert_eq!(client.cmd(format!("PUT {key}")), "1");
            assert_eq!(client.cmd(format!("HAS {key}")), "1");
        }
    }
    poisoner.join().expect("poisoner panicked");
    let stats = server.stats();
    assert!(
        stats.panics >= BURSTS,
        "panics gauge {} < {BURSTS}",
        stats.panics
    );
    // The poisoned key never reached the store; every healthy key did.
    let mut probe = BlockingClient::connect(addr);
    assert_eq!(probe.cmd("SIZE"), "800");
}

/// Satellite (fault plane): a stalled PUT trips the per-request deadline
/// — the client gets `ERR TIMEOUT`, the connection's slot is reclaimed
/// (follow-ups answer immediately), and the handler's late reply is
/// dropped rather than misdelivered to the next request.
#[cfg(feature = "faults")]
#[test]
fn stalled_request_times_out_and_slot_recovers() {
    use concurrent_size::faults::{self, FaultPlane};
    const STALL: u64 = 888_888_888_888;
    let _guard = faults::install(
        FaultPlane::new(0x57A11).with_stall_key(STALL, Duration::from_millis(400)),
    );
    let config = ServerConfig {
        handlers: 2,
        request_timeout: Some(Duration::from_millis(40)),
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store(2), config).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());
    assert_eq!(client.cmd(format!("PUT {STALL}")), "ERR TIMEOUT");
    // Slot reclaimed: the same connection keeps being served while the
    // stalled handler is still asleep.
    assert_eq!(client.cmd("PUT 5"), "1");
    assert_eq!(client.cmd("HAS 5"), "1");
    // The stalled handler finishes eventually; its stale reply must have
    // been dropped (req_id mismatch), never delivered to a later command.
    std::thread::sleep(Duration::from_millis(500));
    assert_eq!(client.cmd("HAS 5"), "1");
    let stats = server.stats();
    assert_eq!(stats.timeouts, 1);
    assert_eq!(
        client.cmd("SIZE"),
        "2",
        "the stalled PUT did commit in the end"
    );
}

/// Dropping the handle stops the reactor and joins the pool, even with
/// clients mid-conversation.
#[test]
fn shutdown_joins_cleanly_with_live_connections() {
    let server = Server::bind("127.0.0.1:0", store(0), ServerConfig::default()).expect("bind");
    let addr = server.local_addr();
    let mut client = BlockingClient::connect(addr);
    assert_eq!(client.cmd("PUT 1"), "1");
    let started = Instant::now();
    drop(server);
    assert!(
        started.elapsed() < Duration::from_secs(5),
        "shutdown took {:?}",
        started.elapsed()
    );
    // The listener is gone: either the connect fails or the socket is
    // dead; either way no new server answers on that port.
    let gone = match TcpStream::connect(addr) {
        Err(_) => true,
        Ok(stream) => {
            stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            matches!(reader.read_line(&mut line), Err(_) | Ok(0))
        }
    };
    assert!(gone, "server still answering after drop");
}
